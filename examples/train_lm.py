"""End-to-end LM training driver: ~100M-class model, a few hundred steps
on CPU, with checkpointing and fault-tolerant resume.

    PYTHONPATH=src python examples/train_lm.py --steps 200

(defaults to a reduced model so the demo finishes in minutes; pass
``--arch qwen2-0.5b --full`` on real hardware).
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke
from repro.configs.base import TrainConfig
from repro.data import SyntheticTokens
from repro.runtime import StepMonitor
from repro.checkpoint import AsyncCheckpointer, latest_step, \
    restore_checkpoint
from repro.train.train_step import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="use the full (not smoke) architecture config")
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke(args.arch)
    # widen the smoke config into the ~100M range for a real demo
    if not args.full:
        cfg = dataclasses.replace(cfg, d_model=256, n_layers=4,
                                  d_ff=1024, n_heads=8, n_kv_heads=4,
                                  vocab=32_000)
    tc = TrainConfig(param_dtype="float32", compute_dtype="float32",
                     accum_dtype="float32", learning_rate=3e-4,
                     remat="none", seq_len=args.seq,
                     global_batch=args.batch)
    print(f"model: {cfg.name}  params ~{cfg.param_count() / 1e6:.0f}M")

    state = init_state(jax.random.PRNGKey(0), cfg, tc)
    step_fn = jax.jit(make_train_step(cfg, tc), donate_argnums=(0,))
    data = SyntheticTokens(vocab=cfg.vocab, seq_len=args.seq,
                           global_batch=args.batch)
    ckpt = AsyncCheckpointer(args.ckpt)
    monitor = StepMonitor()

    start = latest_step(args.ckpt) or 0
    if start:
        state = restore_checkpoint(args.ckpt, start, state)
        print(f"resumed from step {start}")

    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        t0 = time.monotonic()
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        slow = monitor.record(time.monotonic() - t0)
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}"
                  + ("  [straggler]" if slow else ""))
        if (step + 1) % 50 == 0:
            ckpt.save(step + 1, state)
    ckpt.close()
    print("done; checkpoints in", args.ckpt)


if __name__ == "__main__":
    main()
