"""Batched serving demo: prefill a batch of prompts, then greedy-decode
continuations with the ring-cache serve step.

    PYTHONPATH=src python examples/serve_lm.py --tokens 32
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.configs.base import ServeConfig
from repro.models import registry
from repro.train.serve_step import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b",
                    help="smoke config of this arch (mixtral shows the "
                         "sliding-window ring cache)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    sc = ServeConfig(seq_len=args.prompt_len + args.tokens,
                     batch=args.batch, param_dtype="float32",
                     compute_dtype="float32", kv_dtype="float32")
    params = registry.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    rng = np.random.default_rng(0)
    prompt = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.family == "vlm":
        prompt["patches"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.vision_tokens, cfg.d_model))
            * 0.02, jnp.float32)
    if cfg.family == "encdec":
        prompt["frames"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.encoder_seq, cfg.d_model))
            * 0.02, jnp.float32)

    t0 = time.time()
    out = greedy_generate(cfg, sc, params, prompt, args.tokens)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} generated={args.tokens}")
    print(f"wall {dt:.2f}s  ({args.batch * args.tokens / dt:.1f} tok/s "
          f"batched, CPU)")
    print("first sequence:", np.asarray(out[0])[:16], "...")


if __name__ == "__main__":
    main()
