"""Multiclass quickstart: one-vs-rest SVC + a C/gamma grid in one call.

    PYTHONPATH=src python examples/multiclass_quickstart.py

Trains a 3-class RBF-SVM through the sklearn-style facade, then runs a
whole C/gamma model-selection grid as ONE jit-compiled vmapped solve and
picks the best held-out configuration.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402

from repro.core import grid as grid_mod                   # noqa: E402
from repro.core import multiclass as mc                   # noqa: E402
from repro.core.solver import SolverConfig                # noqa: E402
from repro.svm import SVC, multiclass_blobs               # noqa: E402


def main():
    X, y = multiclass_blobs(300, seed=0, k=3)
    Xtr, ytr, Xte, yte = X[:200], y[:200], X[200:], y[200:]

    # --- facade: fit/predict like sklearn --------------------------------
    clf = SVC(C=10.0, gamma=0.5, eps=1e-3).fit(Xtr, ytr)
    print(f"SVC(one-vs-rest): classes={clf.classes_.tolist()} "
          f"n_support={clf.n_support_.tolist()} "
          f"test_acc={clf.score(Xte, yte):.3f}")

    # --- model selection: the whole grid is ONE compiled call ------------
    classes, y_idx = mc.class_index(ytr)
    Y = mc.ovr_labels(y_idx, len(classes))
    Cs = np.array([0.5, 2.0, 8.0, 32.0])
    gammas = np.array([0.1, 0.5, 2.0])
    res = grid_mod.solve_grid(jnp.asarray(Xtr), Y, Cs, gammas,
                              SolverConfig(eps=1e-3))
    print(f"grid: {res.alpha.shape[0] * res.alpha.shape[1] * res.alpha.shape[2]}"
          f" QPs solved in one call, all converged={bool(res.converged.all())}")

    # same grid through the fused two-pass batched engine (one while_loop,
    # two kernel passes per iteration for every lane; see README "Backend /
    # engine selection")
    res_f = grid_mod.solve_grid(jnp.asarray(Xtr), Y, Cs, gammas,
                                SolverConfig(eps=1e-3), impl="auto")
    agree = bool(jnp.allclose(res_f.objective, res.objective, rtol=1e-5))
    print(f"fused-batched engine: converged={bool(res_f.converged.all())} "
          f"objectives_match_vmapped={agree}")

    dec = grid_mod.grid_decision(jnp.asarray(Xte), jnp.asarray(Xtr), gammas,
                                 res.alpha, res.b)   # (nG, k, nC, m)
    pred = jnp.argmax(dec, axis=1)                   # (nG, nC, m)
    yte_idx = np.searchsorted(classes, yte)          # labels -> class indices
    acc = jnp.mean(pred == jnp.asarray(yte_idx)[None, None, :], axis=-1)
    gi, ci = np.unravel_index(int(jnp.argmax(acc)), acc.shape)
    print("held-out accuracy per (gamma, C):")
    for g, row in zip(gammas, np.asarray(acc)):
        print("  gamma=%-5g " % g + " ".join(
            f"C={c:<4g}:{a:.3f}" for c, a in zip(Cs, row)))
    print(f"best: gamma={gammas[gi]:g} C={Cs[ci]:g} acc={float(acc[gi, ci]):.3f}")


if __name__ == "__main__":
    main()
