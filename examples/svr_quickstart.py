"""SVR quickstart: fit a noisy sinc with ε-SVR on the fused PA-SMO engine.

    PYTHONPATH=src python examples/svr_quickstart.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.svm import SVR  # noqa: E402


def main():
    rng = np.random.default_rng(0)
    X = rng.uniform(-3, 3, size=(200, 1))
    y = np.sinc(X[:, 0]) + 0.1 * rng.normal(size=200)

    reg = SVR(C=10.0, epsilon=0.1, gamma=1.0).fit(X[:150], y[:150])
    print(f"engine={reg.engine_}  support_vectors={reg.n_support_}  "
          f"iterations={int(reg.fit_result_.iterations)}")
    print(f"train R^2={reg.score(X[:150], y[:150]):.3f}  "
          f"test R^2={reg.score(X[150:], y[150:]):.3f}")
    # the fit is one 2l-variable generalized dual QP — the doubled Gram is
    # never materialized (rows are tiled base rows)
    print(f"dual vars={reg.alpha_.shape[0]}  (2 x {X[:150].shape[0]})")


if __name__ == "__main__":
    main()
