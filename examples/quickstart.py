"""Quickstart: train an RBF-SVM with PA-SMO and compare against SMO.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402

from repro.core.solver import SolverConfig   # noqa: E402
from repro.svm import make_dataset, predict, train_svm  # noqa: E402


def main():
    X, y, C, gamma = make_dataset("chessboard", 800, seed=0)
    C = 1000.0  # tame the paper's extreme 1e6 for a quick demo
    Xtr, ytr, Xte, yte = X[:600], y[:600], X[600:], y[600:]

    for alg in ("smo", "pasmo"):
        cfg = SolverConfig(algorithm=alg, eps=1e-3, max_iter=500_000)
        model, res = train_svm(Xtr, ytr, C, gamma, cfg)
        acc = float(jnp.mean(predict(model, jnp.asarray(Xte)) == yte))
        print(f"{alg:6s}: iterations={int(res.iterations):7d}  "
              f"objective={float(res.objective):.4f}  "
              f"planning_steps={int(res.n_planning):6d}  "
              f"test_acc={acc:.3f}")

    print("\nPA-SMO reaches the same optimum in fewer iterations — the "
          "paper's Table 2 effect.")


if __name__ == "__main__":
    main()
