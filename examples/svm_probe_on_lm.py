"""SVM readout heads on LM features, trained with batched PA-SMO — the
paper's solver as a first-class feature of the LM stack.

    PYTHONPATH=src python examples/svm_probe_on_lm.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402

from repro.configs import get_smoke                      # noqa: E402
from repro.core.solver import SolverConfig               # noqa: E402
from repro.models import registry                        # noqa: E402
from repro.svm.probes import (extract_features, predict_probe,  # noqa: E402
                              train_probe)


def main():
    cfg = get_smoke("qwen2-0.5b")
    params = registry.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    rng = np.random.default_rng(0)

    # three synthetic "domains" distinguished by token-id band
    n_per, S, k = 24, 32, 3
    bands = [(0, cfg.vocab // 3), (cfg.vocab // 3, 2 * cfg.vocab // 3),
             (2 * cfg.vocab // 3, cfg.vocab)]
    tokens = np.concatenate([
        rng.integers(lo, hi, size=(n_per, S)) for lo, hi in bands
    ]).astype(np.int32)
    labels = np.repeat(np.arange(k), n_per)
    perm = rng.permutation(len(labels))
    tokens, labels = tokens[perm], labels[perm]
    n_tr = 54

    feats = extract_features(params, cfg, {"tokens": jnp.asarray(tokens)})
    probe = train_probe(feats[:n_tr], jnp.asarray(labels[:n_tr]), k,
                        C=10.0,
                        cfg=SolverConfig(algorithm="pasmo", eps=1e-3))
    pred = np.asarray(predict_probe(probe, feats[n_tr:]))
    acc = (pred == labels[n_tr:]).mean()
    print(f"features: {feats.shape}, classes: {k}")
    print(f"solver iterations per head: "
          f"{np.asarray(probe.iterations).tolist()}")
    print(f"held-out accuracy: {acc:.3f}")
    print("\nThe k one-vs-rest QPs were solved as ONE vmapped PA-SMO "
          "while_loop (batched solver).")


if __name__ == "__main__":
    main()
