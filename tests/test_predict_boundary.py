"""Zero-margin predict regression: a query point ON the separating surface
must get a valid label from every facade (the ``df >= 0`` convention).

``jnp.sign(0.0) == 0.0``, so a sign-based predict emits the invalid label
0 for any query whose decision value is exactly zero — easy to construct
(and to hit in the wild with symmetric data).  The tests build models whose
decision value at the query is an EXACT floating-point zero: two support
vectors equidistant from the query with opposite duals, so the two kernel
terms cancel bitwise.
"""

import jax.numpy as jnp
import numpy as np

from repro.svm import SVC, OneClassSVM
from repro.svm.model import SVMModel, decision_function, predict
from repro.svm.probes import SVMProbe, predict_probe


def _surface_model():
    """k(x, 0) - k(x, 2) + 0 == exact 0.0 at x = 1."""
    return SVMModel(X=jnp.asarray([[0.0], [2.0]]),
                    alpha=jnp.asarray([1.0, -1.0]),
                    b=jnp.asarray(0.0), gamma=jnp.asarray(0.5))


def test_svm_model_predict_zero_margin_is_plus_one():
    m = _surface_model()
    xq = jnp.asarray([[1.0]])
    assert float(decision_function(m, xq)[0]) == 0.0     # exact surface hit
    lab = np.asarray(predict(m, xq))
    assert lab[0] == 1.0                                 # NOT sign(0) == 0
    # off-surface queries keep their signs
    labs = np.asarray(predict(m, jnp.asarray([[-0.5], [2.5]])))
    np.testing.assert_array_equal(labs, [1.0, -1.0])


def test_svc_predict_zero_margin_returns_a_class():
    clf = SVC(C=1.0, gamma=0.5)
    clf.classes_ = np.array([-3, 7])                     # arbitrary labels
    clf.X_ = jnp.asarray([[0.0], [2.0]], clf.dtype)
    clf.alpha_ = jnp.asarray([1.0, -1.0], clf.dtype)
    clf.b_ = jnp.asarray(0.0, clf.dtype)
    clf.gamma_ = 0.5
    xq = np.array([[1.0]])
    assert float(clf.decision_function(xq)[0]) == 0.0
    assert clf.predict(xq)[0] == 7                       # df >= 0 -> classes_[1]


def test_oneclass_predict_zero_margin_is_inlier():
    det = OneClassSVM(nu=0.5, gamma=0.5)
    det.X_ = jnp.asarray([[0.0], [2.0]], det.dtype)
    det.alpha_ = jnp.asarray([1.0, -1.0], det.dtype)
    det.b_ = jnp.asarray(0.0, det.dtype)
    det.gamma_ = 0.5
    xq = np.array([[1.0]])
    assert float(det.decision_function(xq)[0]) == 0.0
    assert det.predict(xq)[0] == 1                       # +1, never 0


def test_probe_predict_tie_returns_valid_class():
    """OVR probes argmax scores — an exact tie still yields a real class
    index (the audit counterpart of the sign-based bug)."""
    X = jnp.asarray(np.random.default_rng(0).normal(size=(4, 3)))
    alphas = jnp.asarray(np.tile([[0.3, -0.1, 0.2, -0.4]], (2, 1)))
    probe = SVMProbe(X=X, alphas=alphas, biases=jnp.zeros(2), gamma=0.5,
                     iterations=jnp.zeros(2, jnp.int32))
    pred = np.asarray(predict_probe(probe, X))           # scores tie per row
    assert set(pred.tolist()) <= {0, 1}
