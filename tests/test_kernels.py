"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(64, 2), (200, 7), (1024, 16), (1500, 60), (4096, 128)]
DTYPES = [jnp.float32, jnp.float64]


def _setup(l, d, dtype, seed=0):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(l, d)), dtype)
    y = jnp.asarray(np.sign(rng.normal(size=l)), dtype)
    C = 10.0
    alpha = jnp.asarray(rng.uniform(-1, 1, size=l), dtype) * jnp.abs(y) * C
    alpha = jnp.clip(alpha, jnp.minimum(0.0, y * C), jnp.maximum(0.0, y * C))
    G = jnp.asarray(rng.normal(size=l), dtype)
    L = jnp.minimum(0.0, y * C)
    U = jnp.maximum(0.0, y * C)
    sqn = jnp.sum(X * X, axis=-1)
    gamma = jnp.asarray(0.3, dtype)
    return X, sqn, G, alpha, L, U, gamma


@pytest.mark.parametrize("l,d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("use_exact", [False, True])
def test_pass_a_row_wss(l, d, dtype, use_exact):
    X, sqn, G, alpha, L, U, gamma = _setup(l, d, dtype)
    i = 3
    xq = X[i]
    a_i, L_i, U_i = alpha[i], L[i], U[i]
    g_i = G[i]
    args = (X, sqn, G, alpha, L, U, xq, a_i, L_i, U_i, g_i,
            jnp.asarray(i, jnp.int32), jnp.asarray(use_exact), gamma)
    k_ref, j_ref, gain_ref = ref.rbf_row_wss(*args)
    k_pl, j_pl, gain_pl = ops.rbf_row_wss(*args, impl="interpret",
                                          block_l=256)
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(k_pl), np.asarray(k_ref),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(float(gain_pl), float(gain_ref),
                               rtol=10 * tol)
    assert int(j_pl) == int(j_ref)


@pytest.mark.parametrize("l,d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_pass_b_update_wss(l, d, dtype):
    X, sqn, G, alpha, L, U, gamma = _setup(l, d, dtype, seed=1)
    i, j = 3, 11
    k_i = ref.rbf_row(X, sqn, X[i], gamma)
    mu = jnp.asarray(0.37, dtype)
    alpha_new = alpha.at[i].add(mu).at[j].add(-mu)
    alpha_new = jnp.clip(alpha_new, L, U)
    G_ref, i_ref, gi_ref, gdn_ref = ref.rbf_update_wss(
        X, sqn, G, k_i, X[j], mu, alpha_new, L, U, gamma)
    G_pl, i_pl, gi_pl, gdn_pl = ops.rbf_update_wss(
        X, sqn, G, k_i, alpha_new, L, U, X[j], mu, gamma,
        impl="interpret", block_l=256)
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(G_pl), np.asarray(G_ref),
                               rtol=tol, atol=tol)
    assert int(i_pl) == int(i_ref)
    np.testing.assert_allclose(float(gi_pl), float(gi_ref), rtol=10 * tol)
    np.testing.assert_allclose(float(gdn_pl), float(gdn_ref), rtol=10 * tol)


@pytest.mark.parametrize("l1,l2,d", [(64, 64, 2), (200, 100, 7),
                                     (300, 513, 33), (1024, 256, 128)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_gram_block(l1, l2, d, dtype):
    rng = np.random.default_rng(2)
    X1 = jnp.asarray(rng.normal(size=(l1, d)), dtype)
    X2 = jnp.asarray(rng.normal(size=(l2, d)), dtype)
    gamma = 0.4
    K_ref = ref.gram_cross(X1, X2, gamma)
    K_pl = ops.gram(X1, X2, gamma, impl="interpret", block_i=128,
                    block_j=128)
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(K_pl), np.asarray(K_ref),
                               rtol=tol, atol=tol)


def test_gram_symmetric_psd():
    rng = np.random.default_rng(3)
    X = jnp.asarray(rng.normal(size=(100, 5)))
    K = np.asarray(ops.gram(X, gamma=0.5, impl="interpret",
                            block_i=128, block_j=128))
    np.testing.assert_allclose(K, K.T, atol=1e-12)
    w = np.linalg.eigvalsh(K)
    assert w.min() > -1e-8
    np.testing.assert_allclose(np.diag(K), 1.0, atol=1e-12)


def _setup_batched(l, d, B, dtype, seed=0):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(l, d)), dtype)
    sqn = jnp.sum(X * X, axis=-1)
    ys = jnp.asarray(np.sign(rng.normal(size=(B, l))), dtype)
    C = 10.0
    L = jnp.minimum(0.0, ys * C)
    U = jnp.maximum(0.0, ys * C)
    alpha = jnp.clip(jnp.asarray(rng.uniform(-1, 1, (B, l)), dtype) * C, L, U)
    G = jnp.asarray(rng.normal(size=(B, l)), dtype)
    gammas = jnp.asarray(rng.uniform(0.2, 1.5, B), dtype)
    i_idx = jnp.asarray(rng.integers(0, l, B), jnp.int32)
    return X, sqn, G, alpha, L, U, gammas, i_idx


def _lane(M, idx):
    return jnp.take_along_axis(M, idx[:, None], axis=1)[:, 0]


@pytest.mark.parametrize("l,d,B", [(64, 2, 3), (513, 33, 9)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_pass_a_batched_matches_single_lane(l, d, B, dtype):
    """Batched pass A (jnp + interpret) == per-lane single-lane oracle."""
    X, sqn, G, alpha, L, U, gammas, i_idx = _setup_batched(l, d, B, dtype)
    XQ = jnp.take(X, i_idx, axis=0)
    sqq = jnp.take(sqn, i_idx)
    a_i, L_i, U_i = _lane(alpha, i_idx), _lane(L, i_idx), _lane(U, i_idx)
    g_i = _lane(G, i_idx)
    use_exact = jnp.asarray([b % 2 == 0 for b in range(B)])
    js, gains = [], []
    for b in range(B):
        _, j, g = ref.rbf_row_wss(X, sqn, G[b], alpha[b], L[b], U[b], XQ[b],
                                  a_i[b], L_i[b], U_i[b], g_i[b], i_idx[b],
                                  use_exact[b], gammas[b])
        js.append(int(j))
        gains.append(float(g))
    tol = 1e-4 if dtype == jnp.float32 else 1e-11
    for impl in ("jnp", "interpret"):
        j_b, gain_b = ops.rbf_row_wss_batched(
            X, sqn, G, alpha, L, U, XQ, sqq, a_i, L_i, U_i, g_i, i_idx,
            use_exact, gammas, impl=impl, block_l=128)
        assert [int(x) for x in j_b] == js, impl
        np.testing.assert_allclose(np.asarray(gain_b), gains, rtol=tol)


@pytest.mark.parametrize("l,d,B", [(64, 2, 3), (513, 33, 9)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_pass_b_batched_matches_single_lane(l, d, B, dtype):
    """Batched pass B (jnp + interpret) == per-lane single-lane oracle,
    including a frozen (mu = 0) lane whose G must come back unchanged."""
    X, sqn, G, alpha, L, U, gammas, i_idx = _setup_batched(l, d, B, dtype,
                                                           seed=1)
    rng = np.random.default_rng(2)
    j_idx = jnp.asarray(rng.integers(0, l, B), jnp.int32)
    mu = jnp.asarray(rng.uniform(-0.5, 0.5, B), dtype).at[0].set(0.0)
    XQi = jnp.take(X, i_idx, axis=0)
    XQj = jnp.take(X, j_idx, axis=0)
    sqqi, sqqj = jnp.take(sqn, i_idx), jnp.take(sqn, j_idx)
    lanes = jnp.arange(B)
    alpha_new = jnp.clip(alpha.at[lanes, i_idx].add(mu)
                         .at[lanes, j_idx].add(-mu), L, U)
    refs = []
    for b in range(B):
        k_i = ref.rbf_row(X, sqn, XQi[b], gammas[b])
        refs.append(ref.rbf_update_wss(X, sqn, G[b], k_i, XQj[b], mu[b],
                                       alpha_new[b], L[b], U[b], gammas[b]))
    tol = 1e-4 if dtype == jnp.float32 else 1e-11
    for impl in ("jnp", "interpret"):
        Gn, i_n, gi_n, gdn = ops.rbf_update_wss_batched(
            X, sqn, G, alpha_new, L, U, XQi, sqqi, XQj, sqqj, mu, gammas,
            impl=impl, block_l=128)
        np.testing.assert_allclose(np.asarray(Gn),
                                   np.stack([np.asarray(r[0]) for r in refs]),
                                   rtol=tol, atol=tol)
        assert [int(x) for x in i_n] == [int(r[1]) for r in refs], impl
        np.testing.assert_allclose(np.asarray(gi_n),
                                   [float(r[2]) for r in refs], rtol=tol)
        np.testing.assert_allclose(np.asarray(gdn),
                                   [float(r[3]) for r in refs], rtol=tol)
        # the frozen lane: bitwise no-op on G
        np.testing.assert_array_equal(np.asarray(Gn[0]), np.asarray(G[0]))


@pytest.mark.parametrize("block_l", [128, 256, 512, 1024])
def test_pass_a_block_size_sweep(block_l):
    """Block shape must not change results (padding/tiling invariance)."""
    X, sqn, G, alpha, L, U, gamma = _setup(777, 13, jnp.float64, seed=4)
    i = 42
    args = (X, sqn, G, alpha, L, U, X[i], alpha[i], L[i], U[i], G[i],
            jnp.asarray(i, jnp.int32), jnp.asarray(False), gamma)
    k_ref, j_ref, gain_ref = ref.rbf_row_wss(*args)
    k, j, gain = ops.rbf_row_wss(*args, impl="interpret", block_l=block_l)
    np.testing.assert_allclose(np.asarray(k), np.asarray(k_ref), rtol=1e-12)
    assert int(j) == int(j_ref)
