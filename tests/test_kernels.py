"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(64, 2), (200, 7), (1024, 16), (1500, 60), (4096, 128)]
DTYPES = [jnp.float32, jnp.float64]


def _setup(l, d, dtype, seed=0):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(l, d)), dtype)
    y = jnp.asarray(np.sign(rng.normal(size=l)), dtype)
    C = 10.0
    alpha = jnp.asarray(rng.uniform(-1, 1, size=l), dtype) * jnp.abs(y) * C
    alpha = jnp.clip(alpha, jnp.minimum(0.0, y * C), jnp.maximum(0.0, y * C))
    G = jnp.asarray(rng.normal(size=l), dtype)
    L = jnp.minimum(0.0, y * C)
    U = jnp.maximum(0.0, y * C)
    sqn = jnp.sum(X * X, axis=-1)
    gamma = jnp.asarray(0.3, dtype)
    return X, sqn, G, alpha, L, U, gamma


@pytest.mark.parametrize("l,d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("use_exact", [False, True])
def test_pass_a_row_wss(l, d, dtype, use_exact):
    X, sqn, G, alpha, L, U, gamma = _setup(l, d, dtype)
    i = 3
    xq = X[i]
    a_i, L_i, U_i = alpha[i], L[i], U[i]
    g_i = G[i]
    args = (X, sqn, G, alpha, L, U, xq, a_i, L_i, U_i, g_i,
            jnp.asarray(i, jnp.int32), jnp.asarray(use_exact), gamma)
    k_ref, j_ref, gain_ref = ref.rbf_row_wss(*args)
    k_pl, j_pl, gain_pl = ops.rbf_row_wss(*args, impl="interpret",
                                          block_l=256)
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(k_pl), np.asarray(k_ref),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(float(gain_pl), float(gain_ref),
                               rtol=10 * tol)
    assert int(j_pl) == int(j_ref)


@pytest.mark.parametrize("l,d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_pass_b_update_wss(l, d, dtype):
    X, sqn, G, alpha, L, U, gamma = _setup(l, d, dtype, seed=1)
    i, j = 3, 11
    k_i = ref.rbf_row(X, sqn, X[i], gamma)
    mu = jnp.asarray(0.37, dtype)
    alpha_new = alpha.at[i].add(mu).at[j].add(-mu)
    alpha_new = jnp.clip(alpha_new, L, U)
    G_ref, i_ref, gi_ref, gdn_ref = ref.rbf_update_wss(
        X, sqn, G, k_i, X[j], mu, alpha_new, L, U, gamma)
    G_pl, i_pl, gi_pl, gdn_pl = ops.rbf_update_wss(
        X, sqn, G, k_i, alpha_new, L, U, X[j], mu, gamma,
        impl="interpret", block_l=256)
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(G_pl), np.asarray(G_ref),
                               rtol=tol, atol=tol)
    assert int(i_pl) == int(i_ref)
    np.testing.assert_allclose(float(gi_pl), float(gi_ref), rtol=10 * tol)
    np.testing.assert_allclose(float(gdn_pl), float(gdn_ref), rtol=10 * tol)


@pytest.mark.parametrize("l1,l2,d", [(64, 64, 2), (200, 100, 7),
                                     (300, 513, 33), (1024, 256, 128)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_gram_block(l1, l2, d, dtype):
    rng = np.random.default_rng(2)
    X1 = jnp.asarray(rng.normal(size=(l1, d)), dtype)
    X2 = jnp.asarray(rng.normal(size=(l2, d)), dtype)
    gamma = 0.4
    K_ref = ref.gram_cross(X1, X2, gamma)
    K_pl = ops.gram(X1, X2, gamma, impl="interpret", block_i=128,
                    block_j=128)
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(K_pl), np.asarray(K_ref),
                               rtol=tol, atol=tol)


def test_gram_symmetric_psd():
    rng = np.random.default_rng(3)
    X = jnp.asarray(rng.normal(size=(100, 5)))
    K = np.asarray(ops.gram(X, gamma=0.5, impl="interpret",
                            block_i=128, block_j=128))
    np.testing.assert_allclose(K, K.T, atol=1e-12)
    w = np.linalg.eigvalsh(K)
    assert w.min() > -1e-8
    np.testing.assert_allclose(np.diag(K), 1.0, atol=1e-12)


@pytest.mark.parametrize("block_l", [128, 256, 512, 1024])
def test_pass_a_block_size_sweep(block_l):
    """Block shape must not change results (padding/tiling invariance)."""
    X, sqn, G, alpha, L, U, gamma = _setup(777, 13, jnp.float64, seed=4)
    i = 42
    args = (X, sqn, G, alpha, L, U, X[i], alpha[i], L[i], U[i], G[i],
            jnp.asarray(i, jnp.int32), jnp.asarray(False), gamma)
    k_ref, j_ref, gain_ref = ref.rbf_row_wss(*args)
    k, j, gain = ops.rbf_row_wss(*args, impl="interpret", block_l=block_l)
    np.testing.assert_allclose(np.asarray(k), np.asarray(k_ref), rtol=1e-12)
    assert int(j) == int(j_ref)
