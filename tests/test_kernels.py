"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

# tier-1 runs the two small shapes; the large tiling/padding shapes ride
# the slow tier (nightly full run + REPRO_IMPL=interpret leg)
SHAPES = [(64, 2), (200, 7)] + [
    pytest.param(*s, marks=pytest.mark.slow)
    for s in [(1024, 16), (1500, 60), (2048, 128)]]
DTYPES = [jnp.float32, jnp.float64]


def _setup(l, d, dtype, seed=0):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(l, d)), dtype)
    y = jnp.asarray(np.sign(rng.normal(size=l)), dtype)
    C = 10.0
    alpha = jnp.asarray(rng.uniform(-1, 1, size=l), dtype) * jnp.abs(y) * C
    alpha = jnp.clip(alpha, jnp.minimum(0.0, y * C), jnp.maximum(0.0, y * C))
    G = jnp.asarray(rng.normal(size=l), dtype)
    L = jnp.minimum(0.0, y * C)
    U = jnp.maximum(0.0, y * C)
    sqn = jnp.sum(X * X, axis=-1)
    gamma = jnp.asarray(0.3, dtype)
    return X, sqn, G, alpha, L, U, gamma


@pytest.mark.parametrize("l,d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("use_exact", [False, True])
def test_pass_a_row_wss(l, d, dtype, use_exact):
    X, sqn, G, alpha, L, U, gamma = _setup(l, d, dtype)
    i = 3
    xq = X[i]
    a_i, L_i, U_i = alpha[i], L[i], U[i]
    g_i = G[i]
    args = (X, sqn, G, alpha, L, U, xq, a_i, L_i, U_i, g_i,
            jnp.asarray(i, jnp.int32), jnp.asarray(use_exact), gamma)
    k_ref, j_ref, gain_ref = ref.rbf_row_wss(*args)
    k_pl, j_pl, gain_pl = ops.rbf_row_wss(*args, impl="interpret",
                                          block_l=256)
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(k_pl), np.asarray(k_ref),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(float(gain_pl), float(gain_ref),
                               rtol=10 * tol)
    assert int(j_pl) == int(j_ref)


@pytest.mark.parametrize("l,d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_pass_b_update_wss(l, d, dtype):
    X, sqn, G, alpha, L, U, gamma = _setup(l, d, dtype, seed=1)
    i, j = 3, 11
    k_i = ref.rbf_row(X, sqn, X[i], gamma)
    mu = jnp.asarray(0.37, dtype)
    alpha_new = alpha.at[i].add(mu).at[j].add(-mu)
    alpha_new = jnp.clip(alpha_new, L, U)
    G_ref, i_ref, gi_ref, gdn_ref = ref.rbf_update_wss(
        X, sqn, G, k_i, X[j], mu, alpha_new, L, U, gamma)
    G_pl, i_pl, gi_pl, gdn_pl = ops.rbf_update_wss(
        X, sqn, G, k_i, alpha_new, L, U, X[j], mu, gamma,
        impl="interpret", block_l=256)
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(G_pl), np.asarray(G_ref),
                               rtol=tol, atol=tol)
    assert int(i_pl) == int(i_ref)
    np.testing.assert_allclose(float(gi_pl), float(gi_ref), rtol=10 * tol)
    np.testing.assert_allclose(float(gdn_pl), float(gdn_ref), rtol=10 * tol)


@pytest.mark.parametrize("l1,l2,d", [
    (64, 64, 2), (200, 100, 7),
    pytest.param(300, 513, 33, marks=pytest.mark.slow),
    pytest.param(1024, 256, 128, marks=pytest.mark.slow)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_gram_block(l1, l2, d, dtype):
    rng = np.random.default_rng(2)
    X1 = jnp.asarray(rng.normal(size=(l1, d)), dtype)
    X2 = jnp.asarray(rng.normal(size=(l2, d)), dtype)
    gamma = 0.4
    K_ref = ref.gram_cross(X1, X2, gamma)
    K_pl = ops.gram(X1, X2, gamma, impl="interpret", block_i=128,
                    block_j=128)
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(K_pl), np.asarray(K_ref),
                               rtol=tol, atol=tol)


def test_gram_symmetric_psd():
    rng = np.random.default_rng(3)
    X = jnp.asarray(rng.normal(size=(100, 5)))
    K = np.asarray(ops.gram(X, gamma=0.5, impl="interpret",
                            block_i=128, block_j=128))
    np.testing.assert_allclose(K, K.T, atol=1e-12)
    w = np.linalg.eigvalsh(K)
    assert w.min() > -1e-8
    np.testing.assert_allclose(np.diag(K), 1.0, atol=1e-12)


def _setup_batched(l, d, B, dtype, seed=0):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(l, d)), dtype)
    sqn = jnp.sum(X * X, axis=-1)
    ys = jnp.asarray(np.sign(rng.normal(size=(B, l))), dtype)
    C = 10.0
    L = jnp.minimum(0.0, ys * C)
    U = jnp.maximum(0.0, ys * C)
    alpha = jnp.clip(jnp.asarray(rng.uniform(-1, 1, (B, l)), dtype) * C, L, U)
    G = jnp.asarray(rng.normal(size=(B, l)), dtype)
    gammas = jnp.asarray(rng.uniform(0.2, 1.5, B), dtype)
    i_idx = jnp.asarray(rng.integers(0, l, B), jnp.int32)
    return X, sqn, G, alpha, L, U, gammas, i_idx


def _lane(M, idx):
    return jnp.take_along_axis(M, idx[:, None], axis=1)[:, 0]


@pytest.mark.parametrize("l,d,B", [
    (64, 2, 3), pytest.param(257, 33, 5, marks=pytest.mark.slow)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_pass_a_batched_matches_single_lane(l, d, B, dtype):
    """Batched pass A (jnp + interpret) == per-lane single-lane oracle."""
    X, sqn, G, alpha, L, U, gammas, i_idx = _setup_batched(l, d, B, dtype)
    XQ = jnp.take(X, i_idx, axis=0)
    sqq = jnp.take(sqn, i_idx)
    a_i, L_i, U_i = _lane(alpha, i_idx), _lane(L, i_idx), _lane(U, i_idx)
    g_i = _lane(G, i_idx)
    use_exact = jnp.asarray([b % 2 == 0 for b in range(B)])
    js, gains = [], []
    for b in range(B):
        _, j, g = ref.rbf_row_wss(X, sqn, G[b], alpha[b], L[b], U[b], XQ[b],
                                  a_i[b], L_i[b], U_i[b], g_i[b], i_idx[b],
                                  use_exact[b], gammas[b])
        js.append(int(j))
        gains.append(float(g))
    tol = 1e-4 if dtype == jnp.float32 else 1e-11
    for impl in ("jnp", "interpret"):
        j_b, gain_b = ops.rbf_row_wss_batched(
            X, sqn, G, alpha, L, U, XQ, sqq, a_i, L_i, U_i, g_i, i_idx,
            use_exact, gammas, impl=impl, block_l=128)
        assert [int(x) for x in j_b] == js, impl
        np.testing.assert_allclose(np.asarray(gain_b), gains, rtol=tol)


@pytest.mark.parametrize("l,d,B", [
    (64, 2, 3), pytest.param(257, 33, 5, marks=pytest.mark.slow)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_pass_b_batched_matches_single_lane(l, d, B, dtype):
    """Batched pass B (jnp + interpret) == per-lane single-lane oracle,
    including a frozen (mu = 0) lane whose G must come back unchanged."""
    X, sqn, G, alpha, L, U, gammas, i_idx = _setup_batched(l, d, B, dtype,
                                                           seed=1)
    rng = np.random.default_rng(2)
    j_idx = jnp.asarray(rng.integers(0, l, B), jnp.int32)
    mu = jnp.asarray(rng.uniform(-0.5, 0.5, B), dtype).at[0].set(0.0)
    XQi = jnp.take(X, i_idx, axis=0)
    XQj = jnp.take(X, j_idx, axis=0)
    sqqi, sqqj = jnp.take(sqn, i_idx), jnp.take(sqn, j_idx)
    lanes = jnp.arange(B)
    alpha_new = jnp.clip(alpha.at[lanes, i_idx].add(mu)
                         .at[lanes, j_idx].add(-mu), L, U)
    refs = []
    for b in range(B):
        k_i = ref.rbf_row(X, sqn, XQi[b], gammas[b])
        refs.append(ref.rbf_update_wss(X, sqn, G[b], k_i, XQj[b], mu[b],
                                       alpha_new[b], L[b], U[b], gammas[b]))
    tol = 1e-4 if dtype == jnp.float32 else 1e-11
    for impl in ("jnp", "interpret"):
        Gn, i_n, gi_n, gdn = ops.rbf_update_wss_batched(
            X, sqn, G, alpha_new, L, U, XQi, sqqi, XQj, sqqj, mu, gammas,
            impl=impl, block_l=128)
        np.testing.assert_allclose(np.asarray(Gn),
                                   np.stack([np.asarray(r[0]) for r in refs]),
                                   rtol=tol, atol=tol)
        assert [int(x) for x in i_n] == [int(r[1]) for r in refs], impl
        np.testing.assert_allclose(np.asarray(gi_n),
                                   [float(r[2]) for r in refs], rtol=tol)
        np.testing.assert_allclose(np.asarray(gdn),
                                   [float(r[3]) for r in refs], rtol=tol)
        # the frozen lane: bitwise no-op on G
        np.testing.assert_array_equal(np.asarray(Gn[0]), np.asarray(G[0]))


def _setup_doubled(l, d, B, dtype, seed=0):
    """Doubled ε-SVR lane state (n = 2l) over a base (l, d) X."""
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(l, d)), dtype)
    sqn = jnp.sum(X * X, axis=-1)
    C = 5.0
    zl = jnp.zeros((B, l), dtype)
    L = jnp.concatenate([zl, zl - C], axis=1)
    U = jnp.concatenate([zl + C, zl], axis=1)
    alpha = jnp.clip(jnp.asarray(rng.uniform(-1, 1, (B, 2 * l)), dtype), L, U)
    G = jnp.asarray(rng.normal(size=(B, 2 * l)), dtype)
    gammas = jnp.asarray(rng.uniform(0.2, 1.5, B), dtype)
    i_idx = jnp.asarray(rng.integers(0, 2 * l, B), jnp.int32)
    return X, sqn, G, alpha, L, U, gammas, i_idx


@pytest.mark.parametrize("l,d,B", [
    (64, 2, 3), pytest.param(300, 17, 5, marks=pytest.mark.slow)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_pass_a_doubled_in_kernel_matches_jnp_oracle(l, d, B, dtype):
    """Tentpole parity: the in-kernel doubled row mode (interpret) — base
    row tile computed once, read per half — equals the jnp oracle that
    tiles the base (B, l) row, including half-1 working-set indices."""
    X, sqn, G, alpha, L, U, gammas, i_idx = _setup_doubled(l, d, B, dtype)
    bi = i_idx % l
    XQ, sqq = jnp.take(X, bi, axis=0), jnp.take(sqn, bi)
    a_i, L_i, U_i = _lane(alpha, i_idx), _lane(L, i_idx), _lane(U, i_idx)
    g_i = _lane(G, i_idx)
    use_exact = jnp.asarray([b % 2 == 0 for b in range(B)])
    args = (X, sqn, G, alpha, L, U, XQ, sqq, a_i, L_i, U_i, g_i, i_idx,
            use_exact, gammas)
    j_ref, gain_ref = ops.rbf_row_wss_batched(*args, impl="jnp", dup=True)
    j_pl, gain_pl = ops.rbf_row_wss_batched(*args, impl="interpret",
                                            block_l=128, dup=True)
    tol = 1e-4 if dtype == jnp.float32 else 1e-11
    assert [int(x) for x in j_pl] == [int(x) for x in j_ref]
    np.testing.assert_allclose(np.asarray(gain_pl), np.asarray(gain_ref),
                               rtol=tol)
    # at least one lane must have selected a half-1 coordinate for the
    # half-offset index arithmetic to be exercised
    assert any(int(x) >= l for x in j_ref) or any(int(x) >= l for x in i_idx)


@pytest.mark.parametrize("l,d,B", [
    (64, 2, 3), pytest.param(300, 17, 5, marks=pytest.mark.slow)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_pass_b_doubled_in_kernel_matches_jnp_oracle(l, d, B, dtype):
    """Tentpole parity for pass B in doubled mode, incl. the bitwise
    mu = 0 lane freeze across BOTH state halves."""
    X, sqn, G, alpha, L, U, gammas, i_idx = _setup_doubled(l, d, B, dtype,
                                                           seed=1)
    rng = np.random.default_rng(2)
    j_idx = jnp.asarray(rng.integers(0, 2 * l, B), jnp.int32)
    mu = jnp.asarray(rng.uniform(-0.4, 0.4, B), dtype).at[0].set(0.0)
    lanes = jnp.arange(B)
    alpha_new = jnp.clip(alpha.at[lanes, i_idx].add(mu)
                         .at[lanes, j_idx].add(-mu), L, U)
    bi, bj = i_idx % l, j_idx % l
    args = (X, sqn, G, alpha_new, L, U,
            jnp.take(X, bi, axis=0), jnp.take(sqn, bi),
            jnp.take(X, bj, axis=0), jnp.take(sqn, bj), mu, gammas)
    ref_out = ops.rbf_update_wss_batched(*args, impl="jnp", dup=True)
    pl_out = ops.rbf_update_wss_batched(*args, impl="interpret",
                                        block_l=128, dup=True)
    tol = 1e-4 if dtype == jnp.float32 else 1e-11
    np.testing.assert_allclose(np.asarray(pl_out[0]), np.asarray(ref_out[0]),
                               rtol=tol, atol=tol)
    assert [int(x) for x in pl_out[1]] == [int(x) for x in ref_out[1]]
    np.testing.assert_allclose(np.asarray(pl_out[2]), np.asarray(ref_out[2]),
                               rtol=tol)
    np.testing.assert_allclose(np.asarray(pl_out[3]), np.asarray(ref_out[3]),
                               rtol=tol)
    np.testing.assert_array_equal(np.asarray(pl_out[0][0]), np.asarray(G[0]))


@pytest.mark.parametrize("dup", [False, True])
def test_rows_source_kernels_match_jnp(dup):
    """Gram-bank row source: the rows-variant Pallas kernels (interpret)
    equal the jnp from-rows oracle, plain and doubled."""
    l, d, B = 72, 4, 3
    dtype = jnp.float64
    if dup:
        X, sqn, G, alpha, L, U, gammas, i_idx = _setup_doubled(
            l, d, B, dtype, seed=3)
    else:
        X, sqn, G, alpha, L, U, gammas, i_idx = _setup_batched(
            l, d, B, dtype, seed=3)
    bank = jnp.stack([ref.gram(X, g) for g in np.asarray(gammas)])
    gidx = jnp.arange(B, dtype=jnp.int32)
    bi = i_idx % l if dup else i_idx
    KR = bank[gidx, bi]
    a_i, L_i, U_i = _lane(alpha, i_idx), _lane(L, i_idx), _lane(U, i_idx)
    g_i = _lane(G, i_idx)
    use_exact = jnp.asarray([True, False, True])
    aargs = (KR, G, alpha, L, U, a_i, L_i, U_i, g_i, i_idx, use_exact)
    j_ref, gain_ref = ops.row_wss_batched_rows(*aargs, impl="jnp", dup=dup)
    j_pl, gain_pl = ops.row_wss_batched_rows(*aargs, impl="interpret",
                                             block_l=128, dup=dup)
    assert [int(x) for x in j_pl] == [int(x) for x in j_ref]
    np.testing.assert_allclose(np.asarray(gain_pl), np.asarray(gain_ref),
                               rtol=1e-11)
    rng = np.random.default_rng(4)
    n = G.shape[1]
    j_idx = jnp.asarray(rng.integers(0, n, B), jnp.int32)
    mu = jnp.asarray(rng.uniform(-0.3, 0.3, B)).at[1].set(0.0)
    lanes = jnp.arange(B)
    alpha_new = jnp.clip(alpha.at[lanes, i_idx].add(mu)
                         .at[lanes, j_idx].add(-mu), L, U)
    KRj = bank[gidx, j_idx % l if dup else j_idx]
    bargs = (KR, KRj, G, alpha_new, L, U, mu)
    r_ref = ops.update_wss_batched_rows(*bargs, impl="jnp", dup=dup)
    r_pl = ops.update_wss_batched_rows(*bargs, impl="interpret",
                                       block_l=128, dup=dup)
    for a, b in zip(r_pl, r_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-11, atol=1e-12)
    np.testing.assert_array_equal(np.asarray(r_pl[0][1]), np.asarray(G[1]))


def test_index_channel_is_exact_beyond_float32_significand():
    """Satellite regression: working-set indices travel through the int32
    side channel, never the data dtype — a float32 round-trip corrupts
    indices beyond 2^24 (the old scal packing did exactly that)."""
    big = 2 ** 24 + 1
    # the failure mode being guarded against:
    assert int(jnp.asarray(big, jnp.float32).astype(jnp.int32)) != big
    # the int channel is exact:
    np.testing.assert_array_equal(np.asarray(ops._iscal([big], 1)), [[big]])
    assert ops._iscal([big], 1).dtype == jnp.int32
    # behavioral: f32 data with the i-exclusion still selects exactly —
    # index 5 WOULD win the gain argmax (k(x_i, x_i) = 1 makes its q
    # collapse to TAU and l_vec = 50 > 0 there) if the != i_idx mask ever
    # mis-compared, so the selection below is decided by the int channel
    X, sqn, G, alpha, L, U, gamma = _setup(130, 3, jnp.float32, seed=7)
    alpha = alpha.at[5].set(0.5 * (L[5] + U[5]))   # strictly inside the box
    g_i = G[5] + 50.0
    args = (X, sqn, G, alpha, L, U, X[5], alpha[5], L[5], U[5], g_i,
            jnp.asarray(5, jnp.int32), jnp.asarray(False), gamma)
    _, j_ref, _ = ref.rbf_row_wss(*args)
    _, j_pl, _ = ops.rbf_row_wss(*args, impl="interpret", block_l=128)
    assert int(j_pl) == int(j_ref) != 5


@pytest.mark.parametrize("block_l", [
    128, 256, pytest.param(512, marks=pytest.mark.slow),
    pytest.param(1024, marks=pytest.mark.slow)])
def test_pass_a_block_size_sweep(block_l):
    """Block shape must not change results (padding/tiling invariance)."""
    X, sqn, G, alpha, L, U, gamma = _setup(389, 13, jnp.float64, seed=4)
    i = 42
    args = (X, sqn, G, alpha, L, U, X[i], alpha[i], L[i], U[i], G[i],
            jnp.asarray(i, jnp.int32), jnp.asarray(False), gamma)
    k_ref, j_ref, gain_ref = ref.rbf_row_wss(*args)
    k, j, gain = ops.rbf_row_wss(*args, impl="interpret", block_l=block_l)
    np.testing.assert_allclose(np.asarray(k), np.asarray(k_ref), rtol=1e-12)
    assert int(j) == int(j_ref)
