"""Hypothesis property tests on the solver's invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional 'hypothesis' dep")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import qp as qp_mod
from repro.core import reference as ref
from repro.core import step as step_mod
from repro.core.solver import SolverConfig, solve

SETTINGS = dict(max_examples=25, deadline=None)


def _psd_problem(seed, n, C):
    rng = np.random.default_rng(seed)
    d = rng.integers(2, 6)
    X = rng.normal(size=(n, d))
    gamma = float(10 ** rng.uniform(-1.5, 0.5))
    sq = np.sum(X * X, 1)
    K = np.exp(-gamma * (sq[:, None] + sq[None, :] - 2 * X @ X.T))
    y = np.sign(rng.normal(size=n))
    if np.all(y == y[0]):
        y[0] = -y[0]
    return K, y


@given(seed=st.integers(0, 10_000), n=st.integers(8, 48),
       logC=st.floats(-1, 4),
       alg=st.sampled_from(["smo", "pasmo", "pasmo_simple", "overshoot"]))
@settings(**SETTINGS)
def test_final_point_feasible_and_converged(seed, n, logC, alg):
    """Every solve ends feasible; if converged, the KKT gap is <= eps."""
    C = float(10 ** logC)
    K, y = _psd_problem(seed, n, C)
    cfg = SolverConfig(algorithm=alg, eps=1e-4, max_iter=100_000)
    res = solve(qp_mod.PrecomputedKernel(jnp.asarray(K)), jnp.asarray(y), C,
                cfg)
    bounds = qp_mod.make_bounds(jnp.asarray(y), C)
    assert bool(qp_mod.is_feasible(res.alpha, bounds, atol=1e-7))
    assert bool(res.converged)
    assert float(res.kkt_gap) <= 1e-4 + 1e-12


@pytest.mark.slow
@given(seed=st.integers(0, 10_000), n=st.integers(8, 40), logC=st.floats(-1, 3))
@settings(**SETTINGS)
def test_pasmo_reaches_smo_objective(seed, n, logC):
    """PA-SMO's solution is never worse than SMO's at the same eps
    (the paper's §7.1 claim, here as an invariant up to eps-scale slack)."""
    C = float(10 ** logC)
    K, y = _psd_problem(seed, n, C)
    kern = qp_mod.PrecomputedKernel(jnp.asarray(K))
    r_smo = solve(kern, jnp.asarray(y), C,
                  SolverConfig(algorithm="smo", eps=1e-5, max_iter=100_000))
    r_pa = solve(kern, jnp.asarray(y), C,
                 SolverConfig(algorithm="pasmo", eps=1e-5, max_iter=100_000))
    f_s, f_p = float(r_smo.objective), float(r_pa.objective)
    assert f_p >= f_s - 1e-4 * (1.0 + abs(f_s))


@pytest.mark.slow
@given(seed=st.integers(0, 10_000), n=st.integers(8, 32), logC=st.floats(-1, 3))
@settings(**SETTINGS)
def test_double_step_monotonicity(seed, n, logC):
    """Lemma 3 invariant: f never decreases across two consecutive steps
    (single steps may decrease f during planning)."""
    C = float(10 ** logC)
    K, y = _psd_problem(seed, n, C)
    r = ref.solve_pasmo(K, y, C, eps=1e-5, max_iter=20_000, tie="first",
                        record_steps=True)
    alpha = np.zeros(n)
    f_hist = [0.0]
    planned = []
    for (i, j, mu, pl) in r.steps:
        alpha[i] += mu
        alpha[j] -= mu
        planned.append(pl)
        f_hist.append(float(y @ alpha - 0.5 * alpha @ K @ alpha))
    for k, pl in enumerate(planned):
        slack = 1e-9 * (1 + abs(f_hist[k]))
        if pl:
            # Lemma 3: planning step at k + following step recover the dip
            if k + 2 < len(f_hist):
                assert f_hist[k + 2] >= f_hist[k] - slack
        else:
            # plain SMO steps never decrease f
            assert f_hist[k + 1] >= f_hist[k] - slack


@given(w1=st.floats(-10, 10), w2=st.floats(-10, 10),
       a=st.floats(0.1, 10), b=st.floats(0.1, 10), rho=st.floats(-0.95, 0.95))
@settings(max_examples=200, deadline=None)
def test_planning_step_dominates_newton_two_step(w1, w2, a, b, rho):
    """The planned double-step gain (eq. 7 at eq. 8) >= the gain of the
    greedy Newton pair — planning-ahead can only help (§4)."""
    Q11, Q22 = a, b
    Q12 = rho * np.sqrt(a * b)
    t = step_mod.PlanningTerms(w1=jnp.float64(w1), w2=jnp.float64(w2),
                               Q11=jnp.float64(Q11), Q22=jnp.float64(Q22),
                               Q12=jnp.float64(Q12))
    mu_opt, ok = step_mod.planning_step(t)
    assert bool(ok)
    g_plan = float(step_mod.double_step_gain(mu_opt, t))
    g_greedy = float(step_mod.double_step_gain(w1 / Q11, t))
    assert g_plan >= g_greedy - 1e-9 * max(1.0, abs(g_plan))


@given(seed=st.integers(0, 10_000), n=st.integers(8, 32))
@settings(**SETTINGS)
def test_gradient_consistency(seed, n):
    """Maintained gradient == y - K alpha at exit (no drift)."""
    K, y = _psd_problem(seed, n, 10.0)
    res = solve(qp_mod.PrecomputedKernel(jnp.asarray(K)), jnp.asarray(y),
                10.0, SolverConfig(algorithm="pasmo", eps=1e-4,
                                   max_iter=100_000))
    np.testing.assert_allclose(np.asarray(res.G),
                               y - K @ np.asarray(res.alpha),
                               rtol=1e-7, atol=1e-7)


@given(seed=st.integers(0, 1000), n=st.integers(12, 32),
       k=st.integers(2, 64))
@settings(**SETTINGS)
def test_objective_from_gradient_identity(seed, n, k):
    """f(a) = 1/2 (y.a + G.a) identity used by the solver finalizer."""
    rng = np.random.default_rng(seed)
    K, y = _psd_problem(seed, n, 1.0)
    alpha = rng.normal(size=n)
    G = y - K @ alpha
    f_direct = y @ alpha - 0.5 * alpha @ K @ alpha
    f_id = 0.5 * (y @ alpha + G @ alpha)
    np.testing.assert_allclose(f_direct, f_id, rtol=1e-9)
