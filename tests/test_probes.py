"""SVM probe on LM features: end-to-end integration of the paper's solver
with the model zoo."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.solver import SolverConfig
from repro.models import registry
from repro.svm.probes import (extract_features, predict_probe, train_probe)


def test_probe_separates_synthetic_classes():
    """Features with class structure -> the PA-SMO-trained probe must fit
    the training set (and a held-out split) well."""
    rng = np.random.default_rng(0)
    n, d, k = 66, 16, 3
    labels = rng.integers(0, k, size=n)
    centers = rng.normal(size=(k, d)) * 3.0
    feats = centers[labels] + rng.normal(size=(n, d))
    tr, te = slice(0, 48), slice(48, None)
    probe = train_probe(jnp.asarray(feats[tr]), jnp.asarray(labels[tr]), k,
                        C=10.0)
    pred_tr = np.asarray(predict_probe(probe, jnp.asarray(feats[tr])))
    pred_te = np.asarray(predict_probe(probe, jnp.asarray(feats[te])))
    assert (pred_tr == labels[tr]).mean() >= 0.95
    assert (pred_te == labels[te]).mean() >= 0.85


@pytest.mark.parametrize("arch", [
    "qwen2-0.5b",
    pytest.param("mamba2-370m", marks=pytest.mark.slow),
    pytest.param("internvl2-1b", marks=pytest.mark.slow)])
def test_feature_extraction_shapes(arch):
    cfg = get_smoke(arch)
    params = registry.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    batch = registry.demo_batch(cfg, batch=4, seq=16)
    feats = extract_features(params, cfg, batch)
    assert feats.shape == (4, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(feats)))


@pytest.mark.slow
def test_probe_on_lm_features_end_to_end():
    """Full pipeline: model features -> batched PA-SMO heads -> predict."""
    cfg = get_smoke("qwen2-0.5b")
    params = registry.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    rng = np.random.default_rng(1)
    # two synthetic "tasks": sequences of low vs high token ids
    lo = rng.integers(0, cfg.vocab // 4, size=(16, 24))
    hi = rng.integers(3 * cfg.vocab // 4, cfg.vocab, size=(16, 24))
    tokens = np.concatenate([lo, hi]).astype(np.int32)
    labels = np.array([0] * 16 + [1] * 16)
    feats = extract_features(params, cfg, {"tokens": jnp.asarray(tokens)})
    probe = train_probe(feats, jnp.asarray(labels), 2, C=10.0,
                        cfg=SolverConfig(algorithm="pasmo", eps=1e-3))
    pred = np.asarray(predict_probe(probe, feats))
    assert (pred == labels).mean() >= 0.9
    assert int(jnp.max(probe.iterations)) > 0
