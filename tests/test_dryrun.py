"""Dry-run machinery on a small virtual-device mesh (subprocess so the
rest of the suite keeps its single device), plus HLO analyzer unit tests."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze


class TestHloAnalyzer:
    def test_matmul_flops_exact(self):
        f = jax.jit(lambda a, b: a @ b)
        comp = f.lower(jax.ShapeDtypeStruct((128, 256), jnp.float32),
                       jax.ShapeDtypeStruct((256, 64), jnp.float32)
                       ).compile()
        c = analyze(comp.as_text())
        assert c.flops == 2 * 128 * 256 * 64

    def test_scan_trip_count_multiplies(self):
        def scanned(a, ws):
            def body(x, w):
                return x @ w, None
            y, _ = jax.lax.scan(body, a, ws)
            return y

        flops = {}
        for L in (4, 8):
            comp = jax.jit(scanned).lower(
                jax.ShapeDtypeStruct((64, 64), jnp.float32),
                jax.ShapeDtypeStruct((L, 64, 64), jnp.float32)).compile()
            c = analyze(comp.as_text())
            flops[L] = c.flops
            assert c.flops == L * 2 * 64 ** 3
            assert (dict(c.loops).popitem()[1]) == L
        assert flops[8] == 2 * flops[4]

    def test_scan_param_slice_not_counted_full(self):
        """The per-iteration dynamic-slice of scanned weights must count
        ~slice bytes, not the full stacked array."""
        L, D = 64, 128

        def scanned(a, ws):
            def body(x, w):
                return x @ w, None
            y, _ = jax.lax.scan(body, a, ws)
            return y

        comp = jax.jit(scanned).lower(
            jax.ShapeDtypeStruct((D, D), jnp.float32),
            jax.ShapeDtypeStruct((L, D, D), jnp.float32)).compile()
        c = analyze(comp.as_text())
        # if the full (L, D, D) were charged per iteration, bytes would be
        # >= L^2 * D^2 * 4 = 64x the actual weights traffic
        full_per_iter = L * (L * D * D * 4)
        assert c.bytes < 0.25 * full_per_iter
        # but at least the weights are read once each + activations
        assert c.bytes >= L * D * D * 4

    def test_nested_scan_multiplies(self):
        def inner(x, ws):
            def body(c, w):
                return c @ w, None
            return jax.lax.scan(body, x, ws)[0]

        def outer(x, ws):
            def body(c, _):
                return inner(c, ws), None
            return jax.lax.scan(body, x, None, length=3)[0]

        comp = jax.jit(outer).lower(
            jax.ShapeDtypeStruct((32, 32), jnp.float32),
            jax.ShapeDtypeStruct((5, 32, 32), jnp.float32)).compile()
        c = analyze(comp.as_text())
        assert c.flops == 3 * 5 * 2 * 32 ** 3


_CELLS = [
    pytest.param("qwen2-0.5b", "train_4k",  # dense (heaviest: slow tier)
                 marks=pytest.mark.slow),
    ("mixtral-8x7b", "long_500k"),     # moe + SWA ring cache
    ("mamba2-370m", "decode_32k"),     # ssm state decode
    ("whisper-tiny", "prefill_32k"),   # enc-dec
]


@pytest.mark.parametrize("arch,shape", _CELLS)
def test_dryrun_cell_small_mesh(arch, shape, tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env["REPRO_DRYRUN_DEVICES"] = "8"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--mesh", "2x2x2",
         "--arch", arch, "--shape", shape, "--out", str(tmp_path)],
        capture_output=True, text=True, env=env, timeout=560)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    rec = json.load(open(
        tmp_path / f"2x2x2__{arch}__{shape}.json"))
    assert rec["ok"], rec.get("error")
    assert not rec.get("skipped")
    assert rec["hlo_flops"] > 0
    assert rec["roofline"]["roofline_fraction"] <= 1.0
