"""Conjugate-SMO (``SolverConfig.step == "conjugate"``) differential tests.

The conjugate mode replaces the planning-ahead 2x2 *lookahead* with the
Conjugate-SMO 2-direction *solve* (current WSS direction + the carried
previous direction), falling back to plain clipped SMO whenever the
carried direction is invalid.  The contract under test:

* same optimum as SMO / PA-SMO (objective parity at eps scale),
* strictly fewer iterations than PA-SMO on the chess-board problem,
* the accept/reject machinery is bitwise-transparent on frozen lanes and
  composes with soft shrinking and warm-start resumes,
* with ``step="plain"`` nothing changes — the conjugate goldens pin the
  conjugate trace itself (recipe owned by ``tests/golden/regen.py``,
  captured hermetically per golden in a fresh process).
"""

import os
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import FUSED_KW, run_multidevice
from repro.analysis import jaxpr_audit
from repro.core import grid as grid_mod
from repro.core import qp as qp_mod
from repro.core.solver import SolverConfig, solve
from repro.core.solver_fused import (solve_fused, solve_fused_batched,
                                     solve_fused_batched_qp)
from repro.svm.data import chessboard, gaussian_blobs


SMO = dict(algorithm="smo")
PASMO = dict(algorithm="pasmo")
CONJ = dict(algorithm="smo", step="conjugate")


def _chessboard_problem(n=240, seed=0):
    X, y = chessboard(n, seed=seed)
    return jnp.asarray(X), jnp.asarray(y), 1000.0, 0.5


# ---------------------------------------------------------------------------
# config plumbing
# ---------------------------------------------------------------------------

def test_conjugate_requires_plain_smo_base():
    with pytest.raises(AssertionError, match="algorithm='smo'"):
        SolverConfig(algorithm="pasmo", step="conjugate")
    with pytest.raises(AssertionError):
        SolverConfig(step="newton")


def test_single_lane_fused_rejects_conjugate():
    X, y, C, gamma = _chessboard_problem(n=32)
    cfg = SolverConfig(eps=1e-3, max_iter=100, **CONJ)
    with pytest.raises(AssertionError, match="lane-batched"):
        solve_fused(X, y, C, gamma, cfg, impl="jnp")


# ---------------------------------------------------------------------------
# classic engine: the differential claim (mirrors test_differential.py)
# ---------------------------------------------------------------------------

def test_classic_conjugate_fewer_iterations_than_pasmo_on_chessboard():
    """Conjugate directions beat the planning lookahead on the paper's
    hard problem: strictly fewer iterations than PA-SMO (which itself
    beats plain SMO), at the same optimum."""
    X, y, C, gamma = _chessboard_problem()
    kern = qp_mod.make_rbf(X, gamma)
    cfg = dict(eps=1e-3, max_iter=500_000)
    r_pa = solve(kern, y, C, SolverConfig(**PASMO, **cfg))
    r_cj = solve(kern, y, C, SolverConfig(**CONJ, **cfg))
    assert bool(r_pa.converged) and bool(r_cj.converged)
    assert int(r_cj.iterations) < int(r_pa.iterations)
    # the 2-direction step must actually engage, and often
    assert int(r_cj.n_planning) > int(r_cj.iterations) // 4
    f_pa, f_cj = float(r_pa.objective), float(r_cj.objective)
    assert abs(f_cj - f_pa) <= 1e-6 * (1.0 + abs(f_pa))


# ---------------------------------------------------------------------------
# fused engine: parity + iteration win (jnp in tier 1, interpret in the
# nightly leg via FUSED_KW)
# ---------------------------------------------------------------------------

def test_fused_conjugate_fewer_iterations_than_pasmo_on_chessboard():
    X, y, C, gamma = _chessboard_problem()
    cfg = dict(eps=1e-3, max_iter=500_000)
    r_pa = solve_fused_batched(X, y[None], C, gamma,
                               SolverConfig(**PASMO, **cfg), **FUSED_KW)
    r_cj = solve_fused_batched(X, y[None], C, gamma,
                               SolverConfig(**CONJ, **cfg), **FUSED_KW)
    assert bool(r_pa.converged[0]) and bool(r_cj.converged[0])
    assert int(r_cj.iterations[0]) < int(r_pa.iterations[0])
    assert int(r_cj.n_planning[0]) > 0
    f_pa, f_cj = float(r_pa.objective[0]), float(r_cj.objective[0])
    assert abs(f_cj - f_pa) <= 1e-6 * (1.0 + abs(f_pa))


@pytest.mark.parametrize("data", ["chessboard", "blobs"])
def test_fused_conjugate_grid_objective_parity(data):
    """Conjugate vs PA-SMO on a small (C, gamma) grid: every grid point
    reaches the same dual optimum to 1e-6 relative."""
    if data == "chessboard":
        Xn, y = chessboard(160, seed=0)
        Cs, gammas = np.array([1.0, 10.0]), np.array([0.5, 1.0])
    else:
        Xn, y = gaussian_blobs(120, seed=0)
        Cs, gammas = np.array([0.5, 2.0]), np.array([0.05, 0.2])
    X = jnp.asarray(Xn)
    Y = jnp.asarray(y)[None, :]
    cfg = dict(eps=1e-4, max_iter=200_000)
    r_pa = grid_mod.solve_grid(X, Y, Cs, gammas,
                               SolverConfig(**PASMO, **cfg), **FUSED_KW)
    r_cj = grid_mod.solve_grid(X, Y, Cs, gammas,
                               SolverConfig(**CONJ, **cfg), **FUSED_KW)
    assert bool(np.all(np.asarray(r_pa.converged)))
    assert bool(np.all(np.asarray(r_cj.converged)))
    f_pa = np.asarray(r_pa.objective)
    f_cj = np.asarray(r_cj.objective)
    np.testing.assert_array_less(np.abs(f_cj - f_pa),
                                 1e-6 * (1.0 + np.abs(f_pa)))


# ---------------------------------------------------------------------------
# lane freeze / warm starts / shrinking
# ---------------------------------------------------------------------------

def test_conjugate_lane_freeze_is_bitwise():
    """A lane that converges early must be bitwise frozen while the
    straggler lane keeps iterating: rejected-or-frozen lanes take
    mu = mu2 = 0, so pass B is a no-op on their state."""
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(48, 3)))
    y = jnp.asarray(np.where(rng.normal(size=48) >= 0, 1.0, -1.0))
    Y = jnp.stack([y, -y])
    C = jnp.asarray([0.1, 50.0])         # lane 0 converges far earlier
    cfg = SolverConfig(eps=1e-4, max_iter=5_000, **CONJ)
    res = solve_fused_batched(X, Y, C, 0.8, cfg, **FUSED_KW)
    assert bool(np.all(np.asarray(res.converged)))
    it = np.asarray(res.iterations)
    assert it[0] < it[1]
    # rerun with a budget that stops between the two lanes' freeze points:
    # lane 0's state must already be bitwise final
    cfg_cut = SolverConfig(eps=1e-4, max_iter=int(it[0]) + 1, **CONJ)
    cut = solve_fused_batched(X, Y, C, 0.8, cfg_cut, **FUSED_KW)
    assert bool(cut.converged[0]) and not bool(cut.converged[1])
    np.testing.assert_array_equal(np.asarray(cut.alpha[0]),
                                  np.asarray(res.alpha[0]))
    np.testing.assert_array_equal(np.asarray(cut.G[0]),
                                  np.asarray(res.G[0]))
    assert float(cut.b[0]) == float(res.b[0])


def test_conjugate_warm_start_resume_parity():
    """Stopping mid-run and resuming from (alpha, G) — the chunked-driver
    seam; the conjugate direction history resets at the boundary — lands
    on the same optimum as the uninterrupted solve."""
    X, y, C, gamma = _chessboard_problem(n=160)
    cfg_kw = dict(eps=1e-3, **CONJ)
    full = solve_fused_batched(X, y[None], C, gamma,
                               SolverConfig(max_iter=500_000, **cfg_kw),
                               **FUSED_KW)
    assert bool(full.converged[0])
    part = solve_fused_batched(X, y[None], C, gamma,
                               SolverConfig(max_iter=500, **cfg_kw),
                               **FUSED_KW)
    assert not bool(part.converged[0])
    resumed = solve_fused_batched(X, y[None], C, gamma,
                                  SolverConfig(max_iter=500_000, **cfg_kw),
                                  alpha0=part.alpha, G0=part.G, **FUSED_KW)
    assert bool(resumed.converged[0])
    f_full = float(full.objective[0])
    f_res = float(resumed.objective[0])
    assert abs(f_res - f_full) <= 1e-6 * (1.0 + abs(f_full))
    # the chunked grid driver exercises the same resume seam in-loop
    comp = grid_mod.solve_grid_compacted(
        X, y[None], np.array([C]), np.array([gamma]),
        SolverConfig(max_iter=500_000, **cfg_kw), chunk=700, **FUSED_KW)
    assert bool(comp.converged[0, 0, 0])
    f_c = float(comp.objective[0, 0, 0])
    assert abs(f_c - f_full) <= 1e-6 * (1.0 + abs(f_full))


def test_conjugate_composes_with_shrinking():
    """Soft shrinking + conjugate: the direction resets on mask refreshes
    and unshrink events, and the optimum matches the unshrunk run."""
    X, y, C, gamma = _chessboard_problem(n=200)
    cfg = SolverConfig(eps=1e-3, max_iter=500_000, **CONJ)
    base = solve_fused_batched(X, y[None], C, gamma, cfg, **FUSED_KW)
    shr = solve_fused_batched(X, y[None], C, gamma, cfg, shrinking=True,
                              **FUSED_KW)
    assert bool(base.converged[0]) and bool(shr.converged[0])
    assert int(shr.n_planning[0]) > 0
    f_b, f_s = float(base.objective[0]), float(shr.objective[0])
    assert abs(f_s - f_b) <= 1e-6 * (1.0 + abs(f_b))


# ---------------------------------------------------------------------------
# doubled (ε-SVR) lanes + facades
# ---------------------------------------------------------------------------

def test_conjugate_doubled_svr_lane_parity():
    rng = np.random.default_rng(1)
    X = jnp.asarray(rng.normal(size=(60, 2)))
    y = jnp.sin(X[:, 0]) + 0.1 * jnp.asarray(rng.normal(size=60))
    qp = qp_mod.svr_qp(y, 2.0, 0.05)
    cfg = dict(eps=1e-4, max_iter=100_000)
    kw = dict(doubled=True, **FUSED_KW)
    r_pa = solve_fused_batched_qp(
        X, qp.p[None], qp.bounds.lower[None], qp.bounds.upper[None], 0.7,
        SolverConfig(**PASMO, **cfg), **kw)
    r_cj = solve_fused_batched_qp(
        X, qp.p[None], qp.bounds.lower[None], qp.bounds.upper[None], 0.7,
        SolverConfig(**CONJ, **cfg), **kw)
    assert bool(r_pa.converged[0]) and bool(r_cj.converged[0])
    assert int(r_cj.n_planning[0]) > 0
    f_pa, f_cj = float(r_pa.objective[0]), float(r_cj.objective[0])
    assert abs(f_cj - f_pa) <= 1e-6 * (1.0 + abs(f_pa))


def test_facades_thread_the_step_knob():
    from repro.svm import SVC, SVR, OneClassSVM
    from repro.telemetry import Diagnostics, RingConfig
    rng = np.random.default_rng(2)
    X = rng.normal(size=(50, 3))
    y = (X[:, 0] + X[:, 1] > 0).astype(int)
    d = Diagnostics(ring=RingConfig(sample_every=8))
    clf = SVC(C=2.0, gamma=0.7, algorithm="smo", step="conjugate",
              impl=FUSED_KW["impl"], diagnostics=d).fit(X, y)
    ref = SVC(C=2.0, gamma=0.7, algorithm="smo",
              impl=FUSED_KW["impl"]).fit(X, y)
    assert clf.score(X, y) == ref.score(X, y)
    f_c = float(clf.fit_result_.objective)
    f_r = float(ref.fit_result_.objective)
    assert abs(f_c - f_r) <= 1e-6 * (1.0 + abs(f_r))
    # the accepted-conjugate-step share rides the PR-8 lane-event seam
    assert len(d.lanes) == 1
    rec = d.lanes[0]
    assert rec["n_planning"] == int(clf.fit_result_.n_planning)
    assert rec["accepted_step_share"] == pytest.approx(
        rec["n_planning"] / rec["iterations"])

    yr = np.sin(X[:, 0])
    reg = SVR(C=2.0, epsilon=0.1, gamma=0.7, algorithm="smo",
              step="conjugate", impl=FUSED_KW["impl"]).fit(X, yr)
    reg_ref = SVR(C=2.0, epsilon=0.1, gamma=0.7, algorithm="smo",
                  impl=FUSED_KW["impl"]).fit(X, yr)
    f_g = float(reg.fit_result_.objective)
    f_gr = float(reg_ref.fit_result_.objective)
    assert abs(f_g - f_gr) <= 1e-6 * (1.0 + abs(f_gr))
    np.testing.assert_allclose(np.asarray(reg.predict(X)),
                               np.asarray(reg_ref.predict(X)),
                               atol=5e-3)  # eps=1e-3 stopping slack

    oc = OneClassSVM(nu=0.3, gamma=0.7, algorithm="smo", step="conjugate",
                     impl=FUSED_KW["impl"]).fit(X)
    oc_ref = OneClassSVM(nu=0.3, gamma=0.7, algorithm="smo",
                         impl=FUSED_KW["impl"]).fit(X)
    f_o = float(oc.fit_result_.objective)
    f_or = float(oc_ref.fit_result_.objective)
    assert abs(f_o - f_or) <= 1e-6 * (1.0 + abs(f_or))


# ---------------------------------------------------------------------------
# trace stability: conjugate goldens (recipe owned by tests/golden/regen.py)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("entry", [
    "conjugate_jnp",
    "conjugate_interpret",
])
def test_conjugate_jaxpr_structure_matches_golden(entry):
    # structural audit against tests/golden/structural.json (see
    # test_telemetry.py; the conjugate .txt goldens stay as regen
    # fixtures owned by tests/golden/regen.py)
    jaxpr_audit.assert_structural(entry)


# ---------------------------------------------------------------------------
# sharded lanes (the multidevice CI leg)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_conjugate_matches_batched_multidevice():
    out = run_multidevice(textwrap.dedent("""
        import jax
        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp
        import numpy as np
        from repro.core.sharded_lanes import solve_fused_sharded
        from repro.core.solver_fused import solve_fused_batched
        from repro.core.solver import SolverConfig

        rng = np.random.default_rng(0)
        X = jnp.asarray(rng.normal(size=(24, 3)))
        y = jnp.asarray(np.where(rng.normal(size=24) >= 0, 1.0, -1.0))
        Y = jnp.stack([y, -y])
        cfg = SolverConfig(algorithm="smo", step="conjugate", eps=1e-3,
                           max_iter=2000)
        rs = solve_fused_sharded(X, Y, 2.0, 0.8, cfg, impl="jnp")
        rb = solve_fused_batched(X, Y, 2.0, 0.8, cfg, impl="jnp")
        assert np.array_equal(np.asarray(rs.iterations),
                              np.asarray(rb.iterations))
        assert np.array_equal(np.asarray(rs.n_planning),
                              np.asarray(rb.n_planning))
        np.testing.assert_allclose(np.asarray(rs.alpha),
                                   np.asarray(rb.alpha),
                                   rtol=1e-12, atol=0)
        print("SHARDED_CONJ_OK")
    """), n_devices=2)
    assert "SHARDED_CONJ_OK" in out
