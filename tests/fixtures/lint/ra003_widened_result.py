"""Lint fixture: RA003 — widened result signature (planted).

A ``FusedResult`` that grew a field outside the telemetry seam.  Linted
as if it lived at ``src/repro/core/__planted__.py``; never imported.
"""
from typing import NamedTuple


class FusedResult(NamedTuple):
    alpha: object
    b: object
    G: object
    iterations: object
    objective: object
    kkt_gap: object
    converged: object
    n_planning: object
    n_unshrink: object
    shiny_new_counter: object
