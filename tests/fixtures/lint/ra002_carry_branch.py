"""Lint fixture: RA002 — Python branch on a traced carry (planted).

Linted as if it lived at ``src/repro/core/__planted__.py``; never
imported by the test suite.
"""


def body(s):
    if s.done:
        return s
    return s
