"""Lint fixture: RA004 — unseeded RNG in a test (planted).

Linted as if it lived at ``tests/test___planted__.py``; never collected
(``tests/fixtures/`` is excluded from real lint runs and pytest
collection).
"""
import numpy as np


def test_planted():
    assert np.random.default_rng().random() >= 0.0
