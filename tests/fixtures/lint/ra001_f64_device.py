"""Lint fixture: RA001 — f64 literal in device code (planted violation).

Linted as if it lived at ``src/repro/core/__planted__.py``; never
imported by the test suite.
"""
import jax.numpy as jnp


def widen(x):
    return x.astype(jnp.float64)
