"""Solver convergence: JAX solvers vs exact QP oracle (SLSQP) and vs the
faithful numpy reference (trajectory equality)."""

import jax.numpy as jnp
import numpy as np
import pytest
from scipy import optimize

from repro.core import qp as qp_mod
from repro.core import reference as ref
from repro.core.solver import SolverConfig, solve, solve_batched
from repro.svm.data import chessboard, gaussian_blobs, ring, xor_gaussians


def _exact_qp(K, y, C):
    """Exact dual optimum via SLSQP (oracle for small problems)."""
    n = len(y)
    L = np.minimum(0.0, y * C)
    U = np.maximum(0.0, y * C)

    def negf(a):
        return -(y @ a - 0.5 * a @ K @ a)

    def grad(a):
        return -(y - K @ a)

    res = optimize.minimize(
        negf, x0=np.zeros(n), jac=grad, method="SLSQP",
        bounds=list(zip(L, U)),
        constraints=[{"type": "eq", "fun": lambda a: np.sum(a),
                      "jac": lambda a: np.ones(n)}],
        options={"maxiter": 1000, "ftol": 1e-14})
    return -res.fun


def _problem(name, n, seed=0):
    gen = {"chess": chessboard, "blobs": gaussian_blobs, "ring": ring,
           "xor": xor_gaussians}[name]
    X, y = gen(n, seed=seed)
    gamma = {"chess": 0.5, "blobs": 0.05, "ring": 1.0, "xor": 0.5}[name]
    C = {"chess": 1000.0, "blobs": 1.0, "ring": 10.0, "xor": 100.0}[name]
    sq = np.sum(X * X, axis=1)
    K = np.exp(-gamma * (sq[:, None] + sq[None, :] - 2 * X @ X.T))
    return K, y, C


ALGS = ["smo", "pasmo", "pasmo_simple", "overshoot"]


class TestConvergenceToOptimum:
    @pytest.mark.parametrize("alg", ALGS)
    @pytest.mark.parametrize("name", ["blobs", "ring", "xor"])
    def test_matches_exact_oracle(self, alg, name):
        K, y, C = _problem(name, 60)
        f_star = _exact_qp(K, y, C)
        cfg = SolverConfig(algorithm=alg, eps=1e-6, max_iter=200_000)
        res = solve(qp_mod.PrecomputedKernel(jnp.asarray(K)), jnp.asarray(y),
                    C, cfg)
        assert bool(res.converged)
        assert float(res.objective) <= f_star + 1e-6 * (1 + abs(f_star))
        assert float(res.objective) >= f_star - 1e-4 * (1 + abs(f_star))

    @pytest.mark.parametrize("alg", ALGS)
    def test_feasibility(self, alg):
        K, y, C = _problem("xor", 80, seed=3)
        cfg = SolverConfig(algorithm=alg, eps=1e-4, max_iter=200_000)
        res = solve(qp_mod.PrecomputedKernel(jnp.asarray(K)), jnp.asarray(y),
                    C, cfg)
        bounds = qp_mod.make_bounds(jnp.asarray(y), C)
        assert bool(qp_mod.is_feasible(res.alpha, bounds, atol=1e-8))
        # gradient consistency: maintained G == y - K alpha
        np.testing.assert_allclose(np.asarray(res.G),
                                   y - K @ np.asarray(res.alpha),
                                   rtol=1e-8, atol=1e-8)

    @pytest.mark.slow
    def test_pasmo_multi_candidates(self):
        K, y, C = _problem("xor", 60, seed=1)
        f_star = _exact_qp(K, y, C)
        for N in [2, 3]:
            cfg = SolverConfig(algorithm="pasmo", plan_candidates=N,
                               eps=1e-6, max_iter=200_000)
            res = solve(qp_mod.PrecomputedKernel(jnp.asarray(K)),
                        jnp.asarray(y), C, cfg)
            assert bool(res.converged)
            assert float(res.objective) >= f_star - 1e-4 * (1 + abs(f_star))

    @pytest.mark.slow
    def test_rbf_oracle_equals_precomputed(self):
        X, y = xor_gaussians(50, seed=2)
        gamma, C = 0.5, 100.0
        kernel = qp_mod.make_rbf(jnp.asarray(X), gamma)
        cfg = SolverConfig(algorithm="pasmo", eps=1e-5)
        r1 = solve(kernel, jnp.asarray(y), C, cfg)
        K = qp_mod.materialize(kernel)
        r2 = solve(qp_mod.PrecomputedKernel(K), jnp.asarray(y), C, cfg)
        # The oracles are numerically (not bitwise) identical: after hundreds
        # of sequential steps the paths may differ by a few iterations, but
        # both must reach the same optimum at the same accuracy.
        assert bool(r1.converged) and bool(r2.converged)
        assert abs(int(r1.iterations) - int(r2.iterations)) \
            <= 0.05 * int(r2.iterations)
        np.testing.assert_allclose(float(r1.objective), float(r2.objective),
                                   rtol=1e-8)

    @pytest.mark.slow
    def test_shrinking_same_optimum(self):
        K, y, C = _problem("ring", 80, seed=5)
        base = solve(qp_mod.PrecomputedKernel(jnp.asarray(K)), jnp.asarray(y),
                     C, SolverConfig(algorithm="pasmo", eps=1e-5))
        shr = solve(qp_mod.PrecomputedKernel(jnp.asarray(K)), jnp.asarray(y),
                    C, SolverConfig(algorithm="pasmo", eps=1e-5,
                                    shrink_every=16))
        assert bool(shr.converged)
        np.testing.assert_allclose(float(shr.objective), float(base.objective),
                                   rtol=1e-6)

    @pytest.mark.slow
    def test_batched_solver(self):
        Ks, ys = [], []
        for s in range(4):
            K, y, C = _problem("xor", 32, seed=s)
            Ks.append(K)
            ys.append(y)
        res = solve_batched(jnp.asarray(np.stack(Ks)), jnp.asarray(np.stack(ys)),
                            50.0, SolverConfig(algorithm="pasmo", eps=1e-5))
        assert res.alpha.shape == (4, 32)
        assert bool(jnp.all(res.converged))
        for s in range(4):
            single = solve(qp_mod.PrecomputedKernel(jnp.asarray(Ks[s])),
                           jnp.asarray(ys[s]), 50.0,
                           SolverConfig(algorithm="pasmo", eps=1e-5))
            np.testing.assert_allclose(float(res.objective[s]),
                                       float(single.objective), rtol=1e-9)


def _first_divergence(np_steps, jx_steps):
    """Index of the first differing (i, j, mu) entry, or None."""
    for t, ((i1, j1, m1, _), (i2, j2, m2)) in enumerate(
            zip(np_steps, jx_steps)):
        if i1 != i2 or j1 != j2 or abs(m1 - m2) > 1e-9 * max(1.0, abs(m1)):
            return t
    return None


def _replay_to(K, y, C, steps, t):
    """Replay a recorded step prefix in float64 numpy; return (alpha, G)."""
    alpha = np.zeros(len(y))
    G = y.astype(np.float64).copy()
    for (i, j, mu, _) in steps[:t]:
        alpha[i] += mu
        alpha[j] -= mu
        G -= mu * (K[i] - K[j])
    return alpha, G


class TestTrajectoryParityWithReference:
    """The compiled JAX solver must take the *same path* as the faithful
    numpy reference, except where XLA's FMA contraction creates numerical
    ties: at the first divergent step, the two selections must have
    selection objectives equal to ~1e-8 relative (i.e. a genuine fp tie),
    and both solvers must reach the same optimum."""

    @staticmethod
    def _check(K, y, C, np_res, jx_res):
        jx_steps = list(zip(np.asarray(jx_res.steps_i).tolist(),
                            np.asarray(jx_res.steps_j).tolist(),
                            np.asarray(jx_res.steps_mu).tolist()))
        jx_steps = jx_steps[:int(jx_res.iterations)]
        t = _first_divergence(np_res.steps, jx_steps)
        if t is not None:
            alpha, G = _replay_to(K, y, C, np_res.steps, t)
            i1, j1 = np_res.steps[t][0], np_res.steps[t][1]
            i2, j2 = jx_steps[t][0], jx_steps[t][1]
            diag = np.diag(K)
            if i1 != i2:
                # i is argmax of G over I_up: a flip means G was fp-tied
                scale = max(1.0, abs(G[i1]), abs(G[i2]))
                assert abs(G[i1] - G[i2]) <= 1e-8 * scale, (
                    f"i-flip at t={t} not a tie: G[{i1}]={G[i1]} "
                    f"G[{i2}]={G[i2]}")
            elif j1 != j2:
                def obj(i, j):
                    l = G[i] - G[j]
                    q = max(diag[i] - 2 * K[i, j] + diag[j], 1e-12)
                    return 0.5 * l * l / q

                g1, g2 = obj(i1, j1), obj(i2, j2)
                assert abs(g1 - g2) <= 1e-6 * max(abs(g1), abs(g2)), (
                    f"j-flip at t={t} not a tie: "
                    f"np={(i1, j1)}:{g1} jx={(i2, j2)}:{g2}")
            # same pair, mu mismatch: FMA drift or a borderline planning
            # feasibility flip — covered by the optimum equality below.
        # both reach the same optimum regardless
        assert np_res.converged and bool(jx_res.converged)
        np.testing.assert_allclose(np_res.objective, float(jx_res.objective),
                                   rtol=1e-6)

    @pytest.mark.parametrize("name,n", [("xor", 60), ("ring", 50),
                                        ("blobs", 60), ("chess", 60)])
    def test_smo_trajectory(self, name, n):
        K, y, C = _problem(name, n)
        r_np = ref.solve_smo(K, y, C, eps=1e-4, tie="first",
                             record_steps=True)
        r_jx = solve(qp_mod.PrecomputedKernel(jnp.asarray(K)), jnp.asarray(y),
                     C, SolverConfig(algorithm="smo", eps=1e-4,
                                     record_steps=True))
        self._check(K, y, C, r_np, r_jx)

    @pytest.mark.parametrize("name,n", [("xor", 60), ("ring", 50),
                                        ("chess", 60)])
    def test_pasmo_trajectory(self, name, n):
        K, y, C = _problem(name, n)
        r_np = ref.solve_pasmo(K, y, C, eps=1e-4, tie="first",
                               record_steps=True)
        r_jx = solve(qp_mod.PrecomputedKernel(jnp.asarray(K)), jnp.asarray(y),
                     C, SolverConfig(algorithm="pasmo", eps=1e-4,
                                     record_steps=True))
        self._check(K, y, C, r_np, r_jx)
        # planning must actually engage on these problems
        if r_np.n_planning > 10:
            assert int(r_jx.n_planning) > 0

    def test_pasmo_multi_same_optimum(self):
        K, y, C = _problem("xor", 50)
        r_np = ref.solve_pasmo_multi(K, y, C, N=3, eps=1e-4, tie="first")
        r_jx = solve(qp_mod.PrecomputedKernel(jnp.asarray(K)), jnp.asarray(y),
                     C, SolverConfig(algorithm="pasmo", plan_candidates=3,
                                     eps=1e-4))
        assert r_np.converged and bool(r_jx.converged)
        np.testing.assert_allclose(r_np.objective, float(r_jx.objective),
                                   rtol=1e-6)
