#!/usr/bin/env python
"""Regenerate the fused-engine golden jaxprs in one command:

    PYTHONPATH=src python tests/golden/regen.py

Each golden pins the traced jaxpr of ``solve_fused_batched_qp`` for one
static configuration.  The first line of every file records the jax
version that printed it — jaxpr pretty-printing is not stable across jax
versions, so the byte-identity tests only run on a matching version and
skip elsewhere.

Captures are HERMETIC: every golden is rendered in its own fresh python
process (``--print NAME`` prints one golden to stdout; the default
regen-all mode spawns one ``--print`` subprocess per file).  This
matters because the jaxpr pretty-printer dedups repeated pjit sub-jaxprs
by object identity — whether eight traced ``jnp.where`` calls share one
jaxpr object (printed as a shared ``_where`` table entry) or expand
inline depends on the in-process tracing-cache state.  A fresh process
per capture makes the bytes a pure function of (jax version, recipe),
and the byte-identity tests use the same ``--print`` path, so test and
regen agree by construction.

Regenerate whenever an INTENTIONAL trace change lands (the pallas
goldens bake kernel source line numbers, so even pure line-shift edits
to ``repro/kernels/rbf_update_wss.py`` move them); review the diff to
confirm the change is the one you meant to make before committing.
"""

import os
import subprocess
import sys

GOLDEN_DIR = os.path.dirname(os.path.abspath(__file__))

# golden file -> (cfg_name, solver kwargs); one entry per byte-identity
# test.  Configs are named (not constructed at import time) so the
# registry is importable without touching jax.
GOLDENS = {
    "fused_jaxpr_jnp.txt": ("plain", dict(impl="jnp")),
    "fused_jaxpr_jnp_shrink.txt": ("plain", dict(impl="jnp",
                                                 shrinking=True)),
    "fused_jaxpr_interpret.txt": ("plain", dict(impl="interpret",
                                                block_l=8)),
    "fused_jaxpr_conjugate_jnp.txt": ("conjugate", dict(impl="jnp")),
    "fused_jaxpr_conjugate_interpret.txt": (
        "conjugate", dict(impl="interpret", block_l=8)),
}


def render(name: str) -> str:
    """Render one golden (header + jaxpr body) IN THIS process.

    Only call this from a fresh interpreter (``--print`` mode) — any
    prior jax tracing in the process can perturb the pretty-printer's
    sub-jaxpr sharing and change the bytes.
    """
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np
    from repro.core.solver import SolverConfig
    from repro.core.solver_fused import solve_fused_batched_qp

    cfg_name, kw = GOLDENS[name]
    cfg = {
        "plain": lambda: SolverConfig(eps=1e-3, max_iter=500),
        "conjugate": lambda: SolverConfig(algorithm="smo",
                                          step="conjugate", eps=1e-3,
                                          max_iter=500),
    }[cfg_name]()

    rng = np.random.default_rng(0)
    l, d, B = 16, 4, 3
    X = jnp.asarray(rng.normal(size=(l, d)))
    Y = jnp.asarray(np.sign(rng.normal(size=(B, l))))
    YC = Y * 2.0
    L, U = jnp.minimum(0.0, YC), jnp.maximum(0.0, YC)
    gam = jnp.asarray(rng.uniform(0.3, 1.0, B))

    body = str(jax.make_jaxpr(
        lambda X, P, L, U, g: solve_fused_batched_qp(
            X, P, L, U, g, cfg, **kw))(X, Y, L, U, gam)).rstrip("\n")
    return f"# jax {jax.__version__}\n{body}\n"


def render_in_subprocess(name: str) -> str:
    """Spawn a fresh interpreter and return its ``--print NAME`` output.

    This is the capture entry point the byte-identity tests use.
    """
    src = os.path.abspath(os.path.join(GOLDEN_DIR, "..", "..", "src"))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, os.path.join(GOLDEN_DIR, "regen.py"),
         "--print", name],
        capture_output=True, text=True, env=env, timeout=600)
    if proc.returncode != 0:
        raise RuntimeError(
            f"regen.py --print {name} failed "
            f"(rc={proc.returncode}):\n{proc.stderr}")
    return proc.stdout


def main(argv):
    if len(argv) == 2 and argv[0] == "--print":
        sys.stdout.write(render(argv[1]))
        return
    if argv:
        sys.exit(f"usage: {sys.argv[0]} [--print GOLDEN_NAME]")
    for name in GOLDENS:
        out = render_in_subprocess(name)
        with open(os.path.join(GOLDEN_DIR, name), "w") as fh:
            fh.write(out)
        header, body = out.split("\n", 1)
        print(f"wrote {name} ({len(body) - 1} bytes, {header[2:]})")


if __name__ == "__main__":
    main(sys.argv[1:])
