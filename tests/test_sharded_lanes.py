"""Lane-sharded fused engine (`repro.core.sharded_lanes`).

In-process tests run on the suite's single device (a 1-device mesh is the
degenerate shard_map — results must be bitwise those of the fused engine).
Multi-device behaviour (pad-lane stripping on uneven counts, iteration
parity, shrinking under sharding) respawns via ``conftest.run_multidevice``
so the rest of the suite keeps seeing one device.
"""

import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import FUSED_KW, run_multidevice
from repro.core import grid, multiclass as mc
from repro.core.sharded_lanes import (lane_schedule, pad_lanes,
                                      resolve_lane_mesh,
                                      solve_fused_sharded)
from repro.core.solver import SolverConfig
from repro.core.solver_fused import solve_fused_batched
from repro.svm import SVC, multiclass_blobs


# ---------------------------------------------------------------------------
# scheduling / padding units
# ---------------------------------------------------------------------------

def test_lane_schedule_round_robin_deal():
    # descending-cost positions are dealt one per shard, round-robin
    cost = jnp.asarray([3.0, 8.0, 1.0, 5.0, 7.0, 2.0, 6.0, 4.0])
    order, inv = lane_schedule(cost, 4)
    dealt = np.asarray(cost)[np.asarray(order)]
    # contiguous slab p = [order[2p], order[2p+1]] holds descending-cost
    # ranks p and p+4: every shard's slab sums to the same cost spread
    slabs = dealt.reshape(4, 2)
    assert np.all(slabs[:, 0] == np.asarray([8.0, 7.0, 6.0, 5.0]))
    assert np.all(slabs[:, 1] == np.asarray([4.0, 3.0, 2.0, 1.0]))
    # inv undoes the deal
    assert np.array_equal(np.asarray(order)[np.asarray(inv)], np.arange(8))


def test_lane_schedule_requires_divisibility():
    with pytest.raises(AssertionError):
        lane_schedule(jnp.ones(10), 4)


def test_pad_lanes():
    A = jnp.arange(6.0).reshape(3, 2)
    P = pad_lanes(A, 2)
    assert P.shape == (5, 2)
    assert np.all(np.asarray(P[3:]) == 0.0)
    g = pad_lanes(jnp.ones(3), 1, value=7.0)
    assert float(g[3]) == 7.0
    assert pad_lanes(A, 0) is A


def test_resolve_lane_mesh_validation():
    mesh = jax.make_mesh((1,), ("model",))
    with pytest.raises(ValueError, match="no 'data' axis"):
        resolve_lane_mesh(mesh)
    good = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="not both"):
        resolve_lane_mesh(good, devices=jax.devices())
    assert resolve_lane_mesh(good) is good
    assert resolve_lane_mesh(None, None).shape["data"] == 1


# ---------------------------------------------------------------------------
# one-device shard_map == fused engine, bitwise
# ---------------------------------------------------------------------------

def _grid_problem(l=120, k=3, seed=0):
    X, y = multiclass_blobs(l, seed=seed, k=k)
    classes, y_idx = mc.class_index(y)
    return X, y, mc.ovr_labels(y_idx, k)


def test_sharded_grid_matches_fused_one_device():
    # the ISSUE parity case: 3-class 2x2 grid, objectives to 1e-6 and
    # identical per-lane iteration counts (bitwise on a 1-device mesh)
    X, _, Y = _grid_problem()
    cfg = SolverConfig(eps=1e-3)
    Cs, gammas = [0.5, 8.0], [0.2, 1.0]
    r0 = grid.solve_grid(X, Y, Cs, gammas, cfg, **FUSED_KW)
    r1 = grid.solve_grid(X, Y, Cs, gammas, cfg, devices=jax.devices(),
                         **FUSED_KW)
    np.testing.assert_allclose(np.asarray(r1.objective),
                               np.asarray(r0.objective), rtol=0, atol=1e-6)
    assert np.array_equal(np.asarray(r1.iterations),
                          np.asarray(r0.iterations))
    np.testing.assert_array_equal(np.asarray(r1.alpha), np.asarray(r0.alpha))
    assert np.all(np.asarray(r1.converged))


def test_sharded_qp_layer_matches_batched_one_device():
    X, _, Y = _grid_problem(l=80)
    cfg = SolverConfig(eps=1e-3)
    C = jnp.asarray([1.0, 4.0, 16.0])
    r0 = solve_fused_batched(X, Y, C, 0.5, cfg, **FUSED_KW)
    r1 = solve_fused_sharded(X, Y, C, 0.5, cfg, devices=jax.devices(),
                             **FUSED_KW)
    np.testing.assert_array_equal(np.asarray(r1.alpha), np.asarray(r0.alpha))
    assert np.array_equal(np.asarray(r1.iterations),
                          np.asarray(r0.iterations))


def test_sharded_grid_svr_and_oneclass_one_device():
    rng = np.random.default_rng(0)
    X = rng.uniform(-2, 2, size=(90, 1))
    y = np.sinc(X[:, 0])
    cfg = SolverConfig(eps=1e-3)
    s0 = grid.solve_grid_svr(X, y, [1.0, 8.0], [0.1], [0.5], cfg, **FUSED_KW)
    s1 = grid.solve_grid_svr(X, y, [1.0, 8.0], [0.1], [0.5], cfg,
                             devices=jax.devices(), **FUSED_KW)
    np.testing.assert_allclose(np.asarray(s1.objective),
                               np.asarray(s0.objective), rtol=0, atol=1e-6)
    o0 = grid.solve_grid_oneclass(X, [0.2, 0.5], [0.5, 2.0], cfg, **FUSED_KW)
    o1 = grid.solve_grid_oneclass(X, [0.2, 0.5], [0.5, 2.0], cfg,
                                  devices=jax.devices(), **FUSED_KW)
    np.testing.assert_array_equal(np.asarray(o1.alpha), np.asarray(o0.alpha))


def test_vmapped_engine_rejects_mesh():
    X, _, Y = _grid_problem(l=40)
    with pytest.raises(ValueError, match="fused engine"):
        grid.solve_grid(X, Y, [1.0], [0.5], devices=jax.devices())
    with pytest.raises(ValueError, match="fused engine"):
        grid.solve_grid_compacted(X, Y, [1.0], [0.5], devices=jax.devices())


# ---------------------------------------------------------------------------
# facade engine selection
# ---------------------------------------------------------------------------

def test_svc_sharded_engine_matches_fused():
    X, y, _ = _grid_problem()
    kw = dict(C=10.0, gamma=0.5, impl=FUSED_KW["impl"])
    clf = SVC(engine="sharded", **kw).fit(X, y)
    ref = SVC(engine="fused", **kw).fit(X, y)
    assert clf.engine_ == "sharded"
    np.testing.assert_array_equal(np.asarray(clf.alpha_),
                                  np.asarray(ref.alpha_))
    assert clf.score(X, y) == ref.score(X, y)


def test_facade_engine_validation():
    with pytest.raises(ValueError, match="sharded"):
        SVC(C=1.0, engine="fused", devices=jax.devices())
    with pytest.raises(ValueError, match="auto|fused|batched|sharded"):
        SVC(C=1.0, engine="warp")
    X, y, _ = _grid_problem(l=40)
    with pytest.raises(ValueError, match="fused engine"):
        SVC(C=1.0, engine="sharded", algorithm="overshoot").fit(X, y)
    # auto never shards a single lane on a single device
    assert SVC(C=1.0)._resolve_engine(n_lanes=1) == "fused"
    # explicit devices flips auto to sharded
    assert SVC(C=1.0, devices=jax.devices()) \
        ._resolve_engine(n_lanes=3) == "sharded"


# ---------------------------------------------------------------------------
# multi-device: respawned with forced host devices (slow tier)
# ---------------------------------------------------------------------------

_EIGHT_DEVICE_SCRIPT = textwrap.dedent("""
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np
    from repro.core import grid, multiclass as mc
    from repro.core.solver import SolverConfig
    from repro.svm import SVC, multiclass_blobs

    assert len(jax.devices()) == 8
    X, y = multiclass_blobs(150, seed=1, k=3)
    classes, y_idx = mc.class_index(y)
    Y = mc.ovr_labels(y_idx, 3)
    # tight tolerance: tiny per-device slabs may compile to a different
    # reduction order than the full batch (see the sharded_lanes
    # docstring), so trajectories can differ — both engines then sit
    # within eps of the optimum, and 1e-6 objective parity needs eps well
    # below it
    cfg = SolverConfig(eps=1e-5)

    # ---- uneven lane count: 3 gammas x 3 classes x 3 Cs = 27 lanes pads
    # to 32 over 8 devices; pad lanes must be stripped and inert
    Cs, gammas = [0.5, 2.0, 8.0], [0.2, 0.5, 1.0]
    r0 = grid.solve_grid(X, Y, Cs, gammas, cfg, impl="jnp")
    r1 = grid.solve_grid(X, Y, Cs, gammas, cfg, impl="jnp",
                         devices=jax.devices())
    assert r1.alpha.shape == r0.alpha.shape, (r1.alpha.shape, r0.alpha.shape)
    np.testing.assert_allclose(np.asarray(r1.objective),
                               np.asarray(r0.objective), rtol=0, atol=1e-6)
    assert np.all(np.asarray(r1.converged))
    print("UNEVEN_OK maxdiff=",
          float(jnp.max(jnp.abs(r1.objective - r0.objective))))

    # ---- shrinking under sharding: reported lanes must stay in caller
    # order (per-lane objective parity vs the unsharded run catches any
    # reorder — neighbouring lanes differ in C/gamma, so their objectives
    # are far apart)
    rs0 = grid.solve_grid(X, Y, Cs, gammas, cfg, impl="jnp", shrinking=True)
    rs1 = grid.solve_grid(X, Y, Cs, gammas, cfg, impl="jnp", shrinking=True,
                          devices=jax.devices())
    np.testing.assert_allclose(np.asarray(rs1.objective),
                               np.asarray(rs0.objective), rtol=0, atol=1e-6)
    assert np.all(np.asarray(rs1.converged))
    print("SHRINK_OK")

    # ---- compacted chunks sharded (host lane compaction x device split)
    rc0 = grid.solve_grid_compacted(X, Y, Cs, gammas, cfg, impl="jnp",
                                    chunk=64)
    rc1 = grid.solve_grid_compacted(X, Y, Cs, gammas, cfg, impl="jnp",
                                    chunk=64, devices=jax.devices())
    np.testing.assert_allclose(np.asarray(rc1.objective),
                               np.asarray(rc0.objective), rtol=0, atol=1e-6)
    print("COMPACT_OK")

    # ---- doubled e-SVR lanes: objective parity (trajectories may
    # legitimately differ — see the sharded_lanes docstring)
    Xr = X[:, :1]; yr = np.sin(Xr[:, 0])
    s0 = grid.solve_grid_svr(Xr, yr, Cs, [0.1], gammas, cfg, impl="jnp")
    s1 = grid.solve_grid_svr(Xr, yr, Cs, [0.1], gammas, cfg, impl="jnp",
                             devices=jax.devices())
    np.testing.assert_allclose(np.asarray(s1.objective),
                               np.asarray(s0.objective), rtol=0, atol=1e-6)
    print("SVR_OK")

    # ---- SVC auto resolves to sharded on >1 device and still classifies
    clf = SVC(C=10.0, gamma=0.5).fit(X, y)
    assert clf.engine_ == "sharded", clf.engine_
    ref = SVC(C=10.0, gamma=0.5, engine="fused").fit(X, y)
    assert clf.score(X, y) == ref.score(X, y)
    print("FACADE_OK")
""")


@pytest.mark.slow
def test_sharded_lanes_eight_devices():
    out = run_multidevice(_EIGHT_DEVICE_SCRIPT, 8)
    for tag in ("UNEVEN_OK", "SHRINK_OK", "COMPACT_OK", "SVR_OK",
                "FACADE_OK"):
        assert tag in out, out


_TWO_DEVICE_PARITY_SCRIPT = textwrap.dedent("""
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np
    from repro.core import grid, multiclass as mc
    from repro.core.solver import SolverConfig
    from repro.svm import multiclass_blobs

    # 3-class 2x2 grid = 12 lanes over 2 devices -> 6-lane slabs, above
    # the tiny-slab codegen threshold: iteration counts must match the
    # single-device fused engine exactly (see sharded_lanes docstring)
    X, y = multiclass_blobs(150, seed=1, k=3)
    classes, y_idx = mc.class_index(y)
    Y = mc.ovr_labels(y_idx, 3)
    cfg = SolverConfig(eps=1e-3)
    r0 = grid.solve_grid(X, Y, [0.5, 8.0], [0.2, 1.0], cfg, impl="jnp")
    r1 = grid.solve_grid(X, Y, [0.5, 8.0], [0.2, 1.0], cfg, impl="jnp",
                         devices=jax.devices()[:2])
    np.testing.assert_allclose(np.asarray(r1.objective),
                               np.asarray(r0.objective), rtol=0, atol=1e-6)
    assert np.array_equal(np.asarray(r1.iterations),
                          np.asarray(r0.iterations)), (
        np.asarray(r0.iterations), np.asarray(r1.iterations))
    print("PARITY_OK")
""")


@pytest.mark.slow
def test_sharded_lanes_iteration_parity_two_devices():
    # 8 forced devices, mesh pinned to a 2-device subset
    out = run_multidevice(_TWO_DEVICE_PARITY_SCRIPT, 8)
    assert "PARITY_OK" in out
