"""Fused-batched grid engine vs the vmapped oracle, and in-kernel lane
freezing: the tentpole acceptance tests of the batched two-pass solver."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import grid as grid_mod
from repro.core import multiclass as mc
from repro.core.solver import SolverConfig
from repro.core.solver_fused import solve_fused_batched
from conftest import FUSED_KW
from repro.svm.data import multiclass_blobs, xor_gaussians

CFG = SolverConfig(eps=1e-4, max_iter=200_000)


def _grid_problem(n=80, k=3, seed=0):
    X, y = multiclass_blobs(n, seed=seed, k=k)
    X = jnp.asarray(X)
    _, y_idx = mc.class_index(y)
    return X, mc.ovr_labels(y_idx, k)


def test_fused_batched_matches_vmapped_grid_3class_2x2():
    """Differential acceptance: fused-batched objectives match the vmapped
    ``solve_grid`` to 1e-6 on EVERY lane of a 3-class 2x2 (C, gamma) grid,
    with identical converged flags."""
    X, Y = _grid_problem(n=64)
    Cs = np.array([1.0, 16.0])
    gammas = np.array([0.4, 1.2])
    vm = grid_mod.solve_grid(X, Y, Cs, gammas, CFG)
    fb = grid_mod.solve_grid(X, Y, Cs, gammas, CFG, **FUSED_KW)
    assert fb.alpha.shape == vm.alpha.shape == (2, 3, 2, 64)
    np.testing.assert_array_equal(np.asarray(fb.converged),
                                  np.asarray(vm.converged))
    assert bool(jnp.all(fb.converged))
    np.testing.assert_allclose(np.asarray(fb.objective),
                               np.asarray(vm.objective), rtol=1e-6)
    assert float(jnp.max(fb.kkt_gap)) <= CFG.eps + 1e-12
    # degenerate-lane regression: converged lanes report FINITE gaps/biases
    assert np.all(np.isfinite(np.asarray(fb.kkt_gap)))
    assert np.all(np.isfinite(np.asarray(fb.b)))
    # UNIFIED counter semantics: n_free (like n_clipped/n_reverted) is a
    # per-STEP counter, untracked on fused paths — it must carry the
    # explicit -1 sentinel there (a zero would read as "never happened");
    # the state counter every engine reports is n_free_sv
    for c in (fb.n_free, fb.n_clipped, fb.n_reverted):
        np.testing.assert_array_equal(np.asarray(c), grid_mod.UNTRACKED)
    assert int(jnp.sum(fb.n_free_sv)) > 0
    assert int(jnp.sum(vm.n_free_sv)) > 0
    assert int(jnp.sum(vm.n_free)) > 0          # classic engine: per-step


def test_fused_batched_interpret_backend_matches_jnp():
    """The batched Pallas kernels (interpret mode) inside the grid loop."""
    X, Y = _grid_problem(n=48)
    Cs = np.array([1.0, 8.0])
    gammas = np.array([0.6])
    r_jnp = grid_mod.solve_grid(X, Y, Cs, gammas, CFG, impl="jnp")
    r_pl = grid_mod.solve_grid(X, Y, Cs, gammas, CFG, impl="interpret",
                               block_l=128)
    assert bool(jnp.all(r_pl.converged))
    np.testing.assert_allclose(np.asarray(r_pl.objective),
                               np.asarray(r_jnp.objective), rtol=1e-6)


@pytest.mark.slow
def test_compacted_drivers_parity_and_counters():
    """Both chunked drivers (classic + fused-flat) reach the vmapped optima;
    satellite: the classic driver now accumulates the per-step counters
    across chunks instead of zero-filling them."""
    X, Y = _grid_problem(n=48)
    Cs = np.array([1.0, 16.0])
    gammas = np.array([0.7])
    vm = grid_mod.solve_grid(X, Y, Cs, gammas, CFG)
    comp = grid_mod.solve_grid_compacted(X, Y, Cs, gammas, CFG, chunk=96)
    compf = grid_mod.solve_grid_compacted(X, Y, Cs, gammas, CFG, chunk=96,
                                          **FUSED_KW)
    for res in (comp, compf):
        assert res.alpha.shape == vm.alpha.shape
        assert bool(jnp.all(res.converged))
        np.testing.assert_allclose(np.asarray(res.objective),
                                   np.asarray(vm.objective), rtol=1e-5,
                                   atol=1e-8)
        assert np.all(np.isfinite(np.asarray(res.kkt_gap)))
        assert np.all(np.isfinite(np.asarray(res.b)))
    # chunk resumes reset the O(1) planning history, so trajectories (and
    # exact counts) can drift — but the classic driver's counters must be
    # tracked (non-zero wherever the vmapped engine's are) and internally
    # consistent; the fused driver carries the UNTRACKED sentinel on all
    # three per-step counters and reports the free-SV state count instead
    assert int(jnp.sum(comp.n_free)) > 0
    assert int(jnp.sum(comp.n_clipped)) > 0
    np.testing.assert_array_equal(
        np.asarray(comp.iterations),
        np.asarray(comp.n_free + comp.n_clipped + comp.n_planning))
    np.testing.assert_array_equal(
        np.asarray(vm.iterations),
        np.asarray(vm.n_free + vm.n_clipped + vm.n_planning))
    for c in (compf.n_free, compf.n_clipped, compf.n_reverted):
        np.testing.assert_array_equal(np.asarray(c), grid_mod.UNTRACKED)
    assert int(jnp.sum(compf.n_free_sv)) > 0
    assert int(jnp.sum(comp.n_free_sv)) > 0


def test_lane_freeze_converged_lane_state_is_bitwise_held():
    """Satellite: a lane that converges early must not change state while a
    slow lane continues — the in-kernel freeze (mu forced to 0) makes the
    update pass a bitwise no-op on the frozen lane."""
    X, y = xor_gaussians(64, seed=0)
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    Y = jnp.stack([y, y])
    C = jnp.asarray([5.0, 100.0])      # lane 0 easy, lane 1 hard
    gamma = jnp.asarray([0.3, 0.5])
    cfg = SolverConfig(algorithm="pasmo", eps=1e-4, max_iter=100_000)

    full = solve_fused_batched(X, Y, C, gamma, cfg, **FUSED_KW)
    assert bool(jnp.all(full.converged))
    t_easy, t_hard = int(full.iterations[0]), int(full.iterations[1])
    assert t_easy < t_hard / 3          # genuinely heterogeneous lanes

    # stop shortly after the easy lane converges: its state must equal the
    # full run's bitwise, even though the hard lane kept iterating
    short = solve_fused_batched(
        X, Y, C, gamma, dataclasses.replace(cfg, max_iter=t_easy + 10),
        **FUSED_KW)
    assert bool(short.converged[0]) and not bool(short.converged[1])
    np.testing.assert_array_equal(np.asarray(short.alpha[0]),
                                  np.asarray(full.alpha[0]))
    np.testing.assert_array_equal(np.asarray(short.G[0]),
                                  np.asarray(full.G[0]))
    assert int(short.iterations[0]) == t_easy
    # per-lane iteration counters stop at convergence
    assert int(full.iterations[0]) == t_easy < int(full.iterations[1])


def test_fused_batched_per_lane_C_gamma_heterogeneous():
    """Heterogeneous (C, gamma) lanes are traced data: one compilation."""
    X, y = xor_gaussians(64, seed=1)
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    Y = jnp.stack([y, -y, y])
    C = jnp.asarray([10.0, 50.0, 2.0])
    gamma = jnp.asarray([0.5, 1.0, 0.25])
    res = solve_fused_batched(X, Y, C, gamma, CFG, **FUSED_KW)
    assert bool(jnp.all(res.converged))
    # each lane respects its own box
    for b in range(3):
        assert float(jnp.max(jnp.abs(res.alpha[b]))) <= float(C[b]) + 1e-9
    # feasibility: sum-to-zero per lane
    np.testing.assert_allclose(np.asarray(jnp.sum(res.alpha, axis=1)),
                               0.0, atol=1e-8)


def test_fused_batched_warm_start_resume():
    """(alpha0, G0) warm starts resume exactly (0 iterations at optimum)."""
    X, y = xor_gaussians(48, seed=2)
    X = jnp.asarray(X)
    Y = jnp.stack([jnp.asarray(y)])
    res = solve_fused_batched(X, Y, 10.0, 0.5, CFG, **FUSED_KW)
    resumed = solve_fused_batched(X, Y, 10.0, 0.5, CFG, alpha0=res.alpha,
                                  G0=res.G, **FUSED_KW)
    assert int(resumed.iterations[0]) == 0
    np.testing.assert_allclose(float(resumed.objective[0]),
                               float(res.objective[0]), rtol=1e-12)
