"""SVC facade: fit/predict round-trips, batched-vs-single parity, shapes."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.svm import SVC
from repro.svm.data import multiclass_blobs, ring


def _binary_data(n=120, seed=0):
    X, y = ring(n, seed=seed)
    return X, y.astype(np.int64)  # labels in {-1, 1}


def test_binary_fit_predict_roundtrip():
    X, y = _binary_data()
    clf = SVC(C=10.0, gamma=1.0, eps=1e-4).fit(X, y)
    assert clf.score(X, y) > 0.95
    df = clf.decision_function(X)
    assert df.shape == (len(y),)
    # sign(decision) maps to classes_[df >= 0]
    pred = clf.predict(X)
    np.testing.assert_array_equal(pred, clf.classes_[(np.asarray(df) >= 0)
                                                     .astype(int)])


def test_multiclass_fit_predict_roundtrip():
    X, y = multiclass_blobs(150, seed=1, k=3)
    clf = SVC(C=10.0, gamma=0.5, eps=1e-4).fit(X, y)
    assert clf.score(X, y) > 0.8
    df = clf.decision_function(X)
    assert df.shape == (len(y), 3)
    assert clf.alpha_.shape == (3, len(y))
    assert set(clf.predict(X)) <= set(clf.classes_)
    # held-out data from the same distribution
    Xq, yq = multiclass_blobs(60, seed=9, k=3)
    assert clf.score(Xq, yq) > 0.7


def test_batched_vs_single_example_predict_parity():
    X, y = multiclass_blobs(90, seed=2, k=3)
    clf = SVC(C=5.0, gamma=0.7, eps=1e-4).fit(X, y)
    Xq, _ = multiclass_blobs(25, seed=3, k=3)
    batched = clf.predict(Xq)
    singles = np.array([clf.predict(Xq[i]) for i in range(len(Xq))])
    np.testing.assert_array_equal(batched, singles)
    df_b = np.asarray(clf.decision_function(Xq))
    for i in range(len(Xq)):
        np.testing.assert_allclose(np.asarray(clf.decision_function(Xq[i])),
                                   df_b[i], rtol=1e-10)


def test_label_dtype_preserved():
    X, y = _binary_data(80, seed=4)
    labels = np.where(y > 0, 7, 3)  # arbitrary non-contiguous labels
    clf = SVC(C=10.0, gamma=1.0, eps=1e-3).fit(X, labels)
    assert set(np.unique(clf.predict(X))) <= {3, 7}
    assert clf.score(X, labels) > 0.9


def test_gamma_scale_and_introspection():
    X, y = multiclass_blobs(80, seed=5, k=3)
    clf = SVC(C=10.0, gamma="scale", eps=1e-3).fit(X, y)
    assert clf.gamma_ > 0
    assert clf.n_support_.shape == (3,)
    assert np.all(clf.n_support_ > 0)


@pytest.mark.slow
def test_precompute_false_matches_precompute_true():
    X, y = _binary_data(70, seed=6)
    a = SVC(C=10.0, gamma=1.0, eps=1e-4, precompute=True).fit(X, y)
    b = SVC(C=10.0, gamma=1.0, eps=1e-4, precompute=False).fit(X, y)
    np.testing.assert_allclose(float(a.fit_result_.objective),
                               float(b.fit_result_.objective), rtol=1e-8)
    np.testing.assert_array_equal(a.predict(X), b.predict(X))


def test_unfitted_and_degenerate_errors():
    with pytest.raises(RuntimeError):
        SVC().predict(np.zeros((3, 2)))
    with pytest.raises(ValueError):
        SVC().fit(np.zeros((4, 2)), np.zeros(4))  # single class
