"""Unit tests for the step / gain algebra of the paper (eqs. 2-8)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qp as qp_mod
from repro.core import step as step_mod
from repro.core import wss as wss_mod


def _random_psd(rng, n):
    A = rng.normal(size=(n, n))
    return A @ A.T / n + 1e-6 * np.eye(n)


def _random_terms(rng):
    """Random PSD 2x2 Q and gradient terms."""
    A = rng.normal(size=(2, 2))
    Q = A @ A.T + 1e-3 * np.eye(2)
    w = rng.normal(size=2)
    return step_mod.PlanningTerms(w1=jnp.asarray(w[0]), w2=jnp.asarray(w[1]),
                                  Q11=jnp.asarray(Q[0, 0]),
                                  Q22=jnp.asarray(Q[1, 1]),
                                  Q12=jnp.asarray(Q[0, 1]))


class TestStepAlgebra:
    def test_newton_gain_consistency(self):
        """Eq. (3) == eq. (4): g~ = l^2/(2Q) = 1/2 Q (mu*)^2."""
        rng = np.random.default_rng(0)
        for _ in range(50):
            l, q = rng.normal(), abs(rng.normal()) + 1e-3
            g3 = step_mod.gain_newton(l, q)
            mu = step_mod.newton_step(l, q)
            g4 = 0.5 * q * mu * mu
            np.testing.assert_allclose(g3, g4, rtol=1e-12)

    def test_gain_of_newton_step_is_max(self):
        """mu* maximizes the single-step gain parabola."""
        rng = np.random.default_rng(1)
        for _ in range(50):
            l, q = rng.normal(), abs(rng.normal()) + 1e-3
            mu_star = step_mod.newton_step(l, q)
            g_star = step_mod.gain_of_step(mu_star, l, q)
            for mu in np.linspace(-3, 3, 41):
                assert step_mod.gain_of_step(mu, l, q) <= g_star + 1e-12

    def test_fig2_gain_ratio(self):
        """Fig. 2: gain(mu)/gain(mu*) = 2r - r^2 for r = mu/mu*; on
        [1-eta, 1+eta] the gain is >= (1-eta^2) g*."""
        rng = np.random.default_rng(2)
        eta = 0.9
        for _ in range(100):
            l, q = rng.normal() + 1e-6, abs(rng.normal()) + 1e-3
            mu_star = step_mod.newton_step(l, q)
            g_star = step_mod.gain_newton(l, q)
            r = rng.uniform(1 - eta, 1 + eta)
            g = step_mod.gain_of_step(r * mu_star, l, q)
            np.testing.assert_allclose(g, (2 * r - r * r) * g_star, rtol=1e-9)
            assert g >= (1 - eta ** 2) * g_star - 1e-12 * abs(g_star)

    def test_double_step_gain_at_newton_matches_eq5(self):
        """Eq. (7) evaluated at mu1 = w1/Q11 equals the naive two-Newton-step
        gain of eq. (5) ... only when Q12 = 0 (independent directions);
        in general eq. (5) assumes the *updated* gradient for step 2.
        Check the exact identity instead: eq. (7) == brute-force two-step."""
        rng = np.random.default_rng(3)
        for _ in range(100):
            t = _random_terms(rng)
            mu1 = rng.normal()
            mu2 = step_mod.planned_second_step(mu1, t)
            w = np.array([t.w1, t.w2])
            Q = np.array([[t.Q11, t.Q12], [t.Q12, t.Q22]])
            mu = np.array([mu1, mu2])
            brute = w @ mu - 0.5 * mu @ Q @ mu
            np.testing.assert_allclose(step_mod.double_step_gain(mu1, t),
                                       brute, rtol=1e-9, atol=1e-12)

    def test_planning_step_maximizes_double_gain(self):
        """Eq. (8) is the argmax of eq. (7)."""
        rng = np.random.default_rng(4)
        for _ in range(100):
            t = _random_terms(rng)
            mu1, ok = step_mod.planning_step(t)
            assert bool(ok)
            g_opt = step_mod.double_step_gain(mu1, t)
            for delta in [-1.0, -0.1, 0.1, 1.0]:
                assert step_mod.double_step_gain(mu1 + delta, t) <= g_opt + 1e-10

        # analytic gradient check: d/dmu eq.(7) at mu1 = 0
        t = _random_terms(rng)
        mu1, _ = step_mod.planning_step(t)
        grad = jax.grad(lambda m: step_mod.double_step_gain(m, t))(mu1)
        np.testing.assert_allclose(grad, 0.0, atol=1e-9)

    def test_double_gain_lower_bounded_by_newton_gain(self):
        """§4/Lemma 3: the planned double-step gain at the optimum is >= the
        single Newton-step gain g~ (the proof's key inequality)."""
        rng = np.random.default_rng(5)
        for _ in range(200):
            t = _random_terms(rng)
            mu1, ok = step_mod.planning_step(t)
            if not bool(ok):
                continue
            g2 = step_mod.double_step_gain(mu1, t)
            g1 = step_mod.gain_newton(t.w1, t.Q11)
            assert g2 >= g1 - 1e-9 * max(1.0, abs(g1))

    def test_clip_step_bounds(self):
        rng = np.random.default_rng(6)
        for _ in range(100):
            lo, hi = -abs(rng.normal()), abs(rng.normal())
            mu = rng.normal() * 3
            c = step_mod.clip_step(mu, step_mod.StepBounds(jnp.asarray(lo),
                                                           jnp.asarray(hi)))
            assert lo <= c <= hi
            if lo < mu < hi:
                assert c == pytest.approx(mu)


class TestQPPrimitives:
    def test_gradient_and_objective(self):
        rng = np.random.default_rng(7)
        n = 16
        K = _random_psd(rng, n)
        y = np.sign(rng.normal(size=n))
        alpha = rng.normal(size=n) * 0.1
        g = qp_mod.gradient(jnp.asarray(alpha), jnp.asarray(y), jnp.asarray(K))
        g_ad = jax.grad(lambda a: qp_mod.dual_objective(a, jnp.asarray(y),
                                                        jnp.asarray(K)))(
            jnp.asarray(alpha))
        np.testing.assert_allclose(g, g_ad, rtol=1e-9)

    def test_kkt_gap_zero_at_optimum_free_problem(self):
        """For an interior optimum (huge C) the gap vanishes at K a = y
        projected onto sum(a)=0 feasibility."""
        rng = np.random.default_rng(8)
        n = 8
        K = _random_psd(rng, n)
        y = np.sign(rng.normal(size=n))
        # solve equality-constrained problem exactly via KKT system
        A = np.block([[K, np.ones((n, 1))], [np.ones((1, n)), np.zeros((1, 1))]])
        sol = np.linalg.solve(A, np.concatenate([y, [0.0]]))
        alpha = sol[:n]
        bounds = qp_mod.make_bounds(jnp.asarray(y), 1e9)
        G = qp_mod.gradient(jnp.asarray(alpha), jnp.asarray(y), jnp.asarray(K))
        gap = qp_mod.kkt_gap(G, jnp.asarray(alpha), bounds)
        assert abs(float(gap)) < 1e-6

    def test_kernel_oracles_match_materialized(self):
        rng = np.random.default_rng(9)
        X = rng.normal(size=(32, 5))
        for kernel in [qp_mod.make_rbf(jnp.asarray(X), 0.7),
                       qp_mod.LinearKernel(jnp.asarray(X))]:
            K = qp_mod.materialize(kernel)
            np.testing.assert_allclose(np.diag(K), kernel.diag(), rtol=1e-9)
            for i in [0, 7, 31]:
                np.testing.assert_allclose(K[i], kernel.row(jnp.asarray(i)),
                                           rtol=1e-9, atol=1e-12)
                np.testing.assert_allclose(
                    K[i, 5], kernel.entry(jnp.asarray(i), jnp.asarray(5)),
                    rtol=1e-9)


class TestWSS:
    def test_wss2_matches_bruteforce(self):
        """eq. (3) selection == brute force over all candidate pairs."""
        rng = np.random.default_rng(10)
        for trial in range(20):
            n = 24
            K = _random_psd(rng, n)
            y = np.sign(rng.normal(size=n))
            alpha = np.zeros(n)
            bounds = qp_mod.make_bounds(jnp.asarray(y), 1.0)
            G = jnp.asarray(y.copy())
            up = qp_mod.up_mask(jnp.asarray(alpha), bounds)
            dn = qp_mod.down_mask(jnp.asarray(alpha), bounds)
            i, gi = wss_mod.select_i(G, up)
            sel = wss_mod.select_wss2(G, jnp.asarray(K[int(i)]),
                                      jnp.asarray(np.diag(K)), up, dn)
            # brute force j given i
            best_j, best_g = -1, -np.inf
            for jj in range(n):
                if jj == int(i) or not bool(dn[jj]):
                    continue
                l = float(gi) - y[jj]
                if l <= 0:
                    continue
                q = max(K[int(i), int(i)] - 2 * K[int(i), jj] + K[jj, jj],
                        1e-12)
                g = 0.5 * l * l / q
                if g > best_g:
                    best_j, best_g = jj, g
            assert int(sel.j) == best_j
            np.testing.assert_allclose(float(sel.gain), best_g, rtol=1e-9)
