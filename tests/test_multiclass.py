"""Batched multi-QP layer: one-vs-rest + C/gamma grids vs sequential solves."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import grid as grid_mod
from repro.core import multiclass as mc
from repro.core import qp as qp_mod
from repro.core.solver import SolverConfig, solve
from repro.svm.data import multiclass_blobs

CFG = SolverConfig(eps=1e-4, max_iter=200_000)


def _problem(n=72, k=3, seed=0, gamma=0.5):
    X, y = multiclass_blobs(n, seed=seed, k=k)
    X = jnp.asarray(X)
    classes, y_idx = mc.class_index(y)
    Y = mc.ovr_labels(y_idx, k)
    K = jnp.exp(-gamma * grid_mod.sqdist(X))
    return X, Y, K, y_idx


def test_ovr_labels_structure():
    y_idx = np.array([0, 2, 1, 1, 0])
    Y = np.asarray(mc.ovr_labels(y_idx, 3))
    assert Y.shape == (3, 5)
    assert set(np.unique(Y)) == {-1.0, 1.0}
    for c in range(3):
        np.testing.assert_array_equal(Y[c] > 0, y_idx == c)
    # each column is +1 for exactly one class head
    np.testing.assert_array_equal((Y > 0).sum(axis=0), np.ones(5))


def test_ovr_matches_sequential_solves():
    """Batched OVR decision values == per-class sequential solves."""
    X, Y, K, y_idx = _problem(n=48)
    kern = qp_mod.PrecomputedKernel(K)
    res = mc.solve_ovr(kern, Y, 10.0, CFG)
    assert bool(jnp.all(res.converged))
    assert res.alpha.shape == Y.shape

    Kq = K  # evaluate on the training points: Kq rows are kernel rows
    batched_dec = np.asarray(mc.ovr_decision(Kq, res.alpha, res.b))
    for c in range(Y.shape[0]):
        single = solve(kern, Y[c], 10.0, CFG)
        np.testing.assert_allclose(float(res.objective[c]),
                                   float(single.objective), rtol=1e-9)
        np.testing.assert_allclose(
            batched_dec[:, c], np.asarray(Kq @ single.alpha + single.b),
            rtol=1e-7, atol=1e-9)
    # and the argmax prediction recovers the labels on separable-ish blobs
    pred = np.asarray(mc.ovr_predict(Kq, res.alpha, res.b))
    assert np.mean(pred == np.asarray(y_idx)) > 0.8


@pytest.mark.slow
def test_ovr_per_class_C():
    X, Y, K, _ = _problem()
    kern = qp_mod.PrecomputedKernel(K)
    Cs = jnp.asarray([1.0, 10.0, 100.0])
    # per-class bounds match the solver's internal per-row construction
    bounds = mc.ovr_bounds(Y, Cs)
    for c in range(3):
        row = qp_mod.make_bounds(Y[c], Cs[c])
        np.testing.assert_array_equal(np.asarray(bounds.lower[c]),
                                      np.asarray(row.lower))
        np.testing.assert_array_equal(np.asarray(bounds.upper[c]),
                                      np.asarray(row.upper))
    res = mc.solve_ovr(kern, Y, Cs, CFG)
    for c, C in enumerate([1.0, 10.0, 100.0]):
        single = solve(kern, Y[c], C, CFG)
        np.testing.assert_allclose(float(res.objective[c]),
                                   float(single.objective), rtol=1e-9)
        # the per-class box actually bound the variables
        assert float(jnp.max(jnp.abs(res.alpha[c]))) <= C + 1e-9


@pytest.mark.slow
def test_grid_one_call_matches_twelve_sequential():
    """Acceptance: a 3-class, 4-point C/gamma grid in ONE vmapped call gives
    the same predictions as the 12 equivalent sequential solves, each at the
    same KKT accuracy."""
    X, Y, _, _ = _problem(n=64)
    Cs = np.array([1.0, 20.0])
    gammas = np.array([0.3, 1.5])
    res = grid_mod.solve_grid(X, Y, Cs, gammas, CFG)
    assert res.alpha.shape == (2, 3, 2, 64)
    assert bool(jnp.all(res.converged))
    assert float(jnp.max(res.kkt_gap)) <= CFG.eps + 1e-12

    Xq, _ = multiclass_blobs(40, seed=7, k=3)
    dec = np.asarray(grid_mod.grid_decision(jnp.asarray(Xq), X, gammas,
                                            res.alpha, res.b))
    n_checked = 0
    for gi, g in enumerate(gammas):
        K = jnp.exp(-g * grid_mod.sqdist(X))
        kern = qp_mod.PrecomputedKernel(K)
        Kq = jnp.exp(-g * (jnp.sum(jnp.asarray(Xq)**2, 1)[:, None]
                           + jnp.sum(X**2, 1)[None, :]
                           - 2.0 * jnp.asarray(Xq) @ X.T))
        for c in range(3):
            for ci, C in enumerate(Cs):
                single = solve(kern, Y[c], float(C), CFG)
                assert bool(single.converged)
                assert float(single.kkt_gap) <= CFG.eps + 1e-12
                # same optimum => same decision values (up to eps-scale dual
                # differences, which perturb h(x) by O(eps))
                np.testing.assert_allclose(
                    dec[gi, c, ci],
                    np.asarray(Kq @ single.alpha + single.b), atol=5e-3)
                n_checked += 1
    assert n_checked == 12


@pytest.mark.slow
def test_grid_warm_start_matches_cold_start():
    """Warm-started C-path reaches the same KKT gap and optima as cold."""
    X, Y, _, _ = _problem(n=56)
    Cs = np.array([0.5, 4.0, 32.0])
    gammas = np.array([0.8])
    warm = grid_mod.solve_grid(X, Y, Cs, gammas, CFG)
    cold = grid_mod.solve_grid(X, Y, Cs, gammas, CFG, warm_start=False)
    assert bool(jnp.all(warm.converged)) and bool(jnp.all(cold.converged))
    assert float(jnp.max(warm.kkt_gap)) <= CFG.eps + 1e-12
    assert float(jnp.max(cold.kkt_gap)) <= CFG.eps + 1e-12
    np.testing.assert_allclose(np.asarray(warm.objective),
                               np.asarray(cold.objective),
                               rtol=1e-5, atol=1e-8)
    # every warm start is feasible: final alphas respect each C's box
    for ci, C in enumerate(Cs):
        assert float(jnp.max(jnp.abs(warm.alpha[:, :, ci]))) <= C + 1e-9


@pytest.mark.slow
def test_grid_compacted_matches_fused():
    """The host-compacted driver reaches the same optima at the same KKT
    accuracy as the single fused call, with the same result axes."""
    X, Y, _, _ = _problem(n=40)
    Cs = np.array([1.0, 16.0])
    gammas = np.array([0.8])
    fused = grid_mod.solve_grid(X, Y, Cs, gammas, CFG)
    comp = grid_mod.solve_grid_compacted(X, Y, Cs, gammas, CFG, chunk=256)
    assert comp.alpha.shape == fused.alpha.shape
    assert bool(jnp.all(comp.converged))
    assert float(jnp.max(comp.kkt_gap)) <= CFG.eps + 1e-12
    np.testing.assert_allclose(np.asarray(comp.objective),
                               np.asarray(fused.objective),
                               rtol=1e-5, atol=1e-8)


def test_grid_unsorted_C_axis_is_input_aligned():
    X, Y, _, _ = _problem(n=36)
    gammas = np.array([0.8])
    up = grid_mod.solve_grid(X, Y, np.array([1.0, 30.0]), gammas, CFG)
    dn = grid_mod.solve_grid(X, Y, np.array([30.0, 1.0]), gammas, CFG)
    np.testing.assert_allclose(np.asarray(up.objective),
                               np.asarray(dn.objective)[:, :, ::-1],
                               rtol=1e-6)


def test_warm_start_alpha0_without_G0():
    """solve() reconstructs the gradient through the oracle's matvec."""
    X, Y, K, _ = _problem(n=48)
    kern = qp_mod.PrecomputedKernel(K)
    y = Y[0]
    first = solve(kern, y, 5.0, CFG)
    resumed = solve(kern, y, 5.0, CFG, alpha0=first.alpha)
    assert int(resumed.iterations) == 0  # already optimal
    np.testing.assert_allclose(float(resumed.objective),
                               float(first.objective), rtol=1e-12)
    # RBF oracle matvec == dense matvec
    rbf = qp_mod.make_rbf(X, 0.5)
    v = jnp.asarray(np.random.default_rng(0).normal(size=X.shape[0]))
    np.testing.assert_allclose(np.asarray(rbf.matvec(v)),
                               np.asarray(qp_mod.materialize(rbf) @ v),
                               rtol=1e-10)
