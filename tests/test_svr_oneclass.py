"""Generalized-dual acceptance tests: ε-SVR and one-class SVM through the
fused batched engine vs the dense ``core/reference.py`` general-QP oracle,
fused-vs-batched engine parity per estimator, class-weighted SVC, and the
(gamma, eps/nu, C) grid lanes."""

import numpy as np
import jax.numpy as jnp
import pytest
from conftest import FUSED_KW

from repro.core import grid as grid_mod
from repro.core import qp as qp_mod
from repro.core import reference
from repro.core.solver import SolverConfig
from repro.core.solver_fused import solve_fused_batched_qp
from repro.kernels import ref as ref_ops
from repro.svm import SVC, SVR, OneClassSVM

CFG = SolverConfig(eps=1e-5, max_iter=200_000)


def _svr_problem(l=40, d=3, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(l, d))
    y = np.sinc(X[:, 0]) + 0.1 * rng.normal(size=l)
    gamma, C, epsilon = 0.7, 5.0, 0.05
    K = np.asarray(ref_ops.gram(jnp.asarray(X), gamma))
    return jnp.asarray(X), jnp.asarray(y), gamma, C, epsilon, K


def _oneclass_problem(l=60, d=2, seed=1, nu=0.3, gamma=0.5):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(l, d))
    X[:5] += 4.0                       # planted outliers
    K = np.asarray(ref_ops.gram(jnp.asarray(X), gamma))
    return jnp.asarray(X), nu, gamma, K


def test_doubled_kernel_oracle_matches_dense_tiled_gram():
    """DoubledKernel rows/diag/entry/matvec == the materialized 2l x 2l
    tile — without ever building it outside this test."""
    X, y, gamma, C, epsilon, K = _svr_problem(l=12)
    Qd = np.tile(K, (2, 2))
    kern = qp_mod.DoubledKernel(qp_mod.PrecomputedKernel(jnp.asarray(K)))
    assert kern.n == 24
    np.testing.assert_allclose(np.asarray(qp_mod.materialize(kern)), Qd,
                               rtol=1e-12)
    np.testing.assert_allclose(np.asarray(kern.diag()), np.diagonal(Qd))
    v = np.random.default_rng(3).normal(size=24)
    np.testing.assert_allclose(np.asarray(kern.matvec(jnp.asarray(v))),
                               Qd @ v, rtol=1e-10)


def test_svr_fused_matches_dense_reference_oracle():
    """Acceptance: the fused-batched ε-SVR lane reaches the dense
    general-QP oracle objective to 1e-6 (the engine tiles base rows; the
    oracle gets the materialized doubled matrix)."""
    X, y, gamma, C, epsilon, K = _svr_problem()
    Q, p, L, U = reference.doubled_qp(K, y, C, epsilon)
    ref = reference.solve_qp_smo(Q, p, L, U, eps=CFG.eps)
    assert ref.converged

    qp = qp_mod.svr_qp(y, C, epsilon)
    res = solve_fused_batched_qp(
        X, qp.p[None], qp.bounds.lower[None], qp.bounds.upper[None],
        gamma, CFG, doubled=True, **FUSED_KW)
    assert bool(res.converged[0])
    np.testing.assert_allclose(float(res.objective[0]), ref.objective,
                               rtol=1e-6)
    # doubled-dual feasibility: box + sum-to-zero (the folded equality)
    a = np.asarray(res.alpha[0])
    assert np.all(a >= np.asarray(qp.bounds.lower) - 1e-9)
    assert np.all(a <= np.asarray(qp.bounds.upper) + 1e-9)
    assert abs(a.sum()) < 1e-8


@pytest.mark.slow
def test_svr_engine_parity_and_fit_quality():
    """Facade parity: SVR(engine='fused') == SVR(engine='batched') to 1e-6
    in objective and prediction; both actually fit the curve."""
    rng = np.random.default_rng(0)
    X = rng.uniform(-3, 3, size=(60, 1))
    y = np.sinc(X[:, 0]) + 0.05 * rng.normal(size=60)
    kw = dict(C=10.0, epsilon=0.05, gamma=1.0, eps=1e-5)
    fused = SVR(engine="fused", **kw).fit(X, y)
    batched = SVR(engine="batched", **kw).fit(X, y)
    assert fused.engine_ == "fused" and batched.engine_ == "batched"
    np.testing.assert_allclose(float(fused.fit_result_.objective),
                               float(batched.fit_result_.objective),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(fused.predict(X)),
                               np.asarray(batched.predict(X)), atol=1e-5)
    assert fused.score(X, y) > 0.95
    # the doubled dual never leaves the tube constraint structure:
    # alpha+ and alpha- are never both active
    ap = np.asarray(fused.alpha_[:60])
    am = -np.asarray(fused.alpha_[60:])
    assert float(np.max(np.minimum(ap, am))) <= 1e-9


def test_oneclass_fused_matches_dense_reference_oracle():
    """Acceptance: the fused one-class lane (p = 0, sum(a) = 1 via the
    feasible LIBSVM start) matches the dense oracle objective to 1e-6."""
    X, nu, gamma, K = _oneclass_problem()
    l = X.shape[0]
    qp = qp_mod.oneclass_qp(l, nu)
    a0 = qp_mod.oneclass_alpha0(l, nu)
    ref = reference.solve_qp_smo(
        K, np.zeros(l), np.asarray(qp.bounds.lower),
        np.asarray(qp.bounds.upper), alpha0=np.asarray(a0), eps=CFG.eps)
    assert ref.converged

    G0 = -(jnp.asarray(K) @ a0)
    res = solve_fused_batched_qp(
        X, qp.p[None], qp.bounds.lower[None], qp.bounds.upper[None],
        gamma, CFG, alpha0=a0[None], G0=G0[None], **FUSED_KW)
    assert bool(res.converged[0])
    np.testing.assert_allclose(float(res.objective[0]), ref.objective,
                               rtol=1e-6, atol=1e-10)
    # equality constraint sum(a) = 1 is preserved by every pair step
    np.testing.assert_allclose(float(jnp.sum(res.alpha[0])), 1.0,
                               atol=1e-10)


@pytest.mark.slow
def test_oneclass_engine_parity_and_nu_semantics():
    """Facade parity fused vs batched; the training-outlier fraction tracks
    nu and the planted outliers score lowest."""
    X, nu, gamma, K = _oneclass_problem(l=80, nu=0.15)
    kw = dict(nu=0.15, gamma=gamma, eps=1e-5)
    fused = OneClassSVM(engine="fused", **kw).fit(X)
    batched = OneClassSVM(engine="batched", **kw).fit(X)
    np.testing.assert_allclose(float(fused.fit_result_.objective),
                               float(batched.fit_result_.objective),
                               rtol=1e-6, atol=1e-10)
    np.testing.assert_allclose(np.asarray(fused.decision_function(X)),
                               np.asarray(batched.decision_function(X)),
                               atol=1e-6)
    pred = fused.predict(X)
    out_frac = float((pred < 0).mean())
    assert abs(out_frac - 0.15) <= 0.1
    # the planted far-away points score clearly below the bulk
    dec = np.asarray(fused.decision_function(X))
    assert dec[:5].mean() < dec[5:].mean()


@pytest.mark.slow
def test_svr_grid_fused_lanes_match_per_lane_facade():
    """A (gamma, eps, C) SVR grid is one flat fused lane batch; every lane
    equals the corresponding single-QP facade solve."""
    rng = np.random.default_rng(2)
    X = rng.uniform(-2, 2, size=(40, 2))
    y = np.sinc(X[:, 0]) * np.cos(X[:, 1]) + 0.05 * rng.normal(size=40)
    Cs, epss, gammas = [1.0, 10.0], [0.02, 0.2], [0.5]
    res = grid_mod.solve_grid_svr(X, y, Cs, epss, gammas, CFG, **FUSED_KW)
    assert res.alpha.shape == (1, 2, 2, 80)
    assert bool(jnp.all(res.converged))
    for gi, g in enumerate(gammas):
        for ei, e in enumerate(epss):
            for ci, c in enumerate(Cs):
                one = SVR(C=c, epsilon=e, gamma=g, eps=CFG.eps,
                          engine="fused").fit(X, y)
                np.testing.assert_allclose(
                    float(res.objective[gi, ei, ci]),
                    float(one.fit_result_.objective), rtol=1e-6)
    # fold + shared decision machinery across the whole grid
    beta = qp_mod.svr_fold(res.alpha)
    dec = grid_mod.grid_decision(X[:7], X, gammas, beta, res.b)
    assert dec.shape == (1, 2, 2, 7)


@pytest.mark.slow
def test_oneclass_grid_fused_lanes_match_per_lane_facade():
    """A (gamma, nu) one-class grid is one flat fused lane batch."""
    X, _, _, _ = _oneclass_problem(l=40)
    nus, gammas = [0.2, 0.4], [0.5, 1.0]
    res = grid_mod.solve_grid_oneclass(X, nus, gammas, CFG, **FUSED_KW)
    assert res.alpha.shape == (2, 2, 40)
    assert bool(jnp.all(res.converged))
    np.testing.assert_allclose(np.asarray(jnp.sum(res.alpha, axis=-1)),
                               1.0, atol=1e-10)
    for gi, g in enumerate(gammas):
        for ni, nu in enumerate(nus):
            one = OneClassSVM(nu=nu, gamma=g, eps=CFG.eps,
                              engine="fused").fit(X)
            np.testing.assert_allclose(
                float(res.objective[gi, ni]),
                float(one.fit_result_.objective), rtol=1e-6, atol=1e-12)


def test_svr_grid_interpret_in_kernel_doubled_matches_jnp():
    """Tier-1 acceptance for the in-kernel doubled row mode: a small
    (gamma, eps, C) SVR grid through ``impl="interpret"`` (Pallas kernels,
    base (lpad, dpad) X tile, half-offset reads — never a pre-tiled X)
    reaches the jnp-engine objectives to 1e-6 on every lane."""
    rng = np.random.default_rng(5)
    X = rng.uniform(-2, 2, size=(24, 2))
    y = np.sinc(X[:, 0]) + 0.05 * rng.normal(size=24)
    Cs, epss, gammas = [1.0, 10.0], [0.05], [0.8]
    # backend parity is eps-independent; looser stop = cheaper interpret run
    cfg = SolverConfig(eps=1e-4, max_iter=200_000)
    r_jnp = grid_mod.solve_grid_svr(X, y, Cs, epss, gammas, cfg, impl="jnp")
    r_int = grid_mod.solve_grid_svr(X, y, Cs, epss, gammas, cfg,
                                    impl="interpret", block_l=128)
    assert bool(jnp.all(r_int.converged))
    np.testing.assert_allclose(np.asarray(r_int.objective),
                               np.asarray(r_jnp.objective), rtol=1e-6)
    # the folded dual agrees to KKT-tolerance level (trajectories differ
    # by floating-point reassociation; the dual is only eps-determined)
    np.testing.assert_allclose(np.asarray(qp_mod.svr_fold(r_int.alpha)),
                               np.asarray(qp_mod.svr_fold(r_jnp.alpha)),
                               atol=1e-3)


def test_gram_bank_row_source_runs_on_interpret_backend():
    """The Gram-bank row source is no longer jnp-only: with
    ``precompute=True`` the bank gathers feed the rows-variant Pallas
    kernels (interpret), for both the plain SVC grid and the doubled SVR
    grid, matching the jnp bank path to 1e-6."""
    rng = np.random.default_rng(6)
    X = rng.normal(size=(24, 2))
    y = np.sign(X[:, 0] * X[:, 1]) + (X[:, 0] * 0 + 0)   # XOR-ish labels
    y[y == 0] = 1.0
    # backend parity is eps-independent (identical algorithm both sides),
    # so a looser stop keeps this interpret-mode test cheap in tier-1
    cfg = SolverConfig(eps=1e-4, max_iter=200_000)
    r_jnp = grid_mod.solve_grid(X, y[None, :], [8.0], [0.6], cfg,
                                impl="jnp", precompute=True)
    r_int = grid_mod.solve_grid(X, y[None, :], [8.0], [0.6], cfg,
                                impl="interpret", block_l=128,
                                precompute=True)
    assert bool(jnp.all(r_int.converged))
    np.testing.assert_allclose(np.asarray(r_int.objective),
                               np.asarray(r_jnp.objective), rtol=1e-6)
    ys = np.sinc(X[:, 0])
    s_jnp = grid_mod.solve_grid_svr(X, ys, [5.0], [0.05], [0.6], cfg,
                                    impl="jnp", precompute=True)
    s_int = grid_mod.solve_grid_svr(X, ys, [5.0], [0.05], [0.6], cfg,
                                    impl="interpret", block_l=128,
                                    precompute=True)
    assert bool(jnp.all(s_int.converged))
    np.testing.assert_allclose(np.asarray(s_int.objective),
                               np.asarray(s_jnp.objective), rtol=1e-6)


def test_svc_class_weight_box_and_engine_parity():
    """Per-class weighted C: the per-sample box is respected bitwise in
    both engines, the engines agree, and 'balanced' lifts minority recall
    on an imbalanced blob."""
    rng = np.random.default_rng(4)
    X = np.vstack([rng.normal(size=(54, 2)),
                   rng.normal(size=(6, 2)) + 1.5])
    y = np.array([0] * 54 + [1] * 6)
    plain = SVC(C=1.0, gamma=0.5, engine="fused").fit(X, y)
    fused = SVC(C=1.0, gamma=0.5, class_weight="balanced",
                engine="fused").fit(X, y)
    batched = SVC(C=1.0, gamma=0.5, class_weight="balanced",
                  engine="batched").fit(X, y)
    np.testing.assert_allclose(float(fused.fit_result_.objective),
                               float(batched.fit_result_.objective),
                               rtol=1e-6)
    w = fused._sample_weights(np.array([0] * 54 + [1] * 6), 2)
    assert np.all(np.abs(np.asarray(fused.alpha_)) <= w + 1e-9)
    assert np.any(np.abs(np.asarray(fused.alpha_)) > 1.0 + 1e-9), \
        "the minority box must actually exceed the unweighted C"
    rec_plain = float((plain.predict(X[54:]) == 1).mean())
    rec_bal = float((fused.predict(X[54:]) == 1).mean())
    assert rec_bal > rec_plain
    # dict weights hit the same code path
    d = SVC(C=1.0, gamma=0.5, class_weight={0: 1.0, 1: 9.0},
            engine="fused").fit(X, y)
    assert float((d.predict(X[54:]) == 1).mean()) >= rec_plain


def test_svr_rejects_bad_engine_and_unfitted_predict():
    with pytest.raises(ValueError):
        SVR(engine="warp")
    with pytest.raises(RuntimeError):
        SVR().predict(np.zeros((2, 2)))
    with pytest.raises(ValueError):
        OneClassSVM(nu=0.0)
