"""Sharded (shard_map) solver: equivalence with the single-device solver.

The 1-device mesh test runs in-process.  The multi-device test spawns a
subprocess with ``--xla_force_host_platform_device_count=8`` so the rest of
the suite keeps seeing a single device (dry-run rule).
"""

import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_multidevice
from repro.core import qp as qp_mod
from repro.core.sharded import solve_sharded
from repro.core.solver import SolverConfig, solve
from repro.svm.data import xor_gaussians, ring


@pytest.mark.parametrize("alg", ["smo", "pasmo"])
def test_sharded_one_device_matches_single(alg):
    X, y = xor_gaussians(64, seed=0)
    gamma, C = 0.5, 100.0
    mesh = jax.make_mesh((1,), ("data",))
    cfg = SolverConfig(algorithm=alg, eps=1e-4, max_iter=100_000)
    rs = solve_sharded(jnp.asarray(X), jnp.asarray(y), C, gamma, mesh, cfg)
    r1 = solve(qp_mod.make_rbf(jnp.asarray(X), gamma), jnp.asarray(y), C, cfg)
    assert bool(rs.converged) and bool(r1.converged)
    np.testing.assert_allclose(float(rs.objective), float(r1.objective),
                               rtol=1e-6)
    if alg == "pasmo":
        assert int(rs.n_planning) > 0


def test_sharded_padding_is_inert():
    # 50 is not divisible by 2; padded tail must not change the solution
    X, y = ring(50, seed=1)
    gamma, C = 1.0, 10.0
    mesh = jax.make_mesh((1,), ("data",))
    cfg = SolverConfig(algorithm="pasmo", eps=1e-4, max_iter=100_000)
    rs = solve_sharded(jnp.asarray(X), jnp.asarray(y), C, gamma, mesh, cfg)
    r1 = solve(qp_mod.make_rbf(jnp.asarray(X), gamma), jnp.asarray(y), C, cfg)
    np.testing.assert_allclose(float(rs.objective), float(r1.objective),
                               rtol=1e-6)
    assert np.all(np.asarray(rs.alpha)[50:] == 0.0)


_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np
    from repro.core import qp as qp_mod
    from repro.core.sharded import solve_sharded
    from repro.core.solver import SolverConfig, solve
    from repro.svm.data import xor_gaussians

    X, y = xor_gaussians(96, seed=3)
    gamma, C = 0.5, 100.0
    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((8,), ("data",))
    cfg = SolverConfig(algorithm="pasmo", eps=1e-4, max_iter=100_000)
    rs = solve_sharded(jnp.asarray(X), jnp.asarray(y), C, gamma, mesh, cfg)
    r1 = solve(qp_mod.make_rbf(jnp.asarray(X), gamma), jnp.asarray(y), C, cfg)
    assert bool(rs.converged) and bool(r1.converged), (rs, r1)
    np.testing.assert_allclose(float(rs.objective), float(r1.objective),
                               rtol=1e-6)
    assert int(rs.n_planning) > 0
    # feasibility of the sharded solution
    a = np.asarray(rs.alpha)[:96]
    L = np.minimum(0, y * C); U = np.maximum(0, y * C)
    assert np.all(a >= L - 1e-9) and np.all(a <= U + 1e-9)
    assert abs(a.sum()) < 1e-6
    print("SHARDED_OK iterations=", int(rs.iterations))
""")


@pytest.mark.slow
def test_sharded_eight_devices_subprocess():
    out = run_multidevice(_SUBPROCESS_SCRIPT, 8)
    assert "SHARDED_OK" in out
