"""Training substrate: optimizer correctness, train-step convergence,
microbatch equivalence, compression, data determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import TrainConfig
from repro.data import SyntheticTokens
from repro.models import registry
from repro.train import optimizer as opt
from repro.train.compression import (dequantize_int8, ef_compress_grads,
                                     quantize_int8)
from repro.train.train_step import TrainState, init_state, make_train_step

TC = TrainConfig(param_dtype="float32", compute_dtype="float32",
                 accum_dtype="float32", learning_rate=1e-2, remat="none",
                 grad_clip=1.0)


def _quadratic_problem(n=8, seed=0):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n, n))
    A = A @ A.T / n + np.eye(n)
    b = rng.normal(size=n)
    x_star = np.linalg.solve(A, b)

    def loss(x):
        return 0.5 * x @ (jnp.asarray(A) @ x) - jnp.asarray(b) @ x

    return loss, jnp.zeros(n), x_star


@pytest.mark.parametrize("name", ["adamw", "adafactor", "sgdm"])
def test_optimizers_descend_quadratic(name):
    loss, x0, x_star = _quadratic_problem()
    tc = TrainConfig(optimizer=name, learning_rate=0.05, weight_decay=0.0)
    params = {"x": x0}
    state = opt.init(params, tc)

    @jax.jit
    def step(params, state):
        g = jax.grad(lambda p: loss(p["x"]))(params)
        return opt.update(g, state, params, tc, lr=jnp.asarray(0.05))

    for _ in range(400):
        params, state = step(params, state)
    final = float(loss(params["x"]))
    init = float(loss(x0))
    assert final < init - 0.5 * (init - float(loss(jnp.asarray(x_star))))


def test_adamw_matches_reference_numpy():
    """One AdamW step vs a hand-written reference."""
    tc = TrainConfig(optimizer="adamw", learning_rate=1e-3,
                     weight_decay=0.1, beta1=0.9, beta2=0.95)
    rng = np.random.default_rng(1)
    p = {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)}
    g = {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)}
    state = opt.init(p, tc)
    new_p, _ = opt.update(g, state, p, tc)
    m = 0.1 * np.asarray(g["w"])
    v = 0.05 * np.asarray(g["w"]) ** 2
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.95)
    ref = (np.asarray(p["w"])
           - 1e-3 * (mh / (np.sqrt(vh) + 1e-8)
                     + 0.1 * np.asarray(p["w"])))
    np.testing.assert_allclose(np.asarray(new_p["w"]), ref, rtol=1e-6)


def test_grad_clip():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = opt.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), np.sqrt(1000.0), rtol=1e-6)
    np.testing.assert_allclose(
        float(opt.global_norm(clipped)), 1.0, rtol=1e-5)


def test_train_step_reduces_loss():
    cfg = get_smoke("qwen2-0.5b")
    tc = TC
    state = init_state(jax.random.PRNGKey(0), cfg, tc)
    step = jax.jit(make_train_step(cfg, tc))
    data = SyntheticTokens(vocab=cfg.vocab, seq_len=32, global_batch=4)
    batch0 = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    losses = []
    for t in range(30):
        state, metrics = step(state, batch0)  # overfit one batch
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]


@pytest.mark.slow
def test_microbatch_accumulation_matches_full_batch():
    cfg = get_smoke("qwen2-0.5b")
    data = SyntheticTokens(vocab=cfg.vocab, seq_len=16, global_batch=8)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}

    tc1 = TC
    tc4 = TrainConfig(**{**TC.__dict__, "microbatches": 4})
    s1 = init_state(jax.random.PRNGKey(0), cfg, tc1)
    s4 = TrainState(params=s1.params, opt=s1.opt, ef=s1.ef, step=s1.step)
    n1, _ = jax.jit(make_train_step(cfg, tc1))(s1, batch)
    n4, _ = jax.jit(make_train_step(cfg, tc4))(s4, batch)
    for a, b in zip(jax.tree.leaves(n1.params), jax.tree.leaves(n4.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                   atol=2e-5)


def test_quantize_roundtrip_bounded_error():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-7


def test_error_feedback_unbiased_over_time():
    """EF compression: the accumulated applied update converges to the
    accumulated true gradient (residual stays bounded)."""
    rng = np.random.default_rng(3)
    g_true = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    res = {"g": jnp.zeros((64,), jnp.float32)}
    applied = np.zeros(64)
    for t in range(50):
        out, res_new = ef_compress_grads({"g": g_true}, res)
        applied += np.asarray(out["g"])
        res = res_new
    np.testing.assert_allclose(applied / 50, np.asarray(g_true), atol=1e-2)


@pytest.mark.slow
def test_compressed_training_still_converges():
    cfg = get_smoke("qwen2-0.5b")
    tc = TrainConfig(**{**TC.__dict__, "compress_grads": True})
    state = init_state(jax.random.PRNGKey(0), cfg, tc)
    step = jax.jit(make_train_step(cfg, tc))
    data = SyntheticTokens(vocab=cfg.vocab, seq_len=32, global_batch=4)
    batch0 = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    losses = []
    for t in range(30):
        state, metrics = step(state, batch0)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5


def test_data_pipeline_deterministic_and_step_indexed():
    d1 = SyntheticTokens(vocab=1000, seq_len=64, global_batch=4, seed=7)
    d2 = SyntheticTokens(vocab=1000, seq_len=64, global_batch=4, seed=7)
    b1 = d1.batch_at(123)
    b2 = d2.batch_at(123)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = d1.batch_at(124)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
