import os
import re
import subprocess
import sys

import jax

# The QP solver tests need f64 (chess-board uses C=1e6).  Model smoke tests
# use explicit f32/bf16 dtypes, so the flag is harmless there.  The dry-run
# device-count flag is intentionally NOT set here (smoke tests see 1 device).
jax.config.update("jax_enable_x64", True)

# Kernel-backend toggle for the fused-engine tests (the nightly CI interpret
# leg): REPRO_IMPL=interpret re-runs them through the batched Pallas kernels
# in interpret mode instead of the jnp oracle (REPRO_BLOCK_L tunes the block
# size; small keeps interpret-mode padding cheap).  Default stays jnp — the
# tier-1 fast path.  Tests import FUSED_KW and splat it into fused-engine
# calls.
FUSED_IMPL = os.environ.get("REPRO_IMPL", "jnp")
FUSED_KW = {"impl": FUSED_IMPL}
if FUSED_IMPL != "jnp":
    FUSED_KW["block_l"] = int(os.environ.get("REPRO_BLOCK_L", "128"))


def golden_fresh_capture(name: str) -> tuple:
    """Hermetically re-render golden ``name``; return (version, body).

    Delegates to ``tests/golden/regen.py --print`` in a FRESH interpreter —
    the jaxpr pretty-printer's sub-jaxpr sharing depends on in-process
    tracing-cache state, so an in-suite ``make_jaxpr`` can print different
    bytes than the regen script did.  Spawning the regen script itself
    makes test and golden agree on the recipe by construction.
    """
    import importlib.util
    path = os.path.join(os.path.dirname(__file__), "golden", "regen.py")
    spec = importlib.util.spec_from_file_location("golden_regen", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    out = mod.render_in_subprocess(name)
    header, body = out.split("\n", 1)
    return header.removeprefix("# jax ").strip(), body


def run_multidevice(script: str, n_devices: int = 8, *,
                    timeout: int = 600) -> str:
    """Run ``script`` in a fresh interpreter with ``n_devices`` forced host
    CPU devices; return its stdout.

    ``--xla_force_host_platform_device_count`` must be set before jax is
    imported, and the running suite must keep seeing a single device (the
    dry-run rule), so multi-device tests respawn: the flag is composed into
    ``XLA_FLAGS`` (replacing any inherited device-count flag), ``PYTHONPATH``
    gains ``src/``, and the child owns imports and x64 config itself.  A
    non-zero exit asserts with the stderr tail.  Mark callers
    ``@pytest.mark.slow`` — each respawn pays a fresh jit warm-up.
    """
    env = dict(os.environ)
    flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                   env.get("XLA_FLAGS", "")).strip()
    env["XLA_FLAGS"] = (f"{flags} " if flags else "") + \
        f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.pathsep.join(p for p in (
        os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src")),
        env.get("PYTHONPATH")) if p)
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)
    assert proc.returncode == 0, \
        f"multi-device subprocess failed:\n{proc.stderr[-4000:]}"
    return proc.stdout
