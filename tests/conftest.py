import os

import jax

# The QP solver tests need f64 (chess-board uses C=1e6).  Model smoke tests
# use explicit f32/bf16 dtypes, so the flag is harmless there.  The dry-run
# device-count flag is intentionally NOT set here (smoke tests see 1 device).
jax.config.update("jax_enable_x64", True)

# Kernel-backend toggle for the fused-engine tests (the nightly CI interpret
# leg): REPRO_IMPL=interpret re-runs them through the batched Pallas kernels
# in interpret mode instead of the jnp oracle (REPRO_BLOCK_L tunes the block
# size; small keeps interpret-mode padding cheap).  Default stays jnp — the
# tier-1 fast path.  Tests import FUSED_KW and splat it into fused-engine
# calls.
FUSED_IMPL = os.environ.get("REPRO_IMPL", "jnp")
FUSED_KW = {"impl": FUSED_IMPL}
if FUSED_IMPL != "jnp":
    FUSED_KW["block_l"] = int(os.environ.get("REPRO_BLOCK_L", "128"))
