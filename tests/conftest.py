import jax

# The QP solver tests need f64 (chess-board uses C=1e6).  Model smoke tests
# use explicit f32/bf16 dtypes, so the flag is harmless there.  The dry-run
# device-count flag is intentionally NOT set here (smoke tests see 1 device).
jax.config.update("jax_enable_x64", True)
