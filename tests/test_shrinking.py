"""Active-set shrinking differential tests: identical optima with the knob
on/off across engines (classic, fused soft-mask, chunked hard-compaction),
backends (jnp, interpret), and operators (SVC, doubled ε-SVR, one-class) —
plus regression guards for the degenerate-lane fixes (one-sided-box bias,
empty-endpoint KKT gap)."""

import dataclasses
import functools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import grid as grid_mod
from repro.core import qp as qp_mod
from repro.core.solver import (DEFAULT_SHRINK_EVERY, SolverConfig,
                               resolve_shrink_cfg, solve)
from repro.core.solver_fused import solve_fused_batched
from repro.svm.data import chessboard, xor_gaussians

# tighter than the default 1e-4: objective parity at 1e-6 needs the duals
# themselves converged past that scale
CFG = SolverConfig(eps=1e-5, max_iter=200_000)

IMPLS = [pytest.param("jnp", id="jnp"),
         pytest.param("interpret", id="interpret")]


def _kw(impl):
    return {"impl": impl} if impl == "jnp" else {"impl": impl, "block_l": 64}


def _obj_close(on, off):
    np.testing.assert_allclose(np.asarray(on), np.asarray(off),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("impl", IMPLS)
def test_grid_shrinking_objective_parity_chessboard(impl):
    """The tentpole differential: a (C, gamma) SVC grid on the paper's
    chess-board data reaches the SAME objectives with shrinking on and off,
    on both the jnp oracle and the masked Pallas kernels (interpret)."""
    X, y = chessboard(40, seed=0)
    Cs = np.array([1.0, 64.0])
    gammas = np.array([0.5])
    off = grid_mod.solve_grid(X, y, Cs, gammas, CFG, **_kw(impl))
    on = grid_mod.solve_grid(X, y, Cs, gammas, CFG, shrinking=True,
                             **_kw(impl))
    assert bool(jnp.all(off.converged)) and bool(jnp.all(on.converged))
    _obj_close(on.objective, off.objective)
    # converged lanes must report a FINITE gap (degenerate-lane regression)
    assert np.all(np.isfinite(np.asarray(on.kkt_gap)))
    assert np.all(np.isfinite(np.asarray(on.b)))


@pytest.mark.parametrize("impl", [
    pytest.param("jnp", id="jnp"),
    pytest.param("interpret", id="interpret", marks=pytest.mark.slow)])
def test_svr_grid_shrinking_objective_parity(impl):
    """Shrinking over the doubled ε-SVR operator: the (B, 2l) active mask
    rides the dup kernels; objectives match the unshrunk engine."""
    rng = np.random.default_rng(3)
    X = rng.normal(size=(32, 2))
    y = np.sin(2.0 * X[:, 0]) + 0.25 * X[:, 1]
    Cs, epss, gammas = [1.0, 16.0], [0.1], [1.0]
    off = grid_mod.solve_grid_svr(X, y, Cs, epss, gammas, CFG, **_kw(impl))
    on = grid_mod.solve_grid_svr(X, y, Cs, epss, gammas, CFG,
                                 shrinking=True, **_kw(impl))
    assert bool(jnp.all(off.converged)) and bool(jnp.all(on.converged))
    _obj_close(on.objective, off.objective)
    assert np.all(np.isfinite(np.asarray(on.kkt_gap)))
    assert np.all(np.asarray(on.n_unshrink) >= 0)


def test_forced_unshrink_and_resume():
    """Aggressive cadence forces the full unshrink cycle: a lane whose
    masked problem looks solved is reactivated (n_unshrink counts it),
    resumes, and still lands on the unshrunk optimum."""
    X, y = xor_gaussians(72, seed=4)
    Y = jnp.stack([jnp.asarray(y)])
    cfg = dataclasses.replace(CFG, shrink_every=8)
    off = solve_fused_batched(X, Y, 100.0, 0.5, cfg, impl="jnp")
    on = solve_fused_batched(X, Y, 100.0, 0.5, cfg, impl="jnp",
                             shrinking=True)
    assert bool(on.converged[0])
    assert int(on.n_unshrink[0]) >= 1
    assert int(off.n_unshrink[0]) == 0       # knob off: cycle never runs
    _obj_close(on.objective, off.objective)
    # convergence was declared on the FULL active set: the stored gap is
    # the true full-mask gap
    assert 0.0 <= float(on.kkt_gap[0]) <= CFG.eps


@functools.lru_cache(maxsize=1)
def _chunked_problem():
    X, y = xor_gaussians(48, seed=5)
    Cs = np.array([2.0, 24.0])
    gammas = np.array([0.4])
    vm = grid_mod.solve_grid(X, y, Cs, gammas, CFG)
    return X, y, Cs, gammas, vm


@pytest.mark.parametrize("precompute", [
    pytest.param(False, id="rbf-rows"),
    pytest.param(True, id="gram-bank", marks=pytest.mark.slow)])
def test_chunked_hard_compaction_parity(precompute):
    """The chunked driver with physical row compaction (lane AND row
    gathers between chunks) matches the vmapped oracle on both row
    sources, and its reconstructed final G is exact on every coordinate."""
    X, y, Cs, gammas, vm = _chunked_problem()
    comp = grid_mod.solve_grid_compacted(X, y, Cs, gammas, CFG, chunk=32,
                                         impl="jnp", precompute=precompute,
                                         shrinking=True)
    assert bool(jnp.all(comp.converged))
    np.testing.assert_allclose(np.asarray(comp.objective),
                               np.asarray(vm.objective), rtol=1e-6,
                               atol=1e-6)
    assert float(jnp.max(comp.kkt_gap)) <= CFG.eps
    # exactness of the reconstructed gradient: G == y - K alpha
    K = np.exp(-gammas[0] * np.asarray(grid_mod.sqdist(jnp.asarray(X))))
    a00 = np.asarray(comp.alpha[0, 0, 0])
    np.testing.assert_allclose(np.asarray(comp.G[0, 0, 0]),
                               np.asarray(y) - K @ a00, atol=1e-9)


def test_degenerate_one_sided_box_bias():
    """nu = 1.0 one-class: every alpha is pinned at the upper bound, so
    I_up is empty — the bias must fall back to the surviving endpoint and
    the gap must clamp finite (previously both were -inf)."""
    rng = np.random.default_rng(7)
    X = rng.normal(size=(24, 2))
    res = grid_mod.solve_grid_oneclass(X, [1.0], [0.5], CFG, impl="jnp")
    assert bool(jnp.all(res.converged))
    assert np.all(np.isfinite(np.asarray(res.b)))
    assert np.all(np.isfinite(np.asarray(res.kkt_gap)))


def test_degenerate_zero_C_lane():
    """A C = 0 lane (box collapsed to a point) converges at init with a
    finite zero gap and b = 0 — not NaN/-inf — while a live lane in the
    same batch solves normally."""
    X, y = xor_gaussians(48, seed=6)
    Y = jnp.stack([jnp.asarray(y), jnp.asarray(y)])
    res = solve_fused_batched(X, Y, jnp.asarray([0.0, 5.0]), 0.5, CFG,
                              impl="jnp", shrinking=True)
    assert bool(jnp.all(res.converged))
    assert np.all(np.isfinite(np.asarray(res.b)))
    np.testing.assert_array_equal(np.asarray(res.alpha[0]), 0.0)
    assert float(res.kkt_gap[0]) == 0.0
    assert int(res.iterations[0]) == 0


def test_classic_solver_shrinking_knob():
    """``solve(..., shrinking=True)`` on the standard engine: same optimum
    as the unshrunk solve, and the knob folds into cfg.shrink_every."""
    rng = np.random.default_rng(11)
    X = rng.normal(size=(36, 3))
    y = np.sign(rng.normal(size=36))
    y[y == 0] = 1.0
    kern = qp_mod.make_rbf(jnp.asarray(X), 0.8)
    off = solve(kern, jnp.asarray(y), 25.0, CFG)
    on = solve(kern, jnp.asarray(y), 25.0, CFG, shrinking=True)
    assert bool(off.converged) and bool(on.converged)
    _obj_close(on.objective, off.objective)
    assert np.isfinite(float(on.kkt_gap)) and np.isfinite(float(on.b))
    # knob resolution: None defers, True fills the default cadence, False
    # zeroes it; explicit cadences are preserved
    assert resolve_shrink_cfg(CFG, None) is CFG
    assert resolve_shrink_cfg(CFG, True).shrink_every == DEFAULT_SHRINK_EVERY
    cfg9 = dataclasses.replace(CFG, shrink_every=9)
    assert resolve_shrink_cfg(cfg9, True) is cfg9
    assert resolve_shrink_cfg(cfg9, False).shrink_every == 0
