"""Edge-case coverage: ring-cache wraparound, solver warm start and
degenerate QPs, windowed flash at long ranges, optimizer dtype configs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import TrainConfig
from repro.core import qp as qp_mod
from repro.core.solver import SolverConfig, solve
from repro.models import registry
from repro.svm.data import xor_gaussians


@pytest.mark.slow
class TestRingCacheWraparound:
    def test_sliding_window_decode_beyond_capacity(self):
        """Decode far past the ring capacity: the windowed model must match
        the full forward on the final positions (mixtral smoke, window 64,
        ring capacity < total length)."""
        cfg = get_smoke("mixtral-8x7b")  # sliding_window=64
        params = registry.init_params(jax.random.PRNGKey(0), cfg,
                                      jnp.float32)
        S_total, S_pre = 96, 16
        batch = registry.demo_batch(cfg, batch=1, seq=S_total, seed=0)
        logits_full, _ = registry.forward_logits(params, cfg, batch)

        prefix = {"tokens": batch["tokens"][:, :S_pre]}
        horizon = cfg.sliding_window  # ring capacity == window < S_total
        _, cache = registry.prefill(params, cfg, prefix, horizon,
                                    kv_dtype=jnp.float32)
        for t in range(S_pre, S_total):
            tok = batch["tokens"][:, t:t + 1]
            logits_t, cache = registry.decode_step(
                params, cfg, cache, tok, jnp.asarray(t, jnp.int32))
        # compare final-position logits (position S_total-1 writes at
        # S_total-1 slot; full forward sees identical window)
        np.testing.assert_allclose(np.asarray(logits_t[:, 0]),
                                   np.asarray(logits_full[:, -1]),
                                   rtol=2e-3, atol=2e-3)

    def test_rglru_long_decode_state_stability(self):
        """Hybrid decode far beyond the local window: states stay finite."""
        cfg = get_smoke("recurrentgemma-2b")
        params = registry.init_params(jax.random.PRNGKey(1), cfg,
                                      jnp.float32)
        cache = registry.init_cache(cfg, 1, cfg.local_window, jnp.float32)
        tok = jnp.zeros((1, 1), jnp.int32)
        for t in range(3 * cfg.local_window // 2):
            logits, cache = registry.decode_step(
                params, cfg, cache, tok, jnp.asarray(t, jnp.int32))
        assert bool(jnp.all(jnp.isfinite(logits)))
        assert bool(jnp.all(jnp.isfinite(cache.rec1.h)))


class TestSolverEdgeCases:
    def test_warm_start_resumes(self):
        """Solving to eps=1e-2 then warm-starting to 1e-5 must reach the
        same optimum as a cold 1e-5 solve, in fewer additional steps."""
        X, y = xor_gaussians(48, seed=0)
        kern = qp_mod.make_rbf(jnp.asarray(X), 0.5)
        yj = jnp.asarray(y)
        coarse = solve(kern, yj, 100.0,
                       SolverConfig(algorithm="pasmo", eps=1e-2))
        warm = solve(kern, yj, 100.0,
                     SolverConfig(algorithm="pasmo", eps=1e-5),
                     alpha0=coarse.alpha, G0=coarse.G)
        cold = solve(kern, yj, 100.0,
                     SolverConfig(algorithm="pasmo", eps=1e-5))
        assert bool(warm.converged)
        np.testing.assert_allclose(float(warm.objective),
                                   float(cold.objective), rtol=1e-7)
        assert int(warm.iterations) < int(cold.iterations)

    def test_already_optimal_input(self):
        """Warm start at the optimum: zero iterations."""
        X, y = xor_gaussians(40, seed=1)
        kern = qp_mod.make_rbf(jnp.asarray(X), 0.5)
        yj = jnp.asarray(y)
        r = solve(kern, yj, 100.0, SolverConfig(algorithm="pasmo",
                                                eps=1e-4))
        r2 = solve(kern, yj, 100.0, SolverConfig(algorithm="pasmo",
                                                 eps=1e-3),
                   alpha0=r.alpha, G0=r.G)
        assert int(r2.iterations) == 0
        assert bool(r2.converged)

    def test_tiny_problem(self):
        """l=2: one step to optimum."""
        K = jnp.asarray([[1.0, 0.2], [0.2, 1.0]], jnp.float64)
        y = jnp.asarray([1.0, -1.0], jnp.float64)
        r = solve(qp_mod.PrecomputedKernel(K), y, 10.0,
                  SolverConfig(algorithm="pasmo", eps=1e-8))
        assert bool(r.converged)
        # analytic: mu* = (y1-y2)/(K11-2K12+K22) = 2/1.6 = 1.25, interior
        np.testing.assert_allclose(np.asarray(r.alpha), [1.25, -1.25],
                                   rtol=1e-9)

    def test_duplicate_points_degenerate_kernel(self):
        """Duplicated rows make K singular (det(Q)=0 planning guards)."""
        rng = np.random.default_rng(2)
        X = rng.normal(size=(20, 3))
        X = np.concatenate([X, X[:10]])  # duplicates
        y = np.sign(rng.normal(size=30))
        y[:2] = [1, -1]
        kern = qp_mod.make_rbf(jnp.asarray(X), 0.7)
        r = solve(kern, jnp.asarray(y), 5.0,
                  SolverConfig(algorithm="pasmo", eps=1e-4,
                               max_iter=100_000))
        assert bool(r.converged)
        bounds = qp_mod.make_bounds(jnp.asarray(y), 5.0)
        assert bool(qp_mod.is_feasible(r.alpha, bounds, atol=1e-8))

    def test_all_same_class_trivial(self):
        """y all +1: alpha=0 is optimal (no violating pairs across classes
        ... gap = max G - min G over feasible dirs <= eps immediately?
        With all y=+1 the initial gradient is all ones and I_down empty
        except nothing > L=0... alpha=0: I_down empty -> gap = -inf."""
        y = jnp.ones((8,), jnp.float64)
        K = jnp.eye(8, dtype=jnp.float64)
        r = solve(qp_mod.PrecomputedKernel(K), y, 1.0,
                  SolverConfig(algorithm="smo", eps=1e-3))
        assert int(r.iterations) == 0
        np.testing.assert_allclose(np.asarray(r.alpha), 0.0)

    def test_shrinking_reactivation_correctness(self):
        """Aggressive shrinking interval still reaches the exact optimum."""
        X, y = xor_gaussians(56, seed=3)
        kern = qp_mod.make_rbf(jnp.asarray(X), 0.5)
        yj = jnp.asarray(y)
        base = solve(kern, yj, 40.0,
                     SolverConfig(algorithm="pasmo", eps=1e-5))
        for every in (4, 64):
            shr = solve(kern, yj, 40.0,
                        SolverConfig(algorithm="pasmo", eps=1e-5,
                                     shrink_every=every))
            assert bool(shr.converged)
            np.testing.assert_allclose(float(shr.objective),
                                       float(base.objective), rtol=1e-7)


@pytest.mark.slow
class TestFlashLongWindow:
    def test_window_band_long_sequence(self):
        """Windowed flash on a long sequence only schedules the band."""
        from repro.models.flash import _pairs, flash_attention
        Sq = Skv = 1024
        cq = ck = 64
        window = 128
        pairs = _pairs(Sq // cq, Skv // ck, True, window, cq, ck)
        tri = _pairs(Sq // cq, Skv // ck, True, 0, cq, ck)
        assert len(pairs) < 0.5 * len(tri)  # band << triangle

        rng = np.random.default_rng(0)
        B, KH, G, D = 1, 1, 2, 8
        q = jnp.asarray(rng.normal(size=(B, Sq, KH, G, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, Skv, KH, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, Skv, KH, D)), jnp.float32)
        pos = jnp.arange(Sq, dtype=jnp.int32)
        out = flash_attention(q, k, v, pos, pos, True, window, cq, ck)
        # reference on the last row only (cheap): softmax over the window
        s = (np.asarray(q)[0, -1, 0] @ np.asarray(k)[0, :, 0].T
             / np.sqrt(D))                       # (G, Skv)
        mask = (np.arange(Skv) > Sq - 1 - window)
        s = np.where(mask[None], s, -1e30)
        p = np.exp(s - s.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        ref_last = p @ np.asarray(v)[0, :, 0]
        np.testing.assert_allclose(np.asarray(out)[0, -1, 0], ref_last,
                                   rtol=2e-4, atol=2e-4)


class TestOptimizerDtypes:
    @pytest.mark.parametrize("opt_dtype", ["float32", "bfloat16"])
    def test_bf16_opt_state(self, opt_dtype):
        from repro.train import optimizer as opt
        tc = TrainConfig(optimizer="adamw", opt_state_dtype=opt_dtype,
                         learning_rate=0.05, weight_decay=0.0)
        p = {"w": jnp.ones((16,), jnp.float32)}
        state = opt.init(p, tc)
        assert jax.tree.leaves(state.m)[0].dtype == jnp.dtype(
            {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[opt_dtype])
        for _ in range(50):
            g = {"w": p["w"] * 0.1 + 1.0}
            p, state = opt.update(g, state, p, tc, lr=jnp.asarray(0.05))
        assert bool(jnp.all(jnp.isfinite(p["w"])))
        assert float(jnp.max(p["w"])) < 1.0  # descended
