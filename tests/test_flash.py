"""Flash attention (static triangle schedule + custom_vjp) vs the naive
reference: forward and gradients, causal / windowed / cross, shape sweep."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.flash import flash_attention, _pairs


def _naive(q, k, v, qp, kp, causal, window):
    B, Sq, KH, G, D = q.shape
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(D)
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= qp[:, None] >= kp[None, :]
    if window > 0:
        mask &= qp[:, None] - kp[None, :] < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


CASES = [
    # (Sq, Skv, cq, ck, causal, window)
    (64, 64, 16, 16, True, 0),
    (64, 64, 16, 16, False, 0),
    (128, 128, 32, 32, True, 48),
    (96, 96, 32, 32, True, 32),       # window == chunk
    (64, 128, 16, 32, False, 0),      # cross attention, uneven chunks
]


@pytest.mark.parametrize("Sq,Skv,cq,ck,causal,window", CASES)
def test_forward_matches_naive(Sq, Skv, cq, ck, causal, window):
    rng = np.random.default_rng(0)
    B, KH, G, D = 2, 2, 3, 16
    q = jnp.asarray(rng.normal(size=(B, Sq, KH, G, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Skv, KH, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Skv, KH, D)), jnp.float32)
    qp = jnp.arange(Sq, dtype=jnp.int32) + (Skv - Sq if causal else 0)
    kp = jnp.arange(Skv, dtype=jnp.int32)
    out = flash_attention(q, k, v, qp, kp, causal, window, cq, ck)
    ref = _naive(q, k, v, qp, kp, causal, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# gradient checks on the larger shapes are slow-tier
GRAD_CASES = CASES[:2] + [pytest.param(*c, marks=pytest.mark.slow)
                          for c in CASES[2:4]]


@pytest.mark.parametrize("Sq,Skv,cq,ck,causal,window", GRAD_CASES)
def test_grads_match_naive(Sq, Skv, cq, ck, causal, window):
    rng = np.random.default_rng(1)
    B, KH, G, D = 1, 2, 2, 8
    q = jnp.asarray(rng.normal(size=(B, Sq, KH, G, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Skv, KH, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Skv, KH, D)), jnp.float32)
    qp = jnp.arange(Sq, dtype=jnp.int32)
    kp = jnp.arange(Skv, dtype=jnp.int32)
    w = jnp.asarray(rng.normal(size=(B, Sq, KH, G, D)), jnp.float32)

    def f_flash(q, k, v):
        return jnp.sum(flash_attention(q, k, v, qp, kp, causal, window,
                                       cq, ck) * w)

    def f_naive(q, k, v):
        return jnp.sum(_naive(q, k, v, qp, kp, causal, window) * w)

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4)


def test_pair_schedule_triangle():
    """Causal schedule is the lower triangle: ~half the rectangle."""
    full = _pairs(8, 8, False, 0, 64, 64)
    tri = _pairs(8, 8, True, 0, 64, 64)
    assert len(full) == 64
    assert len(tri) == 36  # n(n+1)/2
    band = _pairs(8, 8, True, 128, 64, 64)
    assert len(band) < len(tri)  # window prunes further


def test_pair_schedule_respects_window_correctness():
    """No needed pair may be pruned: every unmasked (q, k) position must be
    covered by a scheduled pair."""
    cq = ck = 16
    Sq = Skv = 96
    for window in [16, 32, 50]:
        pairs = set(_pairs(Sq // cq, Skv // ck, True, window, cq, ck))
        for qpos in range(Sq):
            for kpos in range(Skv):
                visible = kpos <= qpos and qpos - kpos < window
                if visible:
                    assert (qpos // cq, kpos // ck) in pairs


def test_bf16_inputs():
    rng = np.random.default_rng(2)
    B, Sq, KH, G, D = 1, 64, 2, 2, 16
    q = jnp.asarray(rng.normal(size=(B, Sq, KH, G, D)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(B, Sq, KH, D)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(B, Sq, KH, D)), jnp.bfloat16)
    qp = jnp.arange(Sq, dtype=jnp.int32)
    out = flash_attention(q, k, v, qp, qp, True, 0, 16, 16)
    ref = _naive(q, k, v, qp, qp, True, 0)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=5e-2,
                               atol=5e-2)
