"""Checkpoint/restore: roundtrip, async, torn-write safety, elastic
re-shard, and bit-exact failure-replay resume."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.checkpoint import ckpt as ckpt_mod
from repro.configs import get_smoke
from repro.configs.base import TrainConfig
from repro.data import SyntheticTokens
from repro.runtime import FailureInjector, StepMonitor, run_resilient
from repro.train.train_step import init_state, make_train_step

TC = TrainConfig(param_dtype="float32", compute_dtype="float32",
                 accum_dtype="float32", learning_rate=1e-3, remat="none")


def _tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_roundtrip(tmp_path):
    state = {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
             "nested": {"b": jnp.asarray([1, 2, 3], jnp.int32)},
             "scalar": jnp.asarray(7, jnp.int32)}
    save_checkpoint(str(tmp_path), 5, state)
    assert latest_step(str(tmp_path)) == 5
    restored = restore_checkpoint(str(tmp_path), 5, state)
    _tree_equal(state, restored)


@pytest.mark.skipif(not ckpt_mod.HAS_ZSTD, reason="zstandard not installed")
def test_zstd_compressed_on_disk(tmp_path):
    """With zstd present, the snapshot is the compressed format."""
    state = {"w": jnp.zeros((256, 256), jnp.float32)}
    path = save_checkpoint(str(tmp_path), 1, state)
    assert os.path.exists(os.path.join(path, "state.msgpack.zst"))
    # all-zero payload must compress far below the raw 256 KiB
    assert os.path.getsize(os.path.join(path, "state.msgpack.zst")) \
        < 256 * 256 * 4 / 10


def test_uncompressed_fallback_roundtrip(tmp_path, monkeypatch):
    """Without zstd the checkpointer degrades to raw msgpack, and the
    restore path reads it back transparently."""
    monkeypatch.setattr(ckpt_mod, "HAS_ZSTD", False)
    state = {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)}
    path = save_checkpoint(str(tmp_path), 3, state)
    assert os.path.exists(os.path.join(path, "state.msgpack"))
    assert not os.path.exists(os.path.join(path, "state.msgpack.zst"))
    restored = restore_checkpoint(str(tmp_path), 3, state)
    _tree_equal(state, restored)


def test_torn_checkpoint_ignored(tmp_path):
    state = {"w": jnp.ones(4)}
    save_checkpoint(str(tmp_path), 1, state)
    # simulate a torn write: directory without COMMIT
    os.makedirs(tmp_path / "step_0000000002")
    (tmp_path / "step_0000000002" / "state.msgpack.zst").write_bytes(b"junk")
    assert latest_step(str(tmp_path)) == 1


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    state = {"w": jnp.ones((8, 8))}
    for s in [1, 2, 3, 4]:
        ck.save(s, jax.tree.map(lambda x: x * s, state))
    ck.close()
    assert latest_step(str(tmp_path)) == 4
    # keep=2 garbage-collects older checkpoints
    kept = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert len(kept) == 2
    r = restore_checkpoint(str(tmp_path), 4, state)
    np.testing.assert_allclose(np.asarray(r["w"]), 4.0)


def test_elastic_reshard_restore(tmp_path):
    """Save unsharded, restore under explicit NamedShardings (1-device mesh
    here; the 8-virtual-device variant runs in the dry-run test module)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh

    state = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    save_checkpoint(str(tmp_path), 1, state)
    mesh = make_host_mesh(1, 1)
    sh = {"w": NamedSharding(mesh, P("data", "model"))}
    r = restore_checkpoint(str(tmp_path), 1, state, shardings=sh)
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(state["w"]))
    assert r["w"].sharding == sh["w"]


@pytest.mark.slow
def test_resilient_run_bit_exact_after_failures(tmp_path):
    """Kill the loop twice; the final state must equal the uninterrupted
    run (deterministic pipeline + step replay)."""
    cfg = get_smoke("qwen2-0.5b")
    state0 = init_state(jax.random.PRNGKey(0), cfg, TC)
    step = jax.jit(make_train_step(cfg, TC))
    data = SyntheticTokens(vocab=cfg.vocab, seq_len=16, global_batch=2)

    def batch_at(s):
        return {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}

    ref = state0
    for s in range(12):
        ref, _ = step(ref, batch_at(s))

    inj = FailureInjector(fail_at=[4, 9])
    final = run_resilient(step, state0, batch_at, n_steps=12,
                          ckpt_dir=str(tmp_path / "ck"), save_every=3,
                          injector=inj)
    assert inj.fired == {4, 9}
    for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(
            final.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_step_monitor_flags_stragglers():
    mon = StepMonitor(deadline_factor=2.0, warmup_steps=1)
    flags = [mon.record(dt) for dt in
             [5.0, 1.0, 1.0, 1.0, 1.1, 0.9, 5.0, 1.0]]
    assert flags[6] is True       # the straggler step
    assert sum(flags) == 1
    assert mon.slow_steps == 1
