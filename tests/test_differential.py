"""Seeded differential tests: PA-SMO vs SMO on random QPs and the paper's
chess-board problem (Table 2's headline effect, as a regression guard)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qp as qp_mod
from repro.core.solver import SolverConfig, solve
from repro.svm.data import chessboard


def _random_qp(seed, n):
    """Random RBF QP in a generalizing C regime (same family as the
    property tests, but with pinned seeds: deterministic in CI)."""
    rng = np.random.default_rng(seed)
    d = int(rng.integers(2, 6))
    X = rng.normal(size=(n, d))
    gamma = float(10 ** rng.uniform(-1.5, 0.5))
    sq = np.sum(X * X, 1)
    K = np.exp(-gamma * (sq[:, None] + sq[None, :] - 2 * X @ X.T))
    y = np.sign(rng.normal(size=n))
    if np.all(y == y[0]):
        y[0] = -y[0]
    C = float(10 ** rng.uniform(-1, 3))
    return jnp.asarray(K), jnp.asarray(y), C


@pytest.mark.parametrize("seed", range(8))
def test_pasmo_smo_same_objective(seed):
    """Both algorithms converge to the same dual optimum within eps-scale."""
    eps = 1e-5
    K, y, C = _random_qp(seed, n=48)
    kern = qp_mod.PrecomputedKernel(K)
    cfg = dict(eps=eps, max_iter=200_000)
    r_smo = solve(kern, y, C, SolverConfig(algorithm="smo", **cfg))
    r_pa = solve(kern, y, C, SolverConfig(algorithm="pasmo", **cfg))
    assert bool(r_smo.converged) and bool(r_pa.converged)
    f_s, f_p = float(r_smo.objective), float(r_pa.objective)
    assert abs(f_p - f_s) <= 1e-4 * (1.0 + abs(f_s))


@pytest.mark.parametrize(
    "n,seed", [(240, 0), pytest.param(400, 1, marks=pytest.mark.slow)])
def test_pasmo_fewer_iterations_on_chessboard(n, seed):
    """The paper's central claim on its hard problem: planning-ahead needs
    no more iterations than plain SMO (Table 2 shows ~20-40% fewer)."""
    X, y = chessboard(n, seed=seed)
    kern = qp_mod.make_rbf(jnp.asarray(X), 0.5)
    cfg = dict(eps=1e-3, max_iter=500_000)
    r_smo = solve(kern, jnp.asarray(y), 1000.0,
                  SolverConfig(algorithm="smo", **cfg))
    r_pa = solve(kern, jnp.asarray(y), 1000.0,
                 SolverConfig(algorithm="pasmo", **cfg))
    assert bool(r_smo.converged) and bool(r_pa.converged)
    assert int(r_pa.iterations) <= int(r_smo.iterations)
    # planning must actually engage, and both reach the same optimum
    assert int(r_pa.n_planning) > 0
    np.testing.assert_allclose(float(r_pa.objective),
                               float(r_smo.objective), rtol=1e-5)
