"""Tier-1 coverage for the static-analysis subsystem (repro.analysis).

Negative controls prove the passes detect what they claim to detect
(a planted f64 cast, a planted widened carry, a planted static-argument
recompile leak, one lint fixture per rule); positive controls prove HEAD
is clean.  The full 13-entry matrix runs in the CI ``static-analysis``
job via ``python -m repro.analysis`` — tests here use small subsets to
keep tier-1 fast.
"""

import json
import os
import subprocess
import sys

from repro.analysis import jaxpr_audit, lint_rules, recompile_guard

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, cwd=REPO, env=env)


# ---------------------------------------------------------------------------
# jaxpr auditor
# ---------------------------------------------------------------------------

def test_planted_f64_cast_is_caught():
    finds = jaxpr_audit.plant_f64()
    assert finds, "planted float64 cast slipped through the dtype audit"
    assert all(f.check.startswith("dtype") for f in finds)


def test_planted_widened_carry_is_caught():
    finds = jaxpr_audit.plant_widened_carry()
    assert any(f.check == "struct-carry" for f in finds), \
        "telemetry ring widens the while carry; the structural " \
        "comparison must see it"


def test_dtype_audit_clean_on_head_subset():
    # one fused + one classic entry as the tier-1 canary; the CI job
    # audits the whole matrix
    finds = jaxpr_audit.audit_all_dtypes(["plain_jnp", "classic_smo"])
    assert finds == [], "\n".join(f.render() for f in finds)


def test_structural_golden_covers_pinned_entries():
    with open(jaxpr_audit.default_golden_path()) as fh:
        golden = json.load(fh)
    assert set(jaxpr_audit.PINNED) <= set(golden["entries"])


def test_census_artifact_schema(tmp_path):
    paths = jaxpr_audit.emit_census(str(tmp_path), names=["plain_jnp"])
    assert len(paths) == 1
    with open(paths[0]) as fh:
        payload = json.load(fh)
    assert payload["entry"] == "plain_jnp"
    assert payload["primitives"].get("while") == 1
    assert payload["carries"] and payload["dtypes"]


# ---------------------------------------------------------------------------
# recompile guard
# ---------------------------------------------------------------------------

def test_recompile_guard_exact_on_2x2_sweep():
    findings = []
    recompile_guard.probe_fused_c_gamma(findings)   # 2 C x 2 gamma
    assert findings == [], "\n".join(f.render() for f in findings)


def test_recompile_guard_grid_counts_exact():
    findings = []
    recompile_guard.probe_grid_values(findings)
    assert findings == [], "\n".join(f.render() for f in findings)


def test_recompile_guard_catches_static_leak():
    finds = recompile_guard.plant_excess_recompile()
    assert [f.check for f in finds] == ["recompile-count"]


# ---------------------------------------------------------------------------
# repo-invariant linter
# ---------------------------------------------------------------------------

def test_lint_clean_on_repo():
    finds = lint_rules.run_lint()
    assert finds == [], "\n".join(f.render() for f in finds)


def test_lint_fixtures_trigger_each_rule_once():
    finds = lint_rules.run_fixtures()
    assert sorted(f.check for f in finds) == \
        ["RA001", "RA002", "RA003", "RA004"], \
        "\n".join(f.render() for f in finds)


def test_result_pins_match_source():
    # the pinned field tuples must track the real structs, else RA003
    # would fire on (or worse, miss) every run
    from repro.core.solver import SolveResult
    from repro.core.solver_fused import FusedResult
    import dataclasses

    assert tuple(f.name for f in dataclasses.fields(SolveResult)) == \
        lint_rules.RESULT_PINS["SolveResult"]
    assert tuple(f.name for f in dataclasses.fields(FusedResult)) == \
        lint_rules.RESULT_PINS["FusedResult"]


# ---------------------------------------------------------------------------
# CLI exit codes (lint paths only: no jax startup cost in a subprocess)
# ---------------------------------------------------------------------------

def test_cli_lint_exits_zero_on_head():
    proc = _cli("--lint")
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_plant_lint_exits_nonzero():
    proc = _cli("--plant", "lint")
    assert proc.returncode != 0
    assert "RA00" in proc.stdout
