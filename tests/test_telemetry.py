"""Flight recorder (``repro.telemetry``): rings, sinks, drivers, report.

The load-bearing guarantee is structural: with ``telemetry=None`` the
fused engine must trace a jaxpr BYTE-IDENTICAL to the pre-telemetry
engine — the recorder is free when off, not merely cheap.  The goldens
under ``tests/golden/fused_jaxpr_*.txt`` were captured from the engine
BEFORE the telemetry seam existed; their first line records the jax
version that printed them (jaxpr pretty-printing is not stable across
jax versions, so the byte comparison only runs on a matching version —
the CI floor pin — and other versions fall back to a structural check).
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import FUSED_KW, run_multidevice
from repro.analysis import jaxpr_audit
from repro.core import grid as grid_mod
from repro.core.solver import SolverConfig, solve
from repro.core.solver_fused import solve_fused_batched, solve_fused_batched_qp
from repro.core import qp as qp_mod
from repro.launch import telemetry_report as report_mod
from repro.telemetry import (Diagnostics, JsonlSink, RingConfig,
                             env_fingerprint, fingerprint_diff, phase_scope,
                             read_jsonl, ring_init, ring_update)



def _rbf_problem(B=3, l=16, d=4, seed=0):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(l, d)))
    Y = jnp.asarray(np.sign(rng.normal(size=(B, l))))
    C = 2.0
    YC = Y * C
    L, U = jnp.minimum(0.0, YC), jnp.maximum(0.0, YC)
    gam = jnp.asarray(rng.uniform(0.3, 1.0, B))
    return X, Y, L, U, gam


def _capture_jaxpr(**kw) -> str:
    """In-process jaxpr capture for structural (not byte-level) checks.

    Byte-level golden comparisons go through ``golden_fresh_capture``
    instead — printed bytes depend on in-process tracing-cache state.
    """
    X, P, L, U, gam = _rbf_problem()
    cfg = SolverConfig(eps=1e-3, max_iter=500)
    return str(jax.make_jaxpr(
        lambda X, P, L, U, g: solve_fused_batched_qp(
            X, P, L, U, g, cfg, **kw))(X, P, L, U, gam))


# ---------------------------------------------------------------------------
# telemetry=None is structurally free
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("entry", [
    "plain_jnp",
    "plain_shrink_jnp",
    "plain_interpret",
])
def test_jaxpr_structure_matches_pretelemetry_golden(entry):
    # structural audit (eqn-primitive multiset + while-carry pytree)
    # against tests/golden/structural.json — replaces the retired byte
    # diff of fused_jaxpr_*.txt, which broke on every pretty-printer
    # change; the carry check runs on EVERY jax version, the primitive
    # multiset only on the pinned one (same scope the byte test had).
    # The .txt goldens remain as regen fixtures (tests/golden/regen.py).
    jaxpr_audit.assert_structural(entry)


def test_jaxpr_off_is_invariant_to_telemetry_use():
    # version-independent structural check: tracing a telemetry-on solve
    # must not perturb the telemetry-off jaxpr (no cache/trace bleed),
    # and the on-jaxpr must be strictly larger (the ring rides the carry)
    before = _capture_jaxpr(impl="jnp")
    on = _capture_jaxpr(impl="jnp", telemetry=RingConfig())
    after = _capture_jaxpr(impl="jnp")
    assert before == after
    assert len(on) > len(before)


def test_telemetry_does_not_perturb_the_solve():
    X, Y, L, U, gam = _rbf_problem()
    cfg = SolverConfig(eps=1e-3, max_iter=500)
    base = solve_fused_batched_qp(X, Y, L, U, gam, cfg, **FUSED_KW)
    res, ring = solve_fused_batched_qp(X, Y, L, U, gam, cfg,
                                       telemetry=RingConfig(sample_every=8),
                                       **FUSED_KW)
    assert np.array_equal(np.asarray(base.iterations),
                          np.asarray(res.iterations))
    assert np.array_equal(np.asarray(base.converged),
                          np.asarray(res.converged))
    np.testing.assert_allclose(np.asarray(base.alpha), np.asarray(res.alpha),
                               rtol=1e-12, atol=0)
    # every lane ends with a forced freeze sample at t = iterations - 1
    ns = np.asarray(ring.n_samples)
    t = np.asarray(ring.t)
    for lane in range(3):
        assert t[lane, min(ns[lane], 128) - 1] == \
            int(res.iterations[lane]) - 1


# ---------------------------------------------------------------------------
# ring semantics
# ---------------------------------------------------------------------------

def test_ring_overflow_oldest_wins():
    cfg = RingConfig(sample_every=1, cap=4, ratio_cap=3)
    ring = ring_init(cfg, 2, jnp.float64)
    on = jnp.ones(2, bool)
    off = jnp.zeros(2, bool)
    for t in range(7):
        ring = ring_update(
            ring, cfg, t=jnp.asarray(t), active=on, newly_done=off,
            gap=jnp.full(2, 10.0 - t), n_active=jnp.full(2, 5, jnp.int32),
            n_unshrink=jnp.zeros(2, jnp.int32),
            plan_event=jnp.asarray([True, False]),
            ratio=jnp.full(2, 1.0 + t))
    # count free-runs past cap (overflow detectable)...
    assert np.all(np.asarray(ring.n_samples) == 7)
    # ...first cap-1 slots keep the OLDEST samples verbatim...
    assert np.asarray(ring.t)[0, :3].tolist() == [0, 1, 2]
    # ...and the last slot holds the NEWEST
    assert np.asarray(ring.t)[0, 3] == 6
    assert np.asarray(ring.gap)[0, 3] == 4.0
    # event channel: lane 0 got 7 events into cap 3, lane 1 none
    assert np.asarray(ring.n_ratio).tolist() == [7, 0]
    assert np.asarray(ring.ratio)[0].tolist() == [1.0, 2.0, 7.0]
    assert np.all(np.asarray(ring.ratio)[1] == 0.0)


def test_ring_respects_active_and_sample_every():
    cfg = RingConfig(sample_every=4, cap=8, ratio_cap=4)
    ring = ring_init(cfg, 2, jnp.float64)
    off = jnp.zeros(2, bool)
    for t in range(9):
        active = jnp.asarray([True, t < 2])
        ring = ring_update(
            ring, cfg, t=jnp.asarray(t), active=active,
            newly_done=jnp.asarray([False, t == 1]),
            gap=jnp.full(2, float(t)), n_active=jnp.full(2, 9, jnp.int32),
            n_unshrink=jnp.zeros(2, jnp.int32), plan_event=off,
            ratio=jnp.zeros(2))
    # lane 0: periodic samples at t = 0, 4, 8
    assert np.asarray(ring.t)[0, :3].tolist() == [0, 4, 8]
    assert np.asarray(ring.n_samples).tolist() == [3, 2]
    # lane 1 froze at t=1: periodic t=0 plus the forced freeze sample
    assert np.asarray(ring.t)[1, :2].tolist() == [0, 1]


# ---------------------------------------------------------------------------
# Fig. 3 parity: fused ratio channel == classic record_trace
# ---------------------------------------------------------------------------

def test_fig3_ratio_parity_with_classic_record_trace():
    rng = np.random.default_rng(3)
    l, d, gamma, C = 24, 3, 0.8, 2.0
    X = jnp.asarray(rng.normal(size=(l, d)))
    y = jnp.asarray(np.where(rng.normal(size=l) >= 0, 1.0, -1.0))
    cfg = SolverConfig(eps=1e-4, max_iter=1000, record_trace=True)
    K = jnp.exp(-gamma * grid_mod.sqdist(X))
    classic = solve(qp_mod.PrecomputedKernel(K), y, C, cfg)

    res, ring = solve_fused_batched(
        X, y[None], C, gamma, SolverConfig(eps=1e-4, max_iter=1000),
        telemetry=RingConfig(ratio_cap=256), **FUSED_KW)
    assert int(res.iterations[0]) == int(classic.iterations)
    n = int(classic.n_trace)
    assert int(ring.n_ratio[0]) == n == int(classic.n_planning)
    np.testing.assert_allclose(np.asarray(ring.ratio)[0, :n],
                               np.asarray(classic.trace)[:n],
                               rtol=1e-9, atol=0)
    # the driver-level trace fields carry the same channel
    diag = Diagnostics(ring=RingConfig(ratio_cap=256))
    gres = grid_mod.solve_grid(X, y[None], np.array([C]), np.array([gamma]),
                               SolverConfig(eps=1e-4, max_iter=1000),
                               impl=FUSED_KW["impl"],
                               block_l=FUSED_KW.get("block_l", 1024),
                               diagnostics=diag)
    assert int(gres.n_trace[0, 0, 0]) == n
    np.testing.assert_allclose(np.asarray(gres.trace)[0, 0, 0, :n],
                               np.asarray(classic.trace)[:n],
                               rtol=1e-9, atol=0)


# ---------------------------------------------------------------------------
# drivers: grid drain, C-order permutation, chunked merge
# ---------------------------------------------------------------------------

def _grid_problem(l=24, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(l, 3))
    y = np.sign(rng.normal(size=l))
    y[y == 0] = 1
    return X, np.stack([y, -y])


def test_solve_grid_drains_lanes_in_caller_order():
    X, Y = _grid_problem()
    gammas = np.array([0.5, 1.0])
    Cs = np.array([2.0, 0.5, 1.0])        # unsorted: exercises the C perm
    cfg = SolverConfig(eps=1e-3, max_iter=300)
    diag = Diagnostics(ring=RingConfig(sample_every=8))
    res = grid_mod.solve_grid(X, Y, Cs, gammas, cfg, diagnostics=diag,
                              **FUSED_KW)
    ref = grid_mod.solve_grid(X, Y, Cs, gammas, cfg, **FUSED_KW)
    assert np.array_equal(np.asarray(res.iterations),
                          np.asarray(ref.iterations))
    assert len(diag.lanes) == 2 * 2 * 3
    it = np.asarray(res.iterations)
    for lane, rec in enumerate(diag.lanes):
        gi, rem = divmod(lane, 2 * 3)
        ci, Ci = divmod(rem, 3)
        assert rec["gamma"] == gammas[gi]
        assert rec["label"] == ci
        assert rec["C"] == Cs[Ci]
        assert rec["iterations"] == int(it[gi, ci, Ci])
        assert rec["n_ratio"] == rec["n_planning"]
    s = diag.summary(top_k=3)
    assert s["n_lanes"] == 12 and s["n_converged"] == 12
    assert len(s["stragglers"]) == 3
    assert s["stragglers"][0]["iterations"] == int(it.max())


def test_chunked_driver_merges_and_rebases_rings():
    X, Y = _grid_problem(l=32, seed=1)
    gammas = np.array([0.5, 1.0])
    Cs = np.array([0.5, 2.0])
    cfg = SolverConfig(eps=1e-3, max_iter=400)
    diag = Diagnostics(ring=RingConfig(sample_every=4, cap=64))
    res = grid_mod.solve_grid_compacted(X, Y, Cs, gammas, cfg, chunk=16,
                                        diagnostics=diag, **FUSED_KW)
    ref = grid_mod.solve_grid_compacted(X, Y, Cs, gammas, cfg, chunk=16,
                                        **FUSED_KW)
    assert np.array_equal(np.asarray(res.iterations),
                          np.asarray(ref.iterations))
    it = np.asarray(res.iterations).reshape(-1)
    assert len(diag.lanes) == it.size
    for lane, rec in enumerate(diag.lanes):
        assert rec["iterations"] == int(it[lane])
        ts = rec["samples"]["t"]
        # chunk-local stamps were rebased to a strictly increasing
        # run-global sequence ending before the lane's final iteration
        assert all(a < b for a, b in zip(ts, ts[1:]))
        assert ts[-1] <= rec["iterations"]
        assert rec["n_ratio"] == rec["n_planning"]
    rounds = [e for e in diag.sink.events
              if e["event"] == "phase" and e.get("name") == "chunk_solve"]
    assert len(rounds) >= 2          # chunk=16 forces multiple rounds
    assert all(e["seconds"] > 0 for e in rounds)


def test_grid_rejects_diagnostics_on_classic_path():
    X, Y = _grid_problem()
    with pytest.raises(ValueError, match="diagnostics"):
        grid_mod.solve_grid(X, Y, np.array([1.0]), np.array([0.5]),
                            SolverConfig(), diagnostics=Diagnostics())


def test_svr_and_oneclass_grid_drains():
    X, _ = _grid_problem(l=28, seed=2)
    rng = np.random.default_rng(2)
    y = np.sin(np.asarray(X)[:, 0]) + 0.1 * rng.normal(size=28)
    cfg = SolverConfig(eps=1e-3, max_iter=400)
    diag = Diagnostics(ring=RingConfig(sample_every=8))
    out = grid_mod.solve_grid_svr(X, y, np.array([0.5, 2.0]),
                                  np.array([0.05, 0.1]),
                                  np.array([0.5, 1.0]), cfg,
                                  diagnostics=diag, **FUSED_KW)
    assert len(diag.lanes) == 8
    it = np.asarray(out.iterations).reshape(-1)
    assert [r["iterations"] for r in diag.lanes] == [int(v) for v in it]
    assert {"gamma", "epsilon", "C"} <= set(diag.lanes[0])

    diag2 = Diagnostics(ring=RingConfig(sample_every=8))
    out2 = grid_mod.solve_grid_oneclass(X, np.array([0.2, 0.5]),
                                        np.array([0.5, 1.0]), cfg,
                                        diagnostics=diag2, **FUSED_KW)
    assert len(diag2.lanes) == 4
    it2 = np.asarray(out2.iterations).reshape(-1)
    assert [r["iterations"] for r in diag2.lanes] == [int(v) for v in it2]
    assert {"gamma", "nu"} <= set(diag2.lanes[0])


# ---------------------------------------------------------------------------
# sharded engine: rings gather back in lane order
# ---------------------------------------------------------------------------

def test_sharded_ring_matches_batched_single_device():
    # 1-device mesh is the degenerate shard_map: ring must be bitwise
    # the batched engine's ring
    from repro.core.sharded_lanes import solve_fused_sharded
    X, Y = _grid_problem()
    cfg = SolverConfig(eps=1e-3, max_iter=300)
    rc = RingConfig(sample_every=8)
    rs, ring_s = solve_fused_sharded(X, jnp.asarray(Y), 1.0, 0.8, cfg,
                                     telemetry=rc, **FUSED_KW)
    rb, ring_b = solve_fused_batched(X, jnp.asarray(Y), 1.0, 0.8, cfg,
                                     telemetry=rc, **FUSED_KW)
    assert np.array_equal(np.asarray(rs.iterations),
                          np.asarray(rb.iterations))
    for a, b in zip(jax.tree.leaves(ring_s), jax.tree.leaves(ring_b)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_sharded_ring_gather_back_multidevice():
    # heterogeneous lanes over 4 forced host devices: the round-robin
    # deal permutes lanes across shards, so ring rows coming back in
    # caller order is exactly the gather-back property under test
    out = run_multidevice(textwrap.dedent("""
        import jax
        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp
        import numpy as np
        from repro.core.sharded_lanes import solve_fused_sharded
        from repro.core.solver_fused import solve_fused_batched
        from repro.core.solver import SolverConfig
        from repro.telemetry import RingConfig

        rng = np.random.default_rng(0)
        X = jnp.asarray(rng.normal(size=(24, 3)))
        y = jnp.asarray(np.where(rng.normal(size=24) >= 0, 1.0, -1.0))
        Y = jnp.stack([y, -y, y, -y])
        gam = jnp.asarray([0.3, 0.6, 1.0, 1.5])
        C = jnp.asarray([8.0, 0.5, 2.0, 1.0])
        cfg = SolverConfig(eps=1e-3, max_iter=500)
        rc = RingConfig(sample_every=8)
        rs, ring_s = solve_fused_sharded(X, Y, C, gam, cfg, impl="jnp",
                                         telemetry=rc)
        rb, ring_b = solve_fused_batched(X, Y, C, gam, cfg, impl="jnp",
                                         telemetry=rc)
        assert np.array_equal(np.asarray(rs.iterations),
                              np.asarray(rb.iterations))
        for a, b in zip(jax.tree.leaves(ring_s), jax.tree.leaves(ring_b)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-12, atol=0)
        # stamps are integer channels: must be exactly equal
        assert np.array_equal(np.asarray(ring_s.t), np.asarray(ring_b.t))
        assert np.array_equal(np.asarray(ring_s.n_samples),
                              np.asarray(ring_b.n_samples))
        print("GATHER_OK")
    """), n_devices=4)
    assert "GATHER_OK" in out


# ---------------------------------------------------------------------------
# facades
# ---------------------------------------------------------------------------

def test_facades_drain_diagnostics():
    from repro.svm import SVC, SVR, OneClassSVM
    rng = np.random.default_rng(2)
    X = rng.normal(size=(40, 3))
    y = (X[:, 0] + X[:, 1] > 0).astype(int)
    yr = np.sin(X[:, 0])
    rc = RingConfig(sample_every=8)

    d = Diagnostics(ring=rc)
    clf = SVC(C=2.0, gamma=0.7, impl=FUSED_KW["impl"],
              diagnostics=d).fit(X, y)
    ref = SVC(C=2.0, gamma=0.7, impl=FUSED_KW["impl"]).fit(X, y)
    assert np.array_equal(np.asarray(clf.alpha_), np.asarray(ref.alpha_))
    assert len(d.lanes) == 1
    assert d.lanes[0]["C"] == 2.0 and d.lanes[0]["label"] == 1
    assert any(e["event"] == "phase" and e["name"] == "svc_fit"
               for e in d.sink.events)

    d2 = Diagnostics(ring=rc)
    reg = SVR(C=2.0, epsilon=0.1, gamma=0.7, impl=FUSED_KW["impl"],
              diagnostics=d2).fit(X, yr)
    assert len(d2.lanes) == 1
    assert d2.lanes[0]["epsilon"] == 0.1
    assert d2.lanes[0]["iterations"] == int(reg.fit_result_.iterations)

    d3 = Diagnostics(ring=rc)
    oc = OneClassSVM(nu=0.3, gamma=0.7, impl=FUSED_KW["impl"],
                     diagnostics=d3).fit(X)
    assert len(d3.lanes) == 1
    assert d3.lanes[0]["nu"] == 0.3
    assert d3.lanes[0]["iterations"] == int(oc.fit_result_.iterations)

    # host-only diagnostics on the classic engine: phases, no lanes
    d4 = Diagnostics(ring=None)
    SVC(C=1.0, gamma=0.7, plan_candidates=2, impl=FUSED_KW["impl"],
        diagnostics=d4).fit(X, y)
    assert d4.lanes == []
    assert any(e["event"] == "phase" for e in d4.sink.events)


# ---------------------------------------------------------------------------
# sink, fingerprint, report CLI
# ---------------------------------------------------------------------------

def test_env_fingerprint_and_diff():
    fp = env_fingerprint()
    for key in ("jax_version", "backend", "device_kind", "device_count",
                "cpu_count", "host", "python", "machine"):
        assert key in fp
    assert fp["jax_version"] == jax.__version__
    assert len(fp["host"]) == 12          # hashed, not the raw hostname
    assert fingerprint_diff(fp, fp) == []
    other = dict(fp, backend="tpu", device_count=8)
    lines = fingerprint_diff(fp, other)
    assert any("backend" in ln for ln in lines)
    assert any("device_count" in ln for ln in lines)


def test_jsonl_sink_roundtrip(tmp_path):
    path = tmp_path / "events.jsonl"
    with JsonlSink(path) as sink:
        sink.emit("fingerprint", **env_fingerprint())
        with phase_scope("unit_phase", sink, tag=1):
            pass
        sink.emit("lane", lane=0, gap=jnp.asarray(0.5),
                  ts_list=np.arange(3))
    events = read_jsonl(path)
    assert [e["event"] for e in events] == ["fingerprint", "phase", "lane"]
    assert events[1]["name"] == "unit_phase"
    assert events[1]["seconds"] >= 0.0
    assert events[2]["gap"] == 0.5        # jax/numpy coerced to plain
    assert events[2]["ts_list"] == [0, 1, 2]


def test_telemetry_report_cli_golden_smoke(tmp_path, capsys):
    X, Y = _grid_problem()
    path = tmp_path / "run.jsonl"
    diag = Diagnostics(path, ring=RingConfig(sample_every=8))
    grid_mod.solve_grid(X, Y, np.array([0.5, 2.0]), np.array([0.5, 1.0]),
                        SolverConfig(eps=1e-3, max_iter=300),
                        diagnostics=diag, **FUSED_KW)
    summary = diag.finalize()
    assert summary["n_lanes"] == 8

    rc = report_mod.main([str(path), "--trace-lane", "0", "--hist"])
    assert rc == 0
    out = capsys.readouterr().out
    for section in ("## environment", "## host phases", "## convergence",
                    "## stragglers", "## iteration histogram",
                    "## planning trace (Fig. 3), lane 0", "## summary"):
        assert section in out
    assert f"jax_version | {jax.__version__}" in out
    # 8 lanes in the convergence table, keyed by their grid cell
    assert out.count("g=0.5") >= 4 and "C=2" in out
    assert "accepted planning steps" in out
    # straggler math: shares sum to <= 100 and the table is ranked
    assert "% of all iterations" in out


def test_telemetry_report_renders_straggler_warnings():
    events = [
        {"event": "lane", "lane": 0, "iterations": 10, "gamma": 0.5,
         "C": 1.0, "n_samples": 1, "samples": {"t": [0], "gap": [1.0],
                                               "n_active": [4],
                                               "n_unshrink": [0]},
         "ratio": {"t": [], "value": []}, "n_ratio": 0},
        {"event": "straggler_warning", "round": 3, "seconds": 9.5,
         "deadline": 3.0, "lanes": [0, 7], "rows": 128},
    ]
    text = report_mod.render_report(events)
    assert "chunk deadline breached" in text
    assert "round 3" in text and "9.5" in text


def test_report_cli_subprocess_entrypoint(tmp_path):
    # `python -m repro.launch.telemetry_report` is the documented entry
    path = tmp_path / "mini.jsonl"
    with JsonlSink(path) as sink:
        sink.emit("fingerprint", **env_fingerprint())
        sink.emit("lane", lane=0, iterations=5, gamma=1.0, C=1.0,
                  converged=True, kkt_gap=1e-4, n_planning=2,
                  total_unshrink=0, n_samples=1, n_ratio=0,
                  samples={"t": [0], "gap": [0.5], "n_active": [4],
                           "n_unshrink": [0]},
                  ratio={"t": [], "value": []})
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(p for p in (
        os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                     "src")),
        env.get("PYTHONPATH")) if p)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.telemetry_report", str(path)],
        capture_output=True, text=True, env=env, timeout=120)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "## convergence" in proc.stdout


def test_bench_gate_fingerprint_note(capsys):
    sys.path.insert(0, os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..")))
    try:
        from benchmarks.bench_gate import _fingerprint_note
    finally:
        sys.path.pop(0)
    fp = env_fingerprint()
    _fingerprint_note({"fingerprint": fp}, {"fingerprint": fp})
    assert "matches" in capsys.readouterr().out
    _fingerprint_note({"fingerprint": dict(fp, backend="tpu")},
                      {"fingerprint": fp})
    out = capsys.readouterr().out
    assert "environment differs" in out and "backend" in out
    _fingerprint_note({}, {"fingerprint": fp})
    assert "no environment fingerprint" in capsys.readouterr().out
