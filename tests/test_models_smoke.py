"""Per-architecture smoke tests: reduced same-family configs, one forward +
one grad step on CPU, asserting shapes and finiteness; plus decode-vs-full
consistency and the SSD/RG-LRU recurrence oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke
from repro.models import registry

F32 = jnp.float32

# Tier-1 keeps one cheap representative arch; the rest ride in the slow
# tier (full run: pytest -m "").
_LIGHT_ARCHS = {"deepseek-7b"}


def _tiered(archs):
    return [a if a in _LIGHT_ARCHS else pytest.param(a, marks=pytest.mark.slow)
            for a in archs]


@pytest.mark.parametrize("arch", _tiered(ARCHS))
def test_forward_and_grad_step(arch):
    cfg = get_smoke(arch)
    params = registry.init_params(jax.random.PRNGKey(0), cfg, F32)
    batch = registry.demo_batch(cfg, batch=2, seq=32)

    logits, _ = registry.forward_logits(params, cfg, batch)
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    loss, metrics = registry.loss_fn(params, cfg, batch)
    assert bool(jnp.isfinite(loss))

    grads = jax.grad(lambda p: registry.loss_fn(p, cfg, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
               for g in flat)
    # at least one nonzero gradient per model
    assert any(float(jnp.max(jnp.abs(g.astype(jnp.float32)))) > 0
               for g in flat)


@pytest.mark.slow
@pytest.mark.parametrize("arch", _tiered(ARCHS))
def test_remat_matches_no_remat(arch):
    cfg = get_smoke(arch)
    params = registry.init_params(jax.random.PRNGKey(1), cfg, F32)
    batch = registry.demo_batch(cfg, batch=2, seq=32, seed=1)
    l1, _ = registry.loss_fn(params, cfg, batch, remat="none")
    l2, _ = registry.loss_fn(params, cfg, batch, remat="full")
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


DECODE_ARCHS = [a for a in ARCHS]


@pytest.mark.parametrize("arch", _tiered(DECODE_ARCHS))
def test_prefill_decode_matches_full_forward(arch):
    """Greedy decode continuation: logits from (prefill + decode_step) must
    match the full forward on the extended sequence."""
    cfg = get_smoke(arch)
    params = registry.init_params(jax.random.PRNGKey(2), cfg, F32)
    S, extra = 16, 4
    batch = registry.demo_batch(cfg, batch=2, seq=S + extra, seed=2)
    full_batch = dict(batch)
    prefix = {k: (v[:, :S] if k in ("tokens", "labels") else v)
              for k, v in batch.items()}

    logits_full, _ = registry.forward_logits(params, cfg, full_batch)

    horizon = S + extra
    logits_pre, cache = registry.prefill(params, cfg, prefix, horizon,
                                         kv_dtype=F32)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(logits_full[:, :S]),
                               rtol=2e-3, atol=2e-3)
    for t in range(extra):
        pos = jnp.asarray(S + t, jnp.int32)
        tok = batch["tokens"][:, S + t:S + t + 1]
        logits_t, cache = registry.decode_step(params, cfg, cache, tok, pos)
        np.testing.assert_allclose(
            np.asarray(logits_t[:, 0]),
            np.asarray(logits_full[:, S + t]), rtol=2e-3, atol=2e-3)


@pytest.mark.slow
def test_ssd_chunked_matches_recurrence():
    """Chunked SSD == naive per-step recurrence (the SSD correctness oracle)."""
    from repro.models.ssm import ssd_chunked
    rng = np.random.default_rng(0)
    B, S, H, P, N = 2, 64, 3, 4, 8
    xdt = jnp.asarray(rng.normal(size=(B, S, H, P)), F32)
    dA = jnp.asarray(-np.abs(rng.normal(size=(B, S, H))) * 0.1, F32)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)), F32)
    Cm = jnp.asarray(rng.normal(size=(B, S, N)), F32)

    for chunk in [8, 16, 64]:
        y, h_fin = ssd_chunked(xdt, dA, Bm, Cm, chunk)
        # naive recurrence
        h = np.zeros((B, H, P, N))
        ys = np.zeros((B, S, H, P))
        for t in range(S):
            a = np.exp(np.asarray(dA[:, t]))                  # (B,H)
            h = h * a[..., None, None] + np.einsum(
                "bhp,bn->bhpn", np.asarray(xdt[:, t]), np.asarray(Bm[:, t]))
            ys[:, t] = np.einsum("bhpn,bn->bhp", h, np.asarray(Cm[:, t]))
        np.testing.assert_allclose(np.asarray(y), ys, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(h_fin), h, rtol=1e-4,
                                   atol=1e-4)


def test_ssd_chunked_with_initial_state():
    from repro.models.ssm import ssd_chunked
    rng = np.random.default_rng(1)
    B, S, H, P, N = 1, 32, 2, 4, 8
    xdt = jnp.asarray(rng.normal(size=(B, S, H, P)), F32)
    dA = jnp.asarray(-np.abs(rng.normal(size=(B, S, H))) * 0.1, F32)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)), F32)
    Cm = jnp.asarray(rng.normal(size=(B, S, N)), F32)
    # split the sequence: running two halves with state handoff must equal
    # the single pass
    y_full, h_full = ssd_chunked(xdt, dA, Bm, Cm, 8)
    y1, h1 = ssd_chunked(xdt[:, :16], dA[:, :16], Bm[:, :16], Cm[:, :16], 8)
    y2, h2 = ssd_chunked(xdt[:, 16:], dA[:, 16:], Bm[:, 16:], Cm[:, 16:], 8,
                         h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=1e-4, atol=1e-5)


def test_rglru_matches_recurrence():
    from repro.models.rglru import _rglru
    rng = np.random.default_rng(2)
    B, S, W = 2, 40, 8
    xb = jnp.asarray(rng.normal(size=(B, S, W)), F32)
    r = jnp.asarray(rng.uniform(size=(B, S, W)), F32)
    i = jnp.asarray(rng.uniform(size=(B, S, W)), F32)
    lam = jnp.asarray(rng.normal(size=(W,)), F32)
    y, h_last = _rglru(xb, r, i, lam)
    # naive
    import scipy.special as sp
    log_a = -8.0 * np.log1p(np.exp(np.asarray(lam))) * np.asarray(r)
    a = np.exp(log_a)
    b = np.sqrt(1 - np.exp(2 * log_a)) * np.asarray(i) * np.asarray(xb)
    h = np.zeros((B, W))
    ys = np.zeros((B, S, W))
    for t in range(S):
        h = a[:, t] * h + b[:, t]
        ys[:, t] = h
    np.testing.assert_allclose(np.asarray(y), ys, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_last), h, rtol=2e-4, atol=2e-4)


def test_attention_chunked_matches_naive():
    from repro.models.layers import attention
    rng = np.random.default_rng(3)
    B, S, H, KH, D = 2, 64, 4, 2, 8
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), F32)
    k = jnp.asarray(rng.normal(size=(B, S, KH, D)), F32)
    v = jnp.asarray(rng.normal(size=(B, S, KH, D)), F32)
    pos = jnp.arange(S, dtype=jnp.int32)

    for window, causal in [(0, True), (16, True), (0, False)]:
        out = attention(q, k, v, pos, pos, causal=causal, window=window,
                        chunk_q=16, chunk_k=16)
        # naive reference
        kk = np.repeat(np.asarray(k), H // KH, axis=2)
        vv = np.repeat(np.asarray(v), H // KH, axis=2)
        s = np.einsum("bshd,bthd->bhst", np.asarray(q), kk) / np.sqrt(D)
        mask = np.ones((S, S), bool)
        if causal:
            mask &= np.tril(np.ones((S, S), bool))
        if window:
            pp = np.arange(S)
            mask &= (pp[:, None] - pp[None, :]) < window
        s = np.where(mask, s, -1e30)
        p = np.exp(s - s.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        ref = np.einsum("bhst,bthd->bshd", p, vv)
        np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4,
                                   atol=2e-4)


@pytest.mark.slow
def test_attention_grads_finite():
    from repro.models.layers import attention
    rng = np.random.default_rng(4)
    B, S, H, KH, D = 1, 32, 2, 1, 8
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), F32)
    k = jnp.asarray(rng.normal(size=(B, S, KH, D)), F32)
    v = jnp.asarray(rng.normal(size=(B, S, KH, D)), F32)
    pos = jnp.arange(S, dtype=jnp.int32)

    def f(q, k, v):
        return jnp.sum(attention(q, k, v, pos, pos, causal=True,
                                 chunk_q=8, chunk_k=8) ** 2)

    gq, gk, gv = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    for g in (gq, gk, gv):
        assert bool(jnp.all(jnp.isfinite(g)))

    # numerical check vs naive implementation's grads
    def f_naive(q, k, v):
        kk = jnp.repeat(k, H // KH, axis=2)
        vv = jnp.repeat(v, H // KH, axis=2)
        s = jnp.einsum("bshd,bthd->bhst", q, kk) / np.sqrt(D)
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.sum(jnp.einsum("bhst,bthd->bshd", p, vv) ** 2)

    ngq, ngk, ngv = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
    np.testing.assert_allclose(np.asarray(gq), np.asarray(ngq), rtol=1e-3,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(ngk), rtol=1e-3,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(ngv), rtol=1e-3,
                               atol=1e-4)
