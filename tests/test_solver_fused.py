"""Fused two-pass solver vs the standard solver: same optimum, same
algorithm semantics, on both the jnp and the Pallas-interpret backends."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qp as qp_mod
from repro.core.solver import SolverConfig, solve
from repro.core.solver_fused import solve_fused
from repro.svm.data import gaussian_blobs, ring, xor_gaussians


def _problem(name, n, seed=0):
    gen = {"blobs": gaussian_blobs, "ring": ring, "xor": xor_gaussians}[name]
    X, y = gen(n, seed=seed)
    gamma = {"blobs": 0.05, "ring": 1.0, "xor": 0.5}[name]
    C = {"blobs": 1.0, "ring": 10.0, "xor": 100.0}[name]
    return X, y, C, gamma


@pytest.mark.parametrize("alg", ["smo", "pasmo"])
@pytest.mark.parametrize("name", [
    "blobs", pytest.param("ring", marks=pytest.mark.slow), "xor"])
def test_fused_jnp_matches_standard(alg, name):
    X, y, C, gamma = _problem(name, 64)
    cfg = SolverConfig(algorithm=alg, eps=1e-4, max_iter=100_000)
    rf = solve_fused(jnp.asarray(X), jnp.asarray(y), C, gamma, cfg,
                     impl="jnp")
    rs = solve(qp_mod.make_rbf(jnp.asarray(X), gamma), jnp.asarray(y), C,
               cfg)
    assert bool(rf.converged) and bool(rs.converged)
    np.testing.assert_allclose(float(rf.objective), float(rs.objective),
                               rtol=1e-6)
    assert float(rf.kkt_gap) <= 1e-4 + 1e-12
    # same algorithm: planning engages on both or neither
    if alg == "pasmo" and int(rs.n_planning) > 10:
        assert int(rf.n_planning) > 0


@pytest.mark.parametrize("alg", ["smo", "pasmo"])
def test_fused_pallas_interpret_matches_jnp(alg):
    """The Pallas kernels inside the full solve loop (interpret mode)."""
    X, y, C, gamma = _problem("xor", 64, seed=1)
    cfg = SolverConfig(algorithm=alg, eps=1e-3, max_iter=20_000)
    r_jnp = solve_fused(jnp.asarray(X), jnp.asarray(y), C, gamma, cfg,
                        impl="jnp")
    r_pl = solve_fused(jnp.asarray(X), jnp.asarray(y), C, gamma, cfg,
                       impl="interpret", block_l=128)
    assert bool(r_pl.converged)
    np.testing.assert_allclose(float(r_pl.objective), float(r_jnp.objective),
                               rtol=1e-6)
    assert abs(int(r_pl.iterations) - int(r_jnp.iterations)) <= max(
        3, 0.05 * int(r_jnp.iterations))


def test_fused_feasible():
    X, y, C, gamma = _problem("ring", 70, seed=2)
    cfg = SolverConfig(algorithm="pasmo", eps=1e-4)
    r = solve_fused(jnp.asarray(X), jnp.asarray(y), C, gamma, cfg,
                    impl="jnp")
    bounds = qp_mod.make_bounds(jnp.asarray(y), C)
    assert bool(qp_mod.is_feasible(r.alpha, bounds, atol=1e-8))
    # maintained gradient equals y - K alpha
    K = qp_mod.materialize(qp_mod.make_rbf(jnp.asarray(X), gamma))
    np.testing.assert_allclose(np.asarray(r.G),
                               y - np.asarray(K) @ np.asarray(r.alpha),
                               rtol=1e-7, atol=1e-7)
