"""Logical-axis sharding rules (MaxText-style), divisibility-aware.

Every parameter and activation in the model zoo is annotated with *logical*
axis names; a rule table maps logical names to mesh axes.  ``spec_for``
drops a rule when the concrete dimension is not divisible by the mesh axis
size (e.g. qwen2's 14 query heads on a 16-way ``model`` axis fall back to
replication while its d_ff = 4864 still tensor-parallelizes) — this keeps
every (arch x shape x mesh) cell compilable with one rule table and makes
the table itself a hillclimb knob (see EXPERIMENTS.md §Perf).

Default layout (production mesh (data=16, model=16), + pod for multi-pod):

    batch   -> ('pod', 'data')   data parallel over pods x data
    embed   -> 'data'            FSDP: params + optimizer state sharded
    heads/kv_heads/mlp/vocab/expert -> 'model'   Megatron TP / EP
    seq/state/layers -> replicated (sequence kept local; see LONG_DECODE)
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]
Rules = Tuple[Tuple[str, MeshAxes], ...]

DEFAULT_RULES: Rules = (
    ("batch", ("pod", "data")),
    ("embed", "data"),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("mlp", "model"),
    ("vocab", "model"),
    ("expert", "model"),
    # Expert FFN hidden dim shards over model x data jointly (§Perf grok
    # hillclimb): with few experts (8) vs wide axes (16), expert-dim
    # sharding is indivisible and FSDP-on-d makes every expert contraction
    # a partial-sum all-reduce (observed 2.8 TB/step/device).  f-sharding
    # keeps parameters fully distributed and removes the w_gate/w_up
    # reductions entirely.
    ("moe_ff", ("model", "data")),
    ("conv", None),
    ("state", None),
    ("seq", None),
    # NOTE (§Perf, refuted hypothesis): sharding kv_seq over 'model'
    # (flash-decoding context parallelism) should cut per-device cache
    # reads 16x, but GSPMD re-replicates the in-loop cache buffers and the
    # per-layer writeback balloons 8x instead.  Realizing it needs a
    # shard_map manual decode step (future work) — replicated here.
    ("kv_seq", None),
    ("layers", None),
    ("head_dim", None),
)

LONG_DECODE_RULES: Rules = DEFAULT_RULES


def _axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes] if axes in mesh.shape else 0
    size = 1
    for a in axes:
        if a not in mesh.shape:
            return 0
        size *= mesh.shape[a]
    return size


def _lookup(rules: Rules, name: Optional[str]) -> MeshAxes:
    if name is None:
        return None
    for key, axes in rules:
        if key == name:
            return axes
    raise KeyError(f"no sharding rule for logical axis {name!r}")


def spec_for(shape: Sequence[int], names: Sequence[Optional[str]],
             mesh: Mesh, rules: Rules = DEFAULT_RULES) -> P:
    """PartitionSpec for a concrete shape annotated with logical names.

    Rules whose mesh axes are absent from the mesh, already used by an
    earlier dimension, or do not divide the dimension size are dropped
    (replicated) — never an error.
    """
    assert len(shape) == len(names), (shape, names)
    used: set = set()
    out = []
    for dim, name in zip(shape, names):
        axes = _lookup(rules, name)
        if axes is None:
            out.append(None)
            continue
        axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
        # drop axes missing from the mesh or already used
        axes_t = tuple(a for a in axes_t
                       if a in mesh.shape and a not in used)
        size = 1
        for a in axes_t:
            size *= mesh.shape[a]
        if size <= 1 or dim % size != 0:
            out.append(None)
            continue
        used.update(axes_t)
        out.append(axes_t[0] if len(axes_t) == 1 else axes_t)
    return P(*out)


@dataclasses.dataclass(frozen=True)
class logical:
    """Logical annotation carried in spec trees: shape dims -> names."""

    names: Tuple[Optional[str], ...]

    def __init__(self, *names: Optional[str]):
        object.__setattr__(self, "names", tuple(names))


def tree_specs(logical_tree: Any, shape_tree: Any, mesh: Mesh,
               rules: Rules = DEFAULT_RULES):
    """Map a tree of ``logical`` + a matching tree of shapes to
    PartitionSpecs."""
    return jax.tree.map(
        lambda lg, sd: spec_for(sd.shape, lg.names, mesh, rules),
        logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, logical))


def tree_shardings(logical_tree: Any, shape_tree: Any, mesh: Mesh,
                   rules: Rules = DEFAULT_RULES):
    specs = tree_specs(logical_tree, shape_tree, mesh, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


_CTX = threading.local()


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Rules = DEFAULT_RULES):
    """Context under which ``constrain`` resolves logical names.

    Launch/dry-run code wraps tracing in this; CPU smoke tests simply don't,
    making every ``constrain`` a no-op."""
    prev = getattr(_CTX, "env", None)
    _CTX.env = (mesh, rules)
    try:
        yield
    finally:
        _CTX.env = prev


def current_rules() -> Optional[Tuple[Mesh, Rules]]:
    return getattr(_CTX, "env", None)


def constrain(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Activation sharding constraint by logical names (no-op without an
    active ``axis_rules`` context)."""
    env = current_rules()
    if env is None:
        return x
    mesh, rules = env
    spec = spec_for(x.shape, names, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
