from repro.sharding.rules import (Rules, DEFAULT_RULES, LONG_DECODE_RULES,
                                  axis_rules, constrain, current_rules,
                                  logical, spec_for, tree_shardings,
                                  tree_specs)
