from repro.svm.data import (chessboard, gaussian_blobs, multiclass_blobs,
                            ring, xor_gaussians, DATASETS, make_dataset)
from repro.svm.model import SVMModel, predict, decision_function, train_svm
from repro.svm.svc import SVC
from repro.svm.svr import SVR
from repro.svm.oneclass import OneClassSVM
