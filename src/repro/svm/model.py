"""SVM model object: train with any core algorithm, predict, inspect SVs."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qp as qp_mod
from repro.core.solver import SolveResult, SolverConfig, solve


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SVMModel:
    """Trained (signed-dual) SVM.  ``alpha`` already carries the label sign,
    so the decision function is ``h(x) = sum_i alpha_i k(x_i, x) + b``."""

    X: jax.Array        # (l, d) training inputs
    alpha: jax.Array    # (l,) signed dual variables
    b: jax.Array        # () bias
    gamma: jax.Array    # () RBF width

    def n_sv(self, atol: float = 1e-9) -> jax.Array:
        return jnp.sum(jnp.abs(self.alpha) > atol)

    def n_bounded_sv(self, C, atol: float = 1e-9) -> jax.Array:
        return jnp.sum(jnp.abs(jnp.abs(self.alpha) - C) <= atol)


def decision_function(model: SVMModel, Xq: jax.Array) -> jax.Array:
    """h(x) for a batch of query points (m, d) -> (m,)."""
    d2 = (jnp.sum(Xq * Xq, -1)[:, None]
          + jnp.sum(model.X * model.X, -1)[None, :]
          - 2.0 * Xq @ model.X.T)
    Kq = jnp.exp(-model.gamma * jnp.maximum(d2, 0.0))
    return Kq @ model.alpha + model.b


def predict(model: SVMModel, Xq: jax.Array) -> jax.Array:
    """±1 labels; an exactly-zero margin maps to +1 (the ``df >= 0``
    convention shared with ``SVC.predict`` — ``jnp.sign`` would emit the
    invalid label 0 for a query on the separating surface)."""
    h = decision_function(model, Xq)
    return jnp.where(h >= 0, 1.0, -1.0).astype(h.dtype)


def train_svm(X, y, C, gamma, cfg: SolverConfig = SolverConfig(),
              dtype=jnp.float64) -> tuple[SVMModel, SolveResult]:
    """Train a binary RBF-SVM with the configured core algorithm."""
    X = jnp.asarray(X, dtype)
    y = jnp.asarray(y, dtype)
    kernel = qp_mod.make_rbf(X, gamma)
    res = solve(kernel, y, C, cfg)
    model = SVMModel(X=X, alpha=res.alpha, b=res.b,
                     gamma=jnp.asarray(gamma, dtype))
    return model, res
