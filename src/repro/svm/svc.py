"""sklearn-style ``SVC`` facade over the PA-SMO core.

Binary problems are one signed-dual QP; multiclass problems are reduced
one-vs-rest.  Two fit engines (selected by ``engine``):

* ``"fused"`` — the fused two-pass batched solver
  (:mod:`repro.core.solver_fused`): two kernel passes per iteration for
  the whole class stack, converged heads frozen in-kernel; ``precompute``
  picks the row source (Gram-bank gathers vs on-the-fly X rows).  The
  default whenever the solver config is compatible (``algorithm`` in
  smo/pasmo, ``plan_candidates == 1``).
* ``"batched"`` — the standard vmapped solver over a precomputed Gram
  matrix (or on-the-fly rows with ``precompute=False``); supports every
  algorithm/ablation knob.

Prediction is batched through :func:`repro.kernels.ops.gram`, so the query
cross-kernel is computed once for all class heads (and hits the Pallas
path on TPU).

    >>> clf = SVC(C=10.0, gamma=0.5).fit(X, y)
    >>> clf.predict(Xq)            # labels, any dtype y was given in
    >>> clf.decision_function(Xq)  # (m,) binary margin or (m, k) OVR scores
"""

from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import multiclass as mc
from repro.core import qp as qp_mod
from repro.core.solver import SolveResult, solve
from repro.core.solver_fused import FusedResult
from repro.kernels import ops
from repro.svm.base import SVMEstimatorBase


class SVC(SVMEstimatorBase):
    """RBF support-vector classifier driven by the planning-ahead solver.

    Parameters mirror sklearn where they overlap: ``C`` (scalar, or a
    per-class vector for one-vs-rest), ``gamma`` (float or ``"scale"``),
    ``class_weight`` (``None``, ``"balanced"``, or a ``{label: weight}``
    dict — sample ``i`` of class ``c`` gets budget ``C * w_c``, i.e. a
    per-coordinate box of the generalized dual; requires scalar ``C``).
    Solver knobs (``algorithm``, ``step``, ``eps``, ``max_iter``,
    ``plan_candidates``) map onto
    :class:`repro.core.solver.SolverConfig` — ``step="conjugate"``
    (requires ``algorithm="smo"``) selects the Conjugate-SMO
    two-direction step; ``impl`` selects the
    kernel backend (``"auto"`` = Pallas on TPU, jnp elsewhere) for both the
    fused fit engine and the predict Gram work; ``engine`` picks the fit
    engine (``"auto"`` resolves to ``"sharded"`` on a multiclass fit with
    more than one device attached — or whenever ``mesh``/``devices`` is
    given — else ``"fused"`` when the config allows it, else
    ``"batched"``); ``precompute=False`` trades the O(l^2) Gram
    memory for on-the-fly kernel rows in either engine (in the fused
    engine ``precompute=True`` builds the shared Gram bank on the jnp
    backend — the CPU throughput mode).  ``engine="sharded"`` lane-shards
    the class heads over a device mesh
    (:mod:`repro.core.sharded_lanes`) — identical fit, one while_loop per
    device slab; ``mesh``/``devices`` pin the mesh (default: every
    attached device).  ``diagnostics`` (a
    :class:`~repro.telemetry.Diagnostics` handle) turns on the flight
    recorder: fit phases are timed on the host, and on the fused/sharded
    engines each class head drains a per-lane
    :class:`~repro.telemetry.ring.TelemetryRing` (KKT-gap trajectory,
    active-set size, planning mu/mu* ratios) into the handle's JSONL sink
    — render it with ``python -m repro.launch.telemetry_report``.
    """

    def __init__(self, C: Union[float, np.ndarray] = 1.0,
                 gamma: Union[float, str] = "scale", *,
                 class_weight: Union[dict, str, None] = None,
                 algorithm: str = "pasmo", step: str = "plain",
                 eps: float = 1e-3,
                 max_iter: int = 1_000_000, plan_candidates: int = 1,
                 impl: str = "auto", engine: str = "auto",
                 precompute: bool = True, dtype=None, mesh=None,
                 devices=None, diagnostics=None):
        if not (class_weight is None or class_weight == "balanced"
                or isinstance(class_weight, dict)):
            raise ValueError("class_weight must be None, 'balanced' or a "
                             f"{{label: weight}} dict, got {class_weight!r}")
        self.C = C
        self.class_weight = class_weight
        self.gamma = gamma
        self._init_common(algorithm=algorithm, eps=eps, max_iter=max_iter,
                          plan_candidates=plan_candidates, impl=impl,
                          engine=engine, precompute=precompute, dtype=dtype,
                          step=step, mesh=mesh, devices=devices,
                          diagnostics=diagnostics)

    # -- fitting ------------------------------------------------------------

    def _sample_weights(self, y_idx: np.ndarray, k: int) -> np.ndarray:
        """Per-sample class weights w_{y_i} (class_weight is not None)."""
        if self.class_weight == "balanced":
            counts = np.bincount(y_idx, minlength=k)
            w = len(y_idx) / (k * np.maximum(counts, 1))
        else:
            w = np.array([float(self.class_weight.get(c, 1.0))
                          for c in self.classes_])
        return w[y_idx]

    def fit(self, X, y) -> "SVC":
        X = jnp.asarray(X, self.dtype)
        self.classes_, y_idx = mc.class_index(y)
        k = len(self.classes_)
        if k < 2:
            raise ValueError("fit needs at least two classes")
        self.gamma_ = self._resolve_gamma(X)
        self.X_ = X
        cfg = self._config()
        engine = self._resolve_engine(n_lanes=1 if k == 2 else k)

        if k == 2 and np.asarray(self.C).size != 1:
            raise ValueError("per-class C requires more than two "
                             "classes (binary problems are one QP)")
        if self.class_weight is not None:
            # per-sample budgets C_i = C * w_{y_i}: a per-coordinate box of
            # the generalized dual, shared by all one-vs-rest heads
            if np.asarray(self.C).size != 1:
                raise ValueError("class_weight requires a scalar C")
            Csamp = jnp.asarray(
                float(np.asarray(self.C).reshape(()))
                * self._sample_weights(y_idx, k), self.dtype)
            C_bin, C_ovr = Csamp, jnp.broadcast_to(Csamp, (k, len(y_idx)))
        else:
            C_bin = float(np.asarray(self.C).reshape(())) if k == 2 else None
            C_ovr = jnp.asarray(self.C, self.dtype)
        if k == 2:
            yb = jnp.where(jnp.asarray(y_idx) == 1, 1.0, -1.0) \
                    .astype(self.dtype)
        else:
            Y = mc.ovr_labels(y_idx, k, self.dtype)

        tel = self._ring_config()
        ring = None
        with self._fit_scope("svc_fit", engine=engine, n_class=k,
                             rows=int(X.shape[0])):
            if engine in ("fused", "sharded"):
                shard_kw = {}
                if engine == "sharded":
                    shard_kw = dict(mesh=self.mesh, devices=self.devices)
                    if self.mesh is None and self.devices is None:
                        shard_kw["devices"] = tuple(jax.devices())
                if k == 2:
                    C_arg = (C_bin[None, :] if self.class_weight is not None
                             else C_bin)
                    out = mc.solve_ovr_fused(X, yb[None, :], C_arg,
                                             self.gamma_, cfg, impl=self.impl,
                                             precompute=self.precompute,
                                             telemetry=tel, **shard_kw)
                else:
                    out = mc.solve_ovr_fused(X, Y, C_ovr,
                                             self.gamma_, cfg, impl=self.impl,
                                             precompute=self.precompute,
                                             telemetry=tel, **shard_kw)
                if tel is not None:
                    out, ring = out
                res = (jax.tree.map(lambda leaf: leaf[0], out)
                       if k == 2 else out)
            else:
                if self.precompute:
                    K = ops.gram(X, gamma=self.gamma_, impl=self.impl)
                    kern = qp_mod.PrecomputedKernel(K.astype(self.dtype))
                else:
                    kern = qp_mod.make_rbf(X, self.gamma_)
                if k == 2:
                    res = solve(kern, yb, C_bin, cfg)
                else:
                    res = mc.solve_ovr(kern, Y, C_ovr, cfg)
            if self.diagnostics is not None:
                jax.block_until_ready(res.alpha)
        if ring is not None:
            # one lane per class head (the lone head of a binary fit is the
            # "classes_[1] vs rest" problem, label index 1)
            Cv = np.asarray(self.C, float).reshape(-1)
            heads = [1] if k == 2 else range(k)
            meta = [{"gamma": self.gamma_, "label": int(c),
                     **({} if self.class_weight is not None else
                        {"C": float(Cv[c] if Cv.size > 1 else Cv[0])})}
                    for c in heads]
            self.diagnostics.drain_ring(ring, meta, out)
        self.fit_result_: Union[SolveResult, FusedResult] = res
        self.engine_ = engine
        self.alpha_ = res.alpha          # (l,) binary, (k, l) one-vs-rest
        self.b_ = res.b
        return self

    # -- inference ----------------------------------------------------------

    def decision_function(self, Xq) -> jnp.ndarray:
        """Binary: (m,) signed margin (positive -> ``classes_[1]``).
        Multiclass: (m, k) one-vs-rest scores."""
        self._check_fitted()
        Kq, squeeze = self._query_gram(Xq)
        if self.alpha_.ndim == 1:
            df = Kq @ self.alpha_ + self.b_
        else:
            df = mc.ovr_decision(Kq, self.alpha_, self.b_)
        return df[0] if squeeze else df

    def predict(self, Xq) -> np.ndarray:
        self._check_fitted()
        df = self.decision_function(Xq)
        if self.alpha_.ndim == 1:
            idx = (np.asarray(df) >= 0).astype(np.int64)
        else:
            idx = np.asarray(jnp.argmax(df, axis=-1))
        return self.classes_[idx]

    def score(self, Xq, yq) -> float:
        """Mean accuracy on (Xq, yq)."""
        return float(np.mean(self.predict(Xq) == np.asarray(yq)))

    # -- introspection --------------------------------------------------

    @property
    def n_support_(self) -> np.ndarray:
        """Support-vector count per head ((1,) binary, (k,) one-vs-rest)."""
        self._check_fitted()
        a = np.atleast_2d(np.asarray(self.alpha_))
        return (np.abs(a) > 1e-9).sum(axis=1)
