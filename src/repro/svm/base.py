"""Shared plumbing for the sklearn-style facades (SVC / SVR / OneClassSVM).

One copy of the solver-knob wiring, the ``gamma="scale"`` resolution, the
fused-engine eligibility rule, and the batched query-Gram helper — the
estimators differ only in which :class:`repro.core.qp.DualQP` instance
they build and how they post-process the dual.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.solver import SolverConfig
from repro.kernels import ops


class SVMEstimatorBase:
    """Mixin holding the facade knobs shared by every estimator.

    Subclasses set ``_fit_attr`` to the attribute whose presence marks a
    fitted model and call :meth:`_init_common` from their ``__init__``.
    """

    _fit_attr = "alpha_"

    def _init_common(self, *, algorithm: str, eps: float, max_iter: int,
                     plan_candidates: int, impl: str, engine: str,
                     precompute: bool, dtype, step: str = "plain",
                     mesh=None, devices=None, diagnostics=None) -> None:
        if engine not in ("auto", "fused", "batched", "sharded"):
            raise ValueError(f"engine must be auto|fused|batched|sharded, "
                             f"got {engine!r}")
        if engine in ("fused", "batched") and (mesh is not None
                                               or devices is not None):
            raise ValueError("mesh/devices belong to the sharded engine — "
                             f"drop them or use engine='sharded'/'auto', "
                             f"got engine={engine!r}")
        self.algorithm = algorithm
        self.step = step
        self.eps = eps
        self.max_iter = max_iter
        self.plan_candidates = plan_candidates
        self.impl = impl
        self.engine = engine
        self.precompute = precompute
        self.mesh = mesh
        self.devices = devices
        self.diagnostics = diagnostics
        # f64 when x64 is on (the paper-accuracy setting), else a clean f32
        # fallback instead of per-call truncation warnings
        self.dtype = dtype if dtype is not None else (
            jnp.float64 if jax.config.jax_enable_x64 else jnp.float32)

    def _ring_config(self):
        """Device-tier telemetry geometry, when the flight recorder is on.

        The static :class:`~repro.telemetry.ring.RingConfig` of the
        attached :class:`~repro.telemetry.Diagnostics` handle, or ``None``
        — the engines then trace their telemetry-free jaxpr.  Only the
        fused/sharded engines carry rings; on the classic batched engine a
        ``diagnostics=`` handle still records host-tier fit phases.
        """
        if self.diagnostics is None:
            return None
        return self.diagnostics.ring_config

    def _fit_scope(self, name: str, **meta):
        """Host-tier phase scope around a fit, or a no-op without one."""
        from contextlib import nullcontext
        if self.diagnostics is None:
            return nullcontext()
        return self.diagnostics.scope(name, **meta)

    def _config(self) -> SolverConfig:
        return SolverConfig(algorithm=self.algorithm, step=self.step,
                            eps=self.eps, max_iter=self.max_iter,
                            plan_candidates=self.plan_candidates)

    def _resolve_gamma(self, X) -> float:
        if self.gamma == "scale":
            var = float(np.asarray(X).var())
            return 1.0 / (X.shape[1] * var) if var > 0 else 1.0
        return float(self.gamma)

    def _resolve_engine(self, n_lanes: int = 1) -> str:
        """Pick the fit engine; ``n_lanes`` is the QP lane count of the
        upcoming fit (class heads for SVC, 1 for SVR/one-class) — ``auto``
        only shards when there is more than one lane to spread."""
        fusable = (self.algorithm in ("smo", "pasmo")
                   and self.plan_candidates == 1)
        if self.engine == "sharded":
            if not fusable:
                raise ValueError(
                    "engine='sharded' runs on the fused engine, which needs "
                    "algorithm in ('smo', 'pasmo') and plan_candidates == 1")
            return "sharded"
        if self.engine != "auto":
            return self.engine
        if not fusable:
            return "batched"
        if (self.mesh is not None or self.devices is not None
                or (n_lanes > 1 and len(jax.devices()) > 1)):
            return "sharded"
        return "fused"

    def _check_fitted(self):
        if not hasattr(self, self._fit_attr):
            raise RuntimeError(
                f"{type(self).__name__} instance is not fitted yet")

    def _query_gram(self, Xq):
        """Query cross-Gram against the training set -> (Kq, squeeze)."""
        Xq = jnp.asarray(Xq, self.dtype)
        squeeze = Xq.ndim == 1
        if squeeze:
            Xq = Xq[None, :]
        Kq = ops.gram(Xq, self.X_, gamma=self.gamma_, impl=self.impl)
        return Kq.astype(self.dtype), squeeze
