"""sklearn-style ``SVR`` facade: ε-insensitive regression on the PA-SMO core.

The fit is ONE generalized dual QP (:func:`repro.core.qp.svr_qp`): 2l
doubled variables sharing the base l x l Gram through the sign-folded
operator — rows are tiled base rows, so no 2l x 2l matrix is ever
materialized in either engine.  Engines mirror :class:`repro.svm.svc.SVC`:

* ``"fused"``   — one lane of the fused two-pass batched solver with
  ``doubled=True`` (:func:`repro.core.solver_fused.solve_fused_batched_qp`).
* ``"batched"`` — the standard solver over a
  :class:`~repro.core.qp.DoubledKernel` oracle (supports every
  algorithm/ablation knob).

Prediction reuses the SVC Gram machinery: ``f(x) = k(x, X) @ beta + b``
with ``beta = alpha[:l] + alpha[l:]`` (:func:`repro.core.qp.svr_fold`).

    >>> reg = SVR(C=10.0, epsilon=0.1, gamma=0.5).fit(X, y)
    >>> reg.predict(Xq)
"""

from __future__ import annotations

from functools import partial
from typing import Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qp as qp_mod
from repro.core.solver import solve_qp
from repro.core.sharded_lanes import solve_fused_sharded_qp
from repro.core.solver_fused import solve_fused_batched_qp
from repro.kernels import ops
from repro.svm.base import SVMEstimatorBase


class SVR(SVMEstimatorBase):
    """RBF ε-support-vector regression driven by the planning-ahead solver.

    ``C`` is the box budget, ``epsilon`` the insensitive-tube half-width,
    ``gamma`` a float or ``"scale"``; ``eps`` is the KKT stopping accuracy
    (solver tolerance, NOT the tube).  ``impl``/``engine``/``precompute``
    — and the ``algorithm``/``step`` solver knobs, including
    ``step="conjugate"`` — select backends exactly as in
    :class:`repro.svm.svc.SVC`.  The fit is
    a single QP lane, so ``engine="auto"`` never picks ``"sharded"`` here
    — an explicit ``engine="sharded"`` (with optional ``mesh``/``devices``)
    still routes the lane through the sharded engine, mainly so grid code
    can treat all three facades uniformly.
    """

    _fit_attr = "beta_"

    def __init__(self, C: float = 1.0, epsilon: float = 0.1,
                 gamma: Union[float, str] = "scale", *,
                 algorithm: str = "pasmo", step: str = "plain",
                 eps: float = 1e-3,
                 max_iter: int = 1_000_000, plan_candidates: int = 1,
                 impl: str = "auto", engine: str = "auto",
                 precompute: bool = True, dtype=None, mesh=None,
                 devices=None, diagnostics=None):
        self.C = C
        self.epsilon = epsilon
        self.gamma = gamma
        self._init_common(algorithm=algorithm, eps=eps, max_iter=max_iter,
                          plan_candidates=plan_candidates, impl=impl,
                          engine=engine, precompute=precompute, dtype=dtype,
                          step=step, mesh=mesh, devices=devices,
                          diagnostics=diagnostics)

    def fit(self, X, y) -> "SVR":
        X = jnp.asarray(X, self.dtype)
        y = jnp.asarray(y, self.dtype)
        self.gamma_ = self._resolve_gamma(X)
        self.X_ = X
        cfg = self._config()
        engine = self._resolve_engine()
        qp = qp_mod.svr_qp(y, float(self.C), float(self.epsilon))

        tel = self._ring_config()
        ring = None
        with self._fit_scope("svr_fit", engine=engine, rows=int(X.shape[0])):
            if engine in ("fused", "sharded"):
                bank_kw = {}
                if self.precompute and ops.resolve_impl(self.impl) == "jnp":
                    K = ops.gram(X, gamma=self.gamma_, impl=self.impl)
                    bank_kw = dict(gram=K[None].astype(self.dtype),
                                   gram_idx=jnp.zeros((1,), jnp.int32))
                if engine == "sharded":
                    solver = partial(solve_fused_sharded_qp, mesh=self.mesh,
                                     devices=self.devices)
                else:
                    solver = solve_fused_batched_qp
                out = solver(
                    X, qp.p[None], qp.bounds.lower[None],
                    qp.bounds.upper[None], self.gamma_, cfg, impl=self.impl,
                    doubled=True, telemetry=tel, **bank_kw)
                if tel is not None:
                    out, ring = out
                res = jax.tree.map(lambda leaf: leaf[0], out)
            else:
                if self.precompute:
                    K = ops.gram(X, gamma=self.gamma_, impl=self.impl)
                    base = qp_mod.PrecomputedKernel(K.astype(self.dtype))
                else:
                    base = qp_mod.make_rbf(X, self.gamma_)
                res = solve_qp(qp_mod.DoubledKernel(base), qp, cfg)
            if self.diagnostics is not None:
                jax.block_until_ready(res.alpha)
        if ring is not None:
            self.diagnostics.drain_ring(
                ring, [{"gamma": self.gamma_, "C": float(self.C),
                        "epsilon": float(self.epsilon)}], out)
        self.fit_result_ = res
        self.engine_ = engine
        self.alpha_ = res.alpha                    # (2l,) doubled dual
        self.beta_ = qp_mod.svr_fold(res.alpha)    # (l,) coefficients
        self.b_ = res.b
        return self

    def predict(self, Xq) -> jnp.ndarray:
        self._check_fitted()
        Kq, squeeze = self._query_gram(Xq)
        f = Kq @ self.beta_ + self.b_
        return f[0] if squeeze else f

    def score(self, Xq, yq) -> float:
        """Coefficient of determination R^2 (sklearn convention)."""
        yq = np.asarray(yq, np.float64)
        pred = np.asarray(self.predict(Xq), np.float64)
        ss_res = float(np.sum((yq - pred) ** 2))
        ss_tot = float(np.sum((yq - yq.mean()) ** 2))
        return 1.0 - ss_res / ss_tot if ss_tot > 0 else 0.0

    @property
    def n_support_(self) -> int:
        """Number of support vectors (nonzero folded coefficients)."""
        self._check_fitted()
        return int((np.abs(np.asarray(self.beta_)) > 1e-9).sum())
