"""Synthetic dataset generators for the paper-validation experiments.

The paper's hardest benchmark is the artificial *chess-board* problem
(Glasmachers & Igel 2005): uniform inputs on [0, s)^2, labels by the parity
of the integer cell — "quadratic programs which are very difficult to solve
for SMO-type decomposition algorithms" (§7).  Because the distribution is
known we can sample any size, exactly as the paper does (1k/10k/100k).

All generators are deterministic in (seed, n) and return float64 numpy
arrays (the reference solver precision); callers cast as needed.
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple

import numpy as np


def chessboard(n: int, seed: int = 0, size: int = 4,
               noise: float = 0.0) -> Tuple[np.ndarray, np.ndarray]:
    """Chess-board problem on [0, size)^2 with parity labels."""
    rng = np.random.default_rng(seed)
    X = rng.uniform(0.0, float(size), size=(n, 2))
    cells = np.floor(X).astype(int)
    y = np.where((cells[:, 0] + cells[:, 1]) % 2 == 0, 1.0, -1.0)
    if noise > 0:
        flip = rng.uniform(size=n) < noise
        y = np.where(flip, -y, y)
    return X, y


def gaussian_blobs(n: int, seed: int = 0, d: int = 8,
                   sep: float = 2.0) -> Tuple[np.ndarray, np.ndarray]:
    """Two spherical Gaussians, moderately separated (an 'easy' problem)."""
    rng = np.random.default_rng(seed)
    y = np.where(rng.uniform(size=n) < 0.5, 1.0, -1.0)
    mean = np.zeros((n, d))
    mean[:, 0] = y * sep / 2.0
    X = mean + rng.normal(size=(n, d))
    return X, y


def ring(n: int, seed: int = 0, r_in: float = 1.0,
         r_out: float = 2.0) -> Tuple[np.ndarray, np.ndarray]:
    """Inner disc vs outer annulus — needs many free SVs (RBF-hard-ish)."""
    rng = np.random.default_rng(seed)
    y = np.where(rng.uniform(size=n) < 0.5, 1.0, -1.0)
    r = np.where(y > 0, rng.uniform(0, r_in, n),
                 rng.uniform(r_in * 1.05, r_out, n))
    theta = rng.uniform(0, 2 * np.pi, n)
    X = np.stack([r * np.cos(theta), r * np.sin(theta)], axis=1)
    return X, y


def xor_gaussians(n: int, seed: int = 0,
                  sep: float = 2.0) -> Tuple[np.ndarray, np.ndarray]:
    """Four Gaussians in XOR layout — strong second-order cross terms, the
    oscillation regime planning-ahead targets (§3)."""
    rng = np.random.default_rng(seed)
    quad = rng.integers(0, 4, size=n)
    sx = np.where(quad % 2 == 0, 1.0, -1.0)
    sy = np.where(quad // 2 == 0, 1.0, -1.0)
    y = sx * sy
    X = np.stack([sx * sep / 2, sy * sep / 2], axis=1) \
        + 0.6 * rng.normal(size=(n, 2))
    return X, y


def multiclass_blobs(n: int, seed: int = 0, k: int = 3, d: int = 2,
                     sep: float = 3.0) -> Tuple[np.ndarray, np.ndarray]:
    """k spherical Gaussians on a circle — integer labels 0..k-1 (the
    one-vs-rest / ``SVC`` multiclass toy problem)."""
    if d < 2:
        raise ValueError("multiclass_blobs needs d >= 2 (circle layout)")
    rng = np.random.default_rng(seed)
    y = rng.integers(0, k, size=n)
    theta = 2.0 * np.pi * y / k
    centers = np.zeros((n, d))
    centers[:, 0] = sep / 2.0 * np.cos(theta)
    centers[:, 1] = sep / 2.0 * np.sin(theta)
    X = centers + rng.normal(size=(n, d))
    return X, y.astype(np.int64)


# dataset registry: name -> (generator, default C, default gamma)
# C/gamma chosen in a generalizing regime, mirroring Table 1's protocol
# (grid-searched once, then fixed).
DATASETS: Dict[str, Tuple[Callable, float, float]] = {
    "chessboard": (chessboard, 1e6, 0.5),       # the paper's hard problem
    "blobs": (gaussian_blobs, 1.0, 0.05),       # easy, mostly bounded SVs
    "ring": (ring, 10.0, 1.0),                  # many free SVs
    "xor": (xor_gaussians, 100.0, 0.5),         # oscillation-prone
}


def make_dataset(name: str, n: int, seed: int = 0):
    """Returns (X, y, C, gamma) for a registered dataset."""
    gen, C, gamma = DATASETS[name]
    X, y = gen(n, seed=seed)
    return X, y, C, gamma


def permute(X: np.ndarray, y: np.ndarray, seed: int):
    """Random permutation — the paper averages over 100 permutations to
    wash out the first-iteration tie-break asymmetry (§7)."""
    perm = np.random.default_rng(seed).permutation(len(y))
    return X[perm], y[perm]
