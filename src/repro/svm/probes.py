"""SVM readout heads on LM features — the integration of the paper's
solver into the LM stack (DESIGN.md §2).

Workflow: pool the final hidden states of any zoo model (mean over
sequence), build the RBF Gram matrix with the Pallas-backed Gram builder
(``kernels.ops.gram``), and train one-vs-rest binary SVMs with the batched
PA-SMO solver (``solve_batched`` vmaps the whole QP solve across classes —
the TPU throughput mode of DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.solver import SolverConfig, solve_batched
from repro.kernels import ops as kops
from repro.models import registry


def extract_features(params, cfg, batch, pool: str = "mean") -> jax.Array:
    """Pooled final hidden states (B, d_model) from any zoo model."""
    mod = registry.get_module(cfg)
    kwargs = {"return_hidden": True}
    if cfg.family == "moe":
        hidden, _ = mod.apply(params, cfg, batch["tokens"], **kwargs)
    elif cfg.family == "encdec":
        hidden = mod.apply(params, cfg, batch["tokens"], batch["frames"],
                           **kwargs)
    elif cfg.family == "vlm":
        hidden = mod.apply(params, cfg, batch["tokens"], batch["patches"],
                           **kwargs)
    else:
        hidden = mod.apply(params, cfg, batch["tokens"], **kwargs)
    if pool == "mean":
        return jnp.mean(hidden.astype(jnp.float32), axis=1)
    return hidden[:, -1].astype(jnp.float32)  # last-token pool


@dataclasses.dataclass
class SVMProbe:
    X: jax.Array            # (n, d) training features
    alphas: jax.Array       # (n_classes, n) signed duals
    biases: jax.Array       # (n_classes,)
    gamma: float
    iterations: jax.Array   # (n_classes,) solver iterations per head


def median_gamma(feats: jax.Array) -> float:
    sq = jnp.sum(feats * feats, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2 * feats @ feats.T
    return float(1.0 / jnp.maximum(jnp.median(jnp.maximum(d2, 0.0)), 1e-6))


def train_probe(feats: jax.Array, labels: jax.Array, n_classes: int,
                C: float = 10.0, gamma: Optional[float] = None,
                cfg: SolverConfig = SolverConfig(algorithm="pasmo",
                                                 eps=1e-3)) -> SVMProbe:
    """One-vs-rest multiclass SVM trained by batched PA-SMO.

    The n_classes binary QPs (shared Gram matrix, different labels) solve
    as ONE vmapped while_loop."""
    feats = jnp.asarray(feats, jnp.float64)
    n = feats.shape[0]
    if gamma is None:
        gamma = median_gamma(feats)
    K = kops.gram(feats, feats, gamma).astype(jnp.float64)
    Ks = jnp.broadcast_to(K, (n_classes, n, n))
    ys = jax.vmap(lambda c: jnp.where(labels == c, 1.0, -1.0))(
        jnp.arange(n_classes)).astype(jnp.float64)
    res = solve_batched(Ks, ys, C, cfg)
    return SVMProbe(X=feats, alphas=res.alpha, biases=res.b, gamma=gamma,
                    iterations=res.iterations)


def predict_probe(probe: SVMProbe, feats: jax.Array) -> jax.Array:
    """(m, d) -> (m,) class predictions."""
    Kq = kops.gram(jnp.asarray(feats, jnp.float64), probe.X,
                   probe.gamma).astype(jnp.float64)
    scores = Kq @ probe.alphas.T + probe.biases[None, :]
    return jnp.argmax(scores, axis=-1)
