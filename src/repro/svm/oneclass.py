"""sklearn-style ``OneClassSVM`` facade: ν novelty detection on PA-SMO.

The fit is the one-class instance of the generalized dual
(:func:`repro.core.qp.oneclass_qp`): ``p = 0``, box ``[0, 1/(nu l)]``,
equality ``sum(a) = 1`` — started from the LIBSVM feasible point
(:func:`repro.core.qp.oneclass_alpha0`) since 0 is infeasible, with its
gradient ``G0 = -K alpha0`` paid as one matvec before the loop.  Engines
mirror :class:`repro.svm.svc.SVC` (one fused lane, or the standard solver
on a kernel oracle).  The decision function is

    f(x) = k(x, X) @ alpha - rho,   rho = -b

(the solver's universal bias estimate ``b = (max_up G + min_down G) / 2``
equals ``-rho`` here); ``predict`` returns +1 for inliers, -1 for
outliers, and the fraction of training outliers approaches ``nu``.
"""

from __future__ import annotations

from functools import partial
from typing import Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qp as qp_mod
from repro.core.sharded_lanes import solve_fused_sharded_qp
from repro.core.solver import solve_qp
from repro.core.solver_fused import solve_fused_batched_qp
from repro.kernels import ops
from repro.svm.base import SVMEstimatorBase


class OneClassSVM(SVMEstimatorBase):
    """RBF one-class SVM driven by the planning-ahead solver.

    ``nu`` in (0, 1) upper-bounds the training-outlier fraction and
    lower-bounds the support-vector fraction.  Remaining knobs as in
    :class:`repro.svm.svc.SVC`.
    """

    def __init__(self, nu: float = 0.5, gamma: Union[float, str] = "scale",
                 *, algorithm: str = "pasmo", step: str = "plain",
                 eps: float = 1e-3,
                 max_iter: int = 1_000_000, plan_candidates: int = 1,
                 impl: str = "auto", engine: str = "auto",
                 precompute: bool = True, dtype=None, mesh=None,
                 devices=None, diagnostics=None):
        if not 0.0 < nu <= 1.0:
            raise ValueError(f"nu must be in (0, 1], got {nu!r}")
        self.nu = nu
        self.gamma = gamma
        self._init_common(algorithm=algorithm, eps=eps, max_iter=max_iter,
                          plan_candidates=plan_candidates, impl=impl,
                          engine=engine, precompute=precompute, dtype=dtype,
                          step=step, mesh=mesh, devices=devices,
                          diagnostics=diagnostics)

    def fit(self, X, y=None) -> "OneClassSVM":
        X = jnp.asarray(X, self.dtype)
        l = X.shape[0]
        self.gamma_ = self._resolve_gamma(X)
        self.X_ = X
        cfg = self._config()
        engine = self._resolve_engine()
        qp = qp_mod.oneclass_qp(l, self.nu, self.dtype)
        a0 = qp_mod.oneclass_alpha0(l, self.nu, self.dtype)

        tel = self._ring_config()
        ring = None
        with self._fit_scope("oneclass_fit", engine=engine,
                             rows=int(X.shape[0])):
            if engine in ("fused", "sharded"):
                bank_kw = {}
                if self.precompute and ops.resolve_impl(self.impl) == "jnp":
                    K = ops.gram(X, gamma=self.gamma_,
                                 impl=self.impl).astype(self.dtype)
                    G0 = -(K @ a0)
                    bank_kw = dict(gram=K[None],
                                   gram_idx=jnp.zeros((1,), jnp.int32))
                else:
                    G0 = -qp_mod.make_rbf(X, self.gamma_).matvec(a0)
                if engine == "sharded":
                    solver = partial(solve_fused_sharded_qp, mesh=self.mesh,
                                     devices=self.devices)
                else:
                    solver = solve_fused_batched_qp
                out = solver(
                    X, qp.p[None], qp.bounds.lower[None],
                    qp.bounds.upper[None], self.gamma_, cfg, impl=self.impl,
                    alpha0=a0[None], G0=G0[None], telemetry=tel, **bank_kw)
                if tel is not None:
                    out, ring = out
                res = jax.tree.map(lambda leaf: leaf[0], out)
            else:
                if self.precompute:
                    K = ops.gram(X, gamma=self.gamma_, impl=self.impl)
                    kern = qp_mod.PrecomputedKernel(K.astype(self.dtype))
                else:
                    kern = qp_mod.make_rbf(X, self.gamma_)
                res = solve_qp(kern, qp, cfg, alpha0=a0)
            if self.diagnostics is not None:
                jax.block_until_ready(res.alpha)
        if ring is not None:
            self.diagnostics.drain_ring(
                ring, [{"gamma": self.gamma_, "nu": float(self.nu)}], out)
        self.fit_result_ = res
        self.engine_ = engine
        self.alpha_ = res.alpha
        self.b_ = res.b
        self.rho_ = float(-res.b)
        return self

    def decision_function(self, Xq) -> jnp.ndarray:
        """Signed distance to the separating surface: >= 0 for inliers."""
        self._check_fitted()
        Kq, squeeze = self._query_gram(Xq)
        df = Kq @ self.alpha_ + self.b_
        return df[0] if squeeze else df

    def predict(self, Xq) -> np.ndarray:
        """+1 (inlier) / -1 (outlier), sklearn convention."""
        self._check_fitted()
        df = np.asarray(self.decision_function(Xq))
        return np.where(df >= 0, 1, -1).astype(np.int64)

    @property
    def n_support_(self) -> int:
        """Number of support vectors (nonzero duals)."""
        self._check_fitted()
        return int((np.asarray(self.alpha_) > 1e-12).sum())
