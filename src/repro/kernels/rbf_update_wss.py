"""Pass B Pallas kernel: fused k_j recompute + gradient update + next i-pick.

The second row k_j is computed tile-by-tile in VMEM and is *never written to
HBM* — it only feeds the update G <- G - mu (k_i - k_j) in-register.  The
same pass emits the per-block first-order argmax over I_up(alpha_new) (the
next iteration's i-selection) and both KKT gap endpoints, so the stopping
rule costs no extra pass over G.

HBM traffic per iteration for the whole solver (pass A + pass B):
read X twice, read G twice, write G once, write k_i once, plus the (1, BL)
mask vectors — i.e. ~2*l*d + 7*l elements, vs ~2*l*d + 12*l for the naive
separate row/update/argmax graph.  For small d (the paper's datasets have
d <= 60) the fusion saves ~40% of HBM bytes; the structural win is fewer
kernel launches and no HBM round-trip for gains/k_j.

Like pass A, the update/stopping algebra is dual-generic (arbitrary L/U
boxes) and row-source-generic: the batched kernels take the lane state as
an (H, B, lpad) stack of variable halves.  With H = 2 (the ε-SVR doubled
operator) both base rows k_i / k_j are computed ONCE per grid step from
the base (BL, d) X tile and applied to each half via index arithmetic —
the matmuls stay l-wide, replacing the old pre-tiled-X launch.  The rows
variant consumes pre-gathered base rows instead (Gram-bank mode).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xq_ref, scal_ref, X_ref, sqn_ref, G_ref, ki_ref, alpha_ref,
            L_ref, U_ref, G_out, bmax_out, barg_out, bmin_out,
            *, block_l: int):
    b = pl.program_id(0)
    # scalars: [sqq_j, mu, gamma]
    sqq = scal_ref[0, 0]
    mu = scal_ref[0, 1]
    gamma = scal_ref[0, 2]

    x = X_ref[...]
    qv = xq_ref[...]
    prod = jax.lax.dot_general(x, qv, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.promote_types(x.dtype, jnp.float32))
    d2 = sqq + sqn_ref[...] - 2.0 * prod.reshape(1, block_l)
    k_j = jnp.exp(-gamma * jnp.maximum(d2, 0.0))

    G_new = G_ref[...] - mu * (ki_ref[...] - k_j)
    G_out[...] = G_new.astype(G_out.dtype)

    alpha = alpha_ref[...]
    up = alpha < U_ref[...]
    dn = alpha > L_ref[...]
    vals_up = jnp.where(up, G_new, -jnp.inf)
    arg = jax.lax.argmax(vals_up[0], 0, jnp.int32)
    bmax_out[0, 0] = vals_up[0, arg]
    barg_out[0, 0] = b * block_l + arg
    bmin_out[0, 0] = jnp.min(jnp.where(dn, G_new, jnp.inf))


def _update_from_rows(k_i, k_j, G, alpha, L, U, mu, b, *, block_l: int,
                      base_l: int, act=None, dirv=None, mu2=None):
    """Shared pass-B algebra over the (H, B, BL) state halves.

    ``k_i``/``k_j`` are the (B, BL) *base* row tiles — the doubled ε-SVR
    operator (H = 2) applies them to each half in turn, so the duplicated
    row is index arithmetic, never a second matmul or a wider tile.  A lane
    with ``mu == 0`` leaves every half of G bitwise unchanged (the
    in-kernel lane freeze).  ``act`` is an optional (H, B, BL) active-set
    tile in the data dtype (1.0/0.0) restricting the next-i scan and the
    gap endpoints; the gradient update itself stays unmasked — soft
    shrinking keeps G exact on every coordinate.  ``dirv``/``mu2`` engage
    the Conjugate-SMO second direction: ``dirv`` is the carried (H, B, BL)
    previous-direction Q-product tile and the update gains the in-register
    axpy ``- mu2 dirv`` (``mu2 == 0`` on rejected steps keeps the lane
    freeze / plain trajectory bitwise).  Returns
    (G_new (H, B, BL), bmax (B, 1), barg (B, 1) int32, bmin (B, 1)).
    """
    H = G.shape[0]
    G_new = G - mu[None] * (k_i - k_j)[None]
    if dirv is not None:
        G_new = G_new - mu2[None] * dirv
    best = barg = bmin = None
    for h in range(H):
        up = alpha[h] < U[h]
        dn = alpha[h] > L[h]
        if act is not None:
            up = up & (act[h] > 0.5)
            dn = dn & (act[h] > 0.5)
        vals_up = jnp.where(up, G_new[h], -jnp.inf)
        arg = jax.lax.argmax(vals_up, 1, jnp.int32)
        m = jnp.max(vals_up, axis=1)
        g_arg = h * base_l + b * block_l + arg
        mn = jnp.min(jnp.where(dn, G_new[h], jnp.inf), axis=1)
        if best is None:
            best, barg, bmin = m, g_arg, mn
        else:
            barg = jnp.where(m > best, g_arg, barg)
            best = jnp.maximum(m, best)
            bmin = jnp.minimum(bmin, mn)
    return G_new, best[:, None], barg[:, None], bmin[:, None]


def _kernel_batched(*refs, block_l: int, base_l: int, masked: bool = False,
                    conj: bool = False):
    """Lane-batched pass B (rbf source): recompute BOTH base rows k_i, k_j
    against the shared X tile (two (B, d) x (d, BL) matmuls), update every
    state half in-register, and emit the per-lane next-i argmax plus both
    KKT gap endpoints.

    Neither row ever touches HBM.  A lane with ``mu == 0`` writes G back
    bitwise unchanged — that is the in-kernel lane freeze: converged lanes
    ride along as masked no-ops until every lane is done.  With
    ``masked=True`` an (H, B, BL) active-set tile rides first in the ref
    list and restricts the next-i scan / gap endpoints (soft shrinking).
    With ``conj=True`` (Conjugate-SMO) a (B, BL) previous-direction tile
    ``dirv`` rides after U, the per-lane scalars gain ``mu2``, the update
    gains the axpy ``- mu2 dirv`` and the *base* row difference
    ``r = k_i - k_j`` — next iteration's direction — is emitted as a fifth
    output (base width: the doubled halves tile it outside the kernel).
    """
    act_ref, refs = (refs[0], refs[1:]) if masked else (None, refs)
    if conj:
        (xqi_ref, xqj_ref, scal_ref, X_ref, sqn_ref, G_ref, alpha_ref,
         L_ref, U_ref, dirv_ref, G_out, bmax_out, barg_out, bmin_out,
         r_out) = refs
    else:
        (xqi_ref, xqj_ref, scal_ref, X_ref, sqn_ref, G_ref, alpha_ref,
         L_ref, U_ref, G_out, bmax_out, barg_out, bmin_out) = refs
        dirv_ref = r_out = None
    b = pl.program_id(0)
    # per-lane scalars: [sqq_i, sqq_j, mu, gamma] (+ [mu2] when conj)
    sqq_i = scal_ref[:, 0:1]
    sqq_j = scal_ref[:, 1:2]
    mu = scal_ref[:, 2:3]
    gamma = scal_ref[:, 3:4]
    mu2 = scal_ref[:, 4:5] if conj else None

    x = X_ref[...]                      # (BL, d) shared tile
    acc = jnp.promote_types(x.dtype, jnp.float32)
    prod_i = jax.lax.dot_general(xqi_ref[...], x, (((1,), (1,)), ((), ())),
                                 preferred_element_type=acc)
    prod_j = jax.lax.dot_general(xqj_ref[...], x, (((1,), (1,)), ((), ())),
                                 preferred_element_type=acc)
    sqn = sqn_ref[...]
    k_i = jnp.exp(-gamma * jnp.maximum(sqq_i + sqn - 2.0 * prod_i, 0.0))
    k_j = jnp.exp(-gamma * jnp.maximum(sqq_j + sqn - 2.0 * prod_j, 0.0))

    G_new, bmax, barg, bmin = _update_from_rows(
        k_i, k_j, G_ref[...], alpha_ref[...], L_ref[...], U_ref[...], mu,
        b, block_l=block_l, base_l=base_l,
        act=None if act_ref is None else act_ref[...],
        dirv=None if dirv_ref is None else dirv_ref[...][None], mu2=mu2)
    G_out[...] = G_new.astype(G_out.dtype)
    bmax_out[...] = bmax
    barg_out[...] = barg
    bmin_out[...] = bmin
    if conj:
        r_out[...] = (k_i - k_j).astype(r_out.dtype)


def _kernel_batched_rows(*refs, block_l: int, base_l: int,
                         masked: bool = False, conj: bool = False):
    """Lane-batched pass B (rows source): both base row tiles arrive
    pre-gathered (Gram-bank mode) — same update algebra, no matmuls.
    ``conj`` as in :func:`_kernel_batched` (scalars become [mu, mu2])."""
    act_ref, refs = (refs[0], refs[1:]) if masked else (None, refs)
    if conj:
        (kri_ref, krj_ref, scal_ref, G_ref, alpha_ref, L_ref, U_ref,
         dirv_ref, G_out, bmax_out, barg_out, bmin_out, r_out) = refs
    else:
        (kri_ref, krj_ref, scal_ref, G_ref, alpha_ref, L_ref, U_ref,
         G_out, bmax_out, barg_out, bmin_out) = refs
        dirv_ref = r_out = None
    b = pl.program_id(0)
    mu = scal_ref[:, 0:1]
    mu2 = scal_ref[:, 1:2] if conj else None
    k_i, k_j = kri_ref[...], krj_ref[...]
    G_new, bmax, barg, bmin = _update_from_rows(
        k_i, k_j, G_ref[...], alpha_ref[...], L_ref[...],
        U_ref[...], mu, b, block_l=block_l, base_l=base_l,
        act=None if act_ref is None else act_ref[...],
        dirv=None if dirv_ref is None else dirv_ref[...][None], mu2=mu2)
    G_out[...] = G_new.astype(G_out.dtype)
    bmax_out[...] = bmax
    barg_out[...] = barg
    bmin_out[...] = bmin
    if conj:
        r_out[...] = (k_i - k_j).astype(r_out.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_l", "interpret", "base_l"))
def rbf_update_wss_batched_pallas(X, sqn, G, alpha_new, L, U, XQi, XQj,
                                  scalars, act=None, dirv=None, *,
                                  block_l: int = 1024,
                                  interpret: bool = False, base_l: int = 0):
    """Launch lane-batched pass B.  The state leaves are (H, B, lpad) half
    stacks (H = 2 for the doubled ε-SVR operator); ``XQi``/``XQj`` are the
    (B, d) *base* query rows and ``scalars`` the packed (B, 4) array
    [sqq_i, sqq_j, mu, gamma] per lane.  ``act`` is an optional
    (H, B, lpad) active-set stack (data dtype 1.0/0.0).  Returns
    (G_new (H, B, lpad), bmax_up (B, nb), barg_up (B, nb), bmin_dn (B, nb)).

    ``dirv`` (Conjugate-SMO) is an optional (B, lpad) *base-width*
    previous-direction row (the doubled operator's direction is
    half-symmetric, so one base row serves both halves); with it,
    ``scalars`` is (B, 5) [..., mu2] and a fifth output ``r`` (B, lpad) —
    the base row difference k_i - k_j — is returned."""
    H, B, lpad = G.shape
    d = X.shape[1]
    assert lpad % block_l == 0, (lpad, block_l)
    nb = lpad // block_l
    dtype = X.dtype

    lane_spec = pl.BlockSpec((H, B, block_l), lambda b: (0, 0, b))
    row_spec = pl.BlockSpec((B, block_l), lambda b: (0, b))
    blk_spec = pl.BlockSpec((B, 1), lambda b: (0, b))
    masked = act is not None
    conj = dirv is not None
    n_scal = 5 if conj else 4
    out_shapes = [
        jax.ShapeDtypeStruct((H, B, lpad), dtype),
        jax.ShapeDtypeStruct((B, nb), dtype),
        jax.ShapeDtypeStruct((B, nb), jnp.int32),
        jax.ShapeDtypeStruct((B, nb), dtype),
    ]
    out_specs = [lane_spec, blk_spec, blk_spec, blk_spec]
    in_specs = [
        pl.BlockSpec((B, d), lambda b: (0, 0)),          # XQi
        pl.BlockSpec((B, d), lambda b: (0, 0)),          # XQj
        pl.BlockSpec((B, n_scal), lambda b: (0, 0)),     # scalars
        pl.BlockSpec((block_l, d), lambda b: (b, 0)),    # X
        pl.BlockSpec((1, block_l), lambda b: (0, b)),    # sqn
        lane_spec, lane_spec, lane_spec, lane_spec,
    ]
    args = [XQi, XQj, scalars, X, sqn.reshape(1, lpad), G, alpha_new, L, U]
    if conj:
        in_specs.append(row_spec)
        args.append(dirv)
        out_specs.append(row_spec)
        out_shapes.append(jax.ShapeDtypeStruct((B, lpad), dtype))
    if masked:
        in_specs.insert(0, lane_spec)
        args.insert(0, act)
    return pl.pallas_call(
        functools.partial(_kernel_batched, block_l=block_l, base_l=base_l,
                          masked=masked, conj=conj),
        grid=(nb,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=tuple(out_shapes),
        interpret=interpret,
    )(*args)


@functools.partial(jax.jit,
                   static_argnames=("block_l", "interpret", "base_l"))
def update_wss_batched_rows_pallas(KRi, KRj, G, alpha_new, L, U, scalars,
                                   act=None, dirv=None, *,
                                   block_l: int = 1024,
                                   interpret: bool = False, base_l: int = 0):
    """Launch lane-batched pass B from pre-gathered base rows ``KRi``/``KRj``
    (B, lpad) — the Gram-bank row source.  ``scalars`` is the packed (B, 1)
    array [mu]; state stack, optional ``act`` stack and ``base_l`` as in
    :func:`rbf_update_wss_batched_pallas`.  ``dirv`` (Conjugate-SMO) as
    there: (B, lpad) base-width direction row, ``scalars`` becomes (B, 2)
    [mu, mu2] and a fifth output ``r`` (B, lpad) is returned."""
    H, B, lpad = G.shape
    assert lpad % block_l == 0, (lpad, block_l)
    nb = lpad // block_l
    dtype = KRi.dtype

    lane_spec = pl.BlockSpec((H, B, block_l), lambda b: (0, 0, b))
    row_spec = pl.BlockSpec((B, block_l), lambda b: (0, b))
    blk_spec = pl.BlockSpec((B, 1), lambda b: (0, b))
    masked = act is not None
    conj = dirv is not None
    n_scal = 2 if conj else 1
    out_shapes = [
        jax.ShapeDtypeStruct((H, B, lpad), dtype),
        jax.ShapeDtypeStruct((B, nb), dtype),
        jax.ShapeDtypeStruct((B, nb), jnp.int32),
        jax.ShapeDtypeStruct((B, nb), dtype),
    ]
    out_specs = [lane_spec, blk_spec, blk_spec, blk_spec]
    in_specs = [
        row_spec,                                        # KRi
        row_spec,                                        # KRj
        pl.BlockSpec((B, n_scal), lambda b: (0, 0)),     # scalars
        lane_spec, lane_spec, lane_spec, lane_spec,
    ]
    args = [KRi, KRj, scalars, G, alpha_new, L, U]
    if conj:
        in_specs.append(row_spec)
        args.append(dirv)
        out_specs.append(row_spec)
        out_shapes.append(jax.ShapeDtypeStruct((B, lpad), dtype))
    if masked:
        in_specs.insert(0, lane_spec)
        args.insert(0, act)
    return pl.pallas_call(
        functools.partial(_kernel_batched_rows, block_l=block_l,
                          base_l=base_l, masked=masked, conj=conj),
        grid=(nb,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=tuple(out_shapes),
        interpret=interpret,
    )(*args)


@functools.partial(jax.jit, static_argnames=("block_l", "interpret"))
def rbf_update_wss_pallas(X, sqn, G, k_i, alpha_new, L, U, xq_j, scalars,
                          *, block_l: int = 1024, interpret: bool = False):
    """Launch pass B.  ``scalars`` is the packed (1, 3) f32 array
    [sqq_j, mu, gamma].  Returns (G_new, bmax_up, barg_up, bmin_dn)."""
    lpad, d = X.shape
    assert lpad % block_l == 0, (lpad, block_l)
    nb = lpad // block_l
    dtype = X.dtype

    row2 = lambda a: a.reshape(1, lpad)
    vec_spec = pl.BlockSpec((1, block_l), lambda b: (0, b))
    blk_spec = pl.BlockSpec((1, 1), lambda b: (0, b))
    out_shapes = (
        jax.ShapeDtypeStruct((1, lpad), dtype),
        jax.ShapeDtypeStruct((1, nb), dtype),
        jax.ShapeDtypeStruct((1, nb), jnp.int32),
        jax.ShapeDtypeStruct((1, nb), dtype),
    )
    G_new, bmax, barg, bmin = pl.pallas_call(
        functools.partial(_kernel, block_l=block_l),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, d), lambda b: (0, 0)),
            pl.BlockSpec((1, 3), lambda b: (0, 0)),
            pl.BlockSpec((block_l, d), lambda b: (b, 0)),
            vec_spec, vec_spec, vec_spec, vec_spec, vec_spec, vec_spec,
        ],
        out_specs=[vec_spec, blk_spec, blk_spec, blk_spec],
        out_shape=out_shapes,
        interpret=interpret,
    )(xq_j.reshape(1, d), scalars, X, row2(sqn), row2(G), row2(k_i),
      row2(alpha_new), row2(L), row2(U))
    return G_new[0], bmax[0], barg[0], bmin[0]
