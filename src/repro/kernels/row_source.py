"""RowSource: the one abstraction for how pass A/B obtain kernel rows.

The fused two-pass engine needs, per iteration, the kernel rows of the two
working-set coordinates plus a handful of O(1) entries.  Three structurally
different suppliers exist:

* **rbf** — rows are recomputed from the shared ``X`` (the accelerator
  memory mode: no Gram is ever materialized);
* **rbf, doubled** — the ε-SVR operator: the lane state has 2l variables
  but row k of ``Q = [[K, K], [K, K]]`` is the *base* row tiled, so every
  row/entry folds its index onto the base axis (``k mod l``) and the O(l d)
  work never doubles;
* **bank** — a shared ``(n_stack, l, l)`` base Gram bank plus a per-lane
  stack index: rows become gathers and the exp work is paid once per
  distinct gamma instead of per iteration (the CPU throughput mode — and,
  via the rows-variant Pallas kernels, available on the
  ``interpret``/``pallas`` backends too).

A :class:`RowSource` is a pytree (jit-transparent; ``dup`` is static) and
is consumed by the dispatchers in :mod:`repro.kernels.ops`
(:func:`~repro.kernels.ops.source_row_wss` /
:func:`~repro.kernels.ops.source_update_wss`) — one call site in the
solver regardless of supplier or backend.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("X", "sqn", "gammas", "gram", "gram_idx"),
    meta_fields=("dup",))
@dataclasses.dataclass(frozen=True)
class RowSource:
    """Where pass A/B kernel rows come from (see module docstring).

    Exactly one of (``X``, ``sqn``) / (``gram``, ``gram_idx``) supplies the
    rows; ``gammas`` is the (B,) per-lane RBF width (used by the rbf
    supplier and by :meth:`entry_pairs`).  ``dup`` marks the doubled ε-SVR
    operator: lane state indices live in [0, 2l) and fold onto the base
    example axis through :meth:`base_idx`.
    """

    X: Optional[jax.Array] = None          # (l, d) base inputs
    sqn: Optional[jax.Array] = None        # (l,) squared norms
    gammas: Optional[jax.Array] = None     # (B,) per-lane RBF widths
    gram: Optional[jax.Array] = None       # (n_stack, l, l) base Gram bank
    gram_idx: Optional[jax.Array] = None   # (B,) lane -> stack entry
    dup: bool = False

    # -- static structure ---------------------------------------------------

    @property
    def is_bank(self) -> bool:
        return self.gram is not None

    @property
    def base_l(self) -> int:
        """True base example count (never the padded or doubled length)."""
        return (self.gram.shape[-1] if self.is_bank else self.X.shape[0])

    @property
    def n(self) -> int:
        """Lane-state width: 2l for the doubled operator, else l."""
        return self.base_l * (2 if self.dup else 1)

    # -- index folding / gathers --------------------------------------------

    def base_idx(self, idx):
        """Fold a (possibly doubled) coordinate index onto the base axis."""
        return idx % self.base_l if self.dup else idx

    def query(self, idx):
        """Per-lane pass inputs at (stacked) coordinate indices ``idx``.

        Bank: the gathered (m, l) *base* rows.  Rbf: the (m, d) base query
        rows plus their squared norms.  Tiling for the doubled operator
        happens downstream (in-kernel, or in the jnp oracle) — never here.
        """
        b = self.base_idx(idx)
        if self.is_bank:
            reps = idx.shape[0] // self.gram_idx.shape[0]
            return self.gram[jnp.tile(self.gram_idx, reps), b]
        return jnp.take(self.X, b, axis=0), jnp.take(self.sqn, b)

    def entry_pairs(self, a, b, reps: int):
        """O(1) kernel entries for ``reps`` stacked (reps*B,) index pairs."""
        if self.is_bank:
            return self.gram[jnp.tile(self.gram_idx, reps),
                             self.base_idx(a), self.base_idx(b)]
        a, b = self.base_idx(a), self.base_idx(b)
        d2 = (jnp.take(self.sqn, a) + jnp.take(self.sqn, b)
              - 2.0 * jnp.sum(jnp.take(self.X, a, axis=0)
                              * jnp.take(self.X, b, axis=0), axis=-1))
        return jnp.exp(-jnp.tile(self.gammas, reps) * jnp.maximum(d2, 0.0))

    def matvec(self, v, block: int = 256):
        """Per-lane operator matvec ``Q_b v_b`` for a (B, n) stack.

        Backs the LIBSVM-style gradient reconstruction
        ``G = p - Q alpha`` after hard shrinking (see
        :func:`repro.core.solver_fused.solve_fused_chunked_qp`).  The
        doubled operator folds its halves first (``Q v = tile(K (v+ +
        v-))``) so the contraction always runs at base width; the rbf
        supplier blocks over rows of X like
        :meth:`repro.core.qp.RBFKernel.matvec` but with per-lane gammas.
        """
        l = self.base_l
        if self.dup:
            v = v[:, :l] + v[:, l:]
        if self.is_bank:
            mv = jnp.einsum("sij,bj->sbi", self.gram, v)
            out = mv[self.gram_idx,
                     jnp.arange(v.shape[0], dtype=jnp.int32)]
        else:
            X, sqn = self.X, self.sqn
            d = X.shape[1]
            pad = (-l) % block
            Xp = jnp.pad(X, ((0, pad), (0, 0)))
            sp = jnp.pad(sqn, (0, pad))

            def blk(args):
                Xb, nb = args
                d2 = nb[:, None] + sqn[None, :] - 2.0 * (Xb @ X.T)
                k = jnp.exp(-self.gammas[:, None, None]
                            * jnp.maximum(d2, 0.0)[None])    # (B, block, l)
                return jnp.einsum("bkl,bl->bk", k, v)

            out = jax.lax.map(blk, (Xp.reshape(-1, block, d),
                                    sp.reshape(-1, block)))
            out = jnp.moveaxis(out, 0, 1).reshape(v.shape[0], -1)[:, :l]
        return jnp.concatenate([out, out], axis=1) if self.dup else out


def rbf_source(X, gammas, B: int, *, dup: bool = False) -> RowSource:
    """Row source recomputing rows from the shared ``X`` (l, d)."""
    X = jnp.asarray(X)
    gammas = jnp.broadcast_to(jnp.asarray(gammas, X.dtype), (B,))
    return RowSource(X=X, sqn=jnp.sum(X * X, axis=-1), gammas=gammas,
                     dup=dup)


def bank_source(gram, gram_idx, gammas=None, *, dup: bool = False
                ) -> RowSource:
    """Row source gathering rows from a shared base Gram bank."""
    gram = jnp.asarray(gram)
    gram_idx = jnp.asarray(gram_idx, jnp.int32)
    if gammas is not None:
        gammas = jnp.broadcast_to(jnp.asarray(gammas, gram.dtype),
                                  gram_idx.shape)
    return RowSource(gram=gram, gram_idx=gram_idx, gammas=gammas, dup=dup)
