"""jit'd wrappers around the Pallas kernels: padding, dispatch, epilogues.

``impl`` selects the backend:
  * "pallas"    — compiled Pallas (TPU target),
  * "interpret" — Pallas interpret mode (CPU correctness validation),
  * "jnp"       — pure-jnp fallback with identical semantics (XLA-fused;
                  the fast path on CPU and the numerical oracle in tests).
  * "auto"      — pallas on TPU, jnp elsewhere.

All wrappers pad the example dimension to the block multiple with *inert*
rows (L = U = 0 so they can never be selected; see sharded.py for the same
trick) and the feature dimension to a lane multiple for the MXU.

The batched wrappers dispatch over a row-source axis as well (see
:mod:`repro.kernels.row_source`): rows recomputed from shared X tiles
(plain or the doubled ε-SVR operator — lane state stacked as (H, B, lpad)
variable halves so the base row tile is computed once and read H times
in-kernel) or gathered from a shared base Gram bank.  Integer working-set
indices travel through dedicated int32 inputs, never through the data
dtype (a float32 round-trip is lossy beyond l = 2^24).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as ref_ops
from repro.kernels.gram_block import gram_pallas
from repro.kernels.rbf_row_wss import (rbf_row_wss_batched_pallas,
                                       rbf_row_wss_pallas,
                                       row_wss_batched_rows_pallas)
from repro.kernels.rbf_update_wss import (rbf_update_wss_batched_pallas,
                                          rbf_update_wss_pallas,
                                          update_wss_batched_rows_pallas)
from repro.kernels.row_source import RowSource

NEG_INF = -jnp.inf


def resolve_impl(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def _pad_l(a, lpad, value=0.0):
    pad = lpad - a.shape[0]
    if pad == 0:
        return a
    widths = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, widths, constant_values=value)


def _pad_d(a, dpad):
    pad = dpad - a.shape[-1]
    if pad == 0:
        return a
    widths = [(0, 0)] * (a.ndim - 1) + [(0, pad)]
    return jnp.pad(a, widths)


def pad_dims(l: int, d: int, block_l: int) -> Tuple[int, int]:
    lpad = ((l + block_l - 1) // block_l) * block_l
    dpad = ((d + 127) // 128) * 128
    return lpad, dpad


def _iscal(i_idx, n: int):
    """Pack integer per-lane indices into the int32 side channel (n, 1).

    Indices must NEVER round-trip through the data dtype: float32 has a
    24-bit significand, so ``jnp.asarray(i, jnp.float32)`` silently
    corrupts indices beyond 2^24 — a real bound for large-l training sets.
    """
    return jnp.asarray(i_idx, jnp.int32).reshape(n, 1)


def rbf_row_wss(X, sqn, G, alpha, L, U, xq, a_i, L_i, U_i, g_i, i_idx,
                use_exact, gamma, *, impl: str = "auto",
                block_l: int = 1024):
    """Pass A: returns (k_i (l,), j (int32), gain_j)."""
    impl = resolve_impl(impl)
    l, d = X.shape
    if impl == "jnp":
        return ref_ops.rbf_row_wss(X, sqn, G, alpha, L, U, xq, a_i, L_i,
                                   U_i, g_i, i_idx, use_exact, gamma)
    lpad, dpad = pad_dims(l, d, block_l)
    dtype = X.dtype
    scal = jnp.stack([jnp.dot(xq, xq), a_i, L_i, U_i, g_i,
                      jnp.asarray(gamma, dtype),
                      use_exact.astype(dtype)]).reshape(1, 7).astype(dtype)
    k, bmax, barg = rbf_row_wss_pallas(
        _pad_d(_pad_l(X, lpad), dpad), _pad_l(sqn, lpad), _pad_l(G, lpad),
        _pad_l(alpha, lpad), _pad_l(L, lpad), _pad_l(U, lpad),
        _pad_d(xq, dpad), scal, _iscal(i_idx, 1),
        block_l=block_l, interpret=(impl == "interpret"))
    w = jax.lax.argmax(bmax, 0, jnp.int32)
    return k[:l], jnp.take(barg, w), jnp.take(bmax, w)


def rbf_update_wss(X, sqn, G, k_i, alpha_new, L, U, xq_j, mu, gamma,
                   *, impl: str = "auto", block_l: int = 1024):
    """Pass B: returns (G_new (l,), i_next, g_i_next, g_dn)."""
    impl = resolve_impl(impl)
    l, d = X.shape
    if impl == "jnp":
        return ref_ops.rbf_update_wss(X, sqn, G, k_i, xq_j, mu, alpha_new,
                                      L, U, gamma)
    lpad, dpad = pad_dims(l, d, block_l)
    dtype = X.dtype
    scal = jnp.stack([jnp.dot(xq_j, xq_j), jnp.asarray(mu, dtype),
                      jnp.asarray(gamma, dtype)]).reshape(1, 3).astype(dtype)
    G_new, bmax, barg, bmin = rbf_update_wss_pallas(
        _pad_d(_pad_l(X, lpad), dpad), _pad_l(sqn, lpad), _pad_l(G, lpad),
        _pad_l(k_i, lpad), _pad_l(alpha_new, lpad), _pad_l(L, lpad),
        _pad_l(U, lpad), _pad_d(xq_j, dpad), scal,
        block_l=block_l, interpret=(impl == "interpret"))
    w = jax.lax.argmax(bmax, 0, jnp.int32)
    return (G_new[:l], jnp.take(barg, w), jnp.take(bmax, w), jnp.min(bmin))


# ---------------------------------------------------------------------------
# Lane-batched wrappers (one lane = one QP; X is shared across lanes)
# ---------------------------------------------------------------------------
#
# The example dimension is padded exactly as above; the lane dimension is
# padded to a sublane multiple (8) with *inert* lanes: L = U = alpha = 0
# rows can never be selected in pass A, and mu = 0 makes pass B a no-op, so
# padded lanes never influence the epilogue reductions.
#
# The doubled ε-SVR operator (dup=True, lane state n = 2l) is carried as an
# (2, bpad, lpad) half stack: the kernels compute the base row tile once
# per grid step and apply it to both halves via index arithmetic, so the
# matmul width, the VMEM X tile, and the padded HBM traffic all stay those
# of the base problem (the old launch path pre-tiled X to 2l).

_LANE = 8


def pad_lanes(B: int) -> int:
    return ((B + _LANE - 1) // _LANE) * _LANE


def _pad_bl(a, bpad, lpad, value=0.0):
    """Pad a (B, l) per-lane state array on both axes."""
    return jnp.pad(a, ((0, bpad - a.shape[0]), (0, lpad - a.shape[1])),
                   constant_values=value)


def _pad_b(a, bpad, value=0.0):
    widths = [(0, bpad - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, widths, constant_values=value)


def _first_max(bmax, barg):
    """Cross-block reduction matching ``jnp.argmax`` tie-breaking.

    Picks the LOWEST global index among blocks attaining the max.  A plain
    argmax over blocks is only order-correct while per-block winners are
    monotone in global index — the doubled half stack breaks that (half 1
    of block b carries larger indices than half 0 of block b+1), so a
    bitwise gain tie could otherwise select a different (valid but
    oracle-divergent) coordinate.  Returns (idx (B,), max (B,)).
    """
    best = jnp.max(bmax, axis=1, keepdims=True)
    sentinel = jnp.iinfo(jnp.int32).max
    cand = jnp.where(bmax == best, barg, sentinel)
    return jnp.min(cand, axis=1), best[:, 0]


def _stack_halves(a, H: int, bpad: int, lpad: int, value=0.0):
    """(B, H*l) lane state -> (H, bpad, lpad) inert-padded half stack."""
    l = a.shape[1] // H
    return jnp.stack([_pad_bl(a[:, h * l:(h + 1) * l], bpad, lpad, value)
                      for h in range(H)], axis=0)


def _unstack_halves(a, B: int, l: int):
    """(H, bpad, lpad) kernel output -> (B, H*l) lane state."""
    return jnp.concatenate([a[h, :B, :l] for h in range(a.shape[0])],
                           axis=1)


def rbf_row_wss_batched(X, sqn, G, alpha, L, U, XQ, sqq, a_i, L_i, U_i,
                        g_i, i_idx, use_exact, gammas, *, impl: str = "auto",
                        block_l: int = 1024, dup: bool = False, act=None):
    """Batched pass A: per-lane WSS2 selection, returns (j (B,), gain (B,)).

    ``X``/``sqn`` are shared; ``G``/``alpha``/``L``/``U`` are (B, n); ``XQ``
    is the (B, d) gathered *base* query rows; the rest are (B,) per-lane
    scalars.  ``dup=True`` runs the doubled ε-SVR operator (n = 2l over
    base ``X``/``sqn``): the jnp oracle computes the base (B, l) row and
    tiles it; the Pallas path stacks the lane state into (2, B, lpad)
    halves and the kernel reads the base row tile twice — the matmul never
    widens past l.  ``act`` is an optional (B, n) active-set mask (soft
    shrinking: restricts the j-scan only).
    """
    impl = resolve_impl(impl)
    if impl == "jnp":
        return ref_ops.rbf_row_wss_batched(X, sqn, G, alpha, L, U, XQ, sqq,
                                           a_i, L_i, U_i, g_i, i_idx,
                                           use_exact, gammas, dup=dup,
                                           act=act)
    l, d = X.shape
    H = 2 if dup else 1
    B = G.shape[0]
    lpad, dpad = pad_dims(l, d, block_l)
    bpad = pad_lanes(B)
    dtype = X.dtype
    scal = jnp.stack([sqq, jnp.broadcast_to(gammas, (B,)),
                      a_i, L_i, U_i, g_i,
                      use_exact.astype(dtype)], axis=1).astype(dtype)
    act_st = (None if act is None
              else _stack_halves(act.astype(dtype), H, bpad, lpad))
    bmax, barg = rbf_row_wss_batched_pallas(
        _pad_d(_pad_l(X, lpad), dpad), _pad_l(sqn, lpad),
        _stack_halves(G, H, bpad, lpad), _stack_halves(alpha, H, bpad, lpad),
        _stack_halves(L, H, bpad, lpad), _stack_halves(U, H, bpad, lpad),
        _pad_b(_pad_d(XQ, dpad), bpad), _pad_b(scal, bpad),
        _pad_b(_iscal(i_idx, B), bpad), act_st,
        block_l=block_l, interpret=(impl == "interpret"), base_l=l)
    j, gain = _first_max(bmax, barg)
    return j[:B], gain[:B]


def rbf_update_wss_batched(X, sqn, G, alpha_new, L, U, XQi, sqqi, XQj, sqqj,
                           mu, gammas, *, impl: str = "auto",
                           block_l: int = 1024, dup: bool = False, act=None,
                           dirv=None, mu2=None):
    """Batched pass B: returns (G_new (B, n), i_next, g_i_next, g_dn).

    Recomputes both *base* rows k_i/k_j against the shared X (no HBM
    round-trip for either); a lane with ``mu == 0`` leaves G bitwise
    unchanged.  ``dup`` selects the doubled ε-SVR operator exactly as in
    :func:`rbf_row_wss_batched` (in-kernel half reads, l-wide matmuls).
    ``act`` optionally restricts the next-i scan and gap endpoints (the
    gradient update is never masked).  ``dirv``/``mu2`` engage the
    Conjugate-SMO second-direction axpy and grow the return by
    ``r = k_i - k_j`` (at full lane-state width) — see
    :func:`repro.kernels.ref.update_wss_batched_from_rows`.
    """
    impl = resolve_impl(impl)
    if impl == "jnp":
        return ref_ops.rbf_update_wss_batched(X, sqn, G, alpha_new, L, U,
                                              XQi, sqqi, XQj, sqqj, mu,
                                              gammas, dup=dup, act=act,
                                              dirv=dirv, mu2=mu2)
    l, d = X.shape
    H = 2 if dup else 1
    B = G.shape[0]
    lpad, dpad = pad_dims(l, d, block_l)
    bpad = pad_lanes(B)
    dtype = X.dtype
    conj = dirv is not None
    if conj:
        scal = jnp.stack([sqqi, sqqj, jnp.broadcast_to(mu, (B,)),
                          jnp.broadcast_to(gammas, (B,)),
                          jnp.broadcast_to(mu2, (B,))],
                         axis=1).astype(dtype)
        # the doubled operator's direction rows are half-symmetric (tiled
        # base rows), so the kernels carry the base half only
        dirv_row = _pad_bl(dirv[:, :l].astype(dtype), bpad, lpad)
    else:
        scal = jnp.stack([sqqi, sqqj, jnp.broadcast_to(mu, (B,)),
                          jnp.broadcast_to(gammas, (B,))],
                         axis=1).astype(dtype)
        dirv_row = None
    act_st = (None if act is None
              else _stack_halves(act.astype(dtype), H, bpad, lpad))
    out = rbf_update_wss_batched_pallas(
        _pad_d(_pad_l(X, lpad), dpad), _pad_l(sqn, lpad),
        _stack_halves(G, H, bpad, lpad),
        _stack_halves(alpha_new, H, bpad, lpad),
        _stack_halves(L, H, bpad, lpad), _stack_halves(U, H, bpad, lpad),
        _pad_b(_pad_d(XQi, dpad), bpad), _pad_b(_pad_d(XQj, dpad), bpad),
        _pad_b(scal, bpad), act_st, dirv_row,
        block_l=block_l, interpret=(impl == "interpret"), base_l=l)
    G_new, bmax, barg, bmin = out[:4]
    i_next, g_i_next = _first_max(bmax, barg)
    res = (_unstack_halves(G_new, B, l), i_next[:B], g_i_next[:B],
           jnp.min(bmin, axis=1)[:B])
    if conj:
        r = out[4][:B, :l]
        return res + (ref_ops.tile_rows(r) if dup else r,)
    return res


def row_wss_batched_rows(KR, G, alpha, L, U, a_i, L_i, U_i, g_i, i_idx,
                         use_exact, *, impl: str = "auto",
                         block_l: int = 1024, dup: bool = False, act=None):
    """Batched pass A from pre-gathered *base* rows ``KR`` (B, l) — the
    Gram-bank row source.  Same contract as :func:`rbf_row_wss_batched`
    (including the optional ``act`` mask); the jnp path tiles the rows for
    the doubled operator, the Pallas path reads the row tile once per half
    in-kernel."""
    impl = resolve_impl(impl)
    if impl == "jnp":
        k = ref_ops.tile_rows(KR) if dup else KR
        return ref_ops.row_wss_batched_from_k(k, G, alpha, L, U, a_i, L_i,
                                              U_i, g_i, i_idx, use_exact,
                                              act=act)
    B, l = KR.shape
    H = 2 if dup else 1
    lpad = pad_dims(l, 1, block_l)[0]
    bpad = pad_lanes(B)
    dtype = KR.dtype
    scal = jnp.stack([a_i, L_i, U_i, g_i,
                      use_exact.astype(dtype)], axis=1).astype(dtype)
    act_st = (None if act is None
              else _stack_halves(act.astype(dtype), H, bpad, lpad))
    bmax, barg = row_wss_batched_rows_pallas(
        _pad_bl(KR, bpad, lpad), _stack_halves(G, H, bpad, lpad),
        _stack_halves(alpha, H, bpad, lpad),
        _stack_halves(L, H, bpad, lpad), _stack_halves(U, H, bpad, lpad),
        _pad_b(scal, bpad), _pad_b(_iscal(i_idx, B), bpad), act_st,
        block_l=block_l, interpret=(impl == "interpret"), base_l=l)
    j, gain = _first_max(bmax, barg)
    return j[:B], gain[:B]


def update_wss_batched_rows(KRi, KRj, G, alpha_new, L, U, mu, *,
                            impl: str = "auto", block_l: int = 1024,
                            dup: bool = False, act=None, dirv=None,
                            mu2=None):
    """Batched pass B from pre-gathered *base* rows — the Gram-bank row
    source.  Same contract as :func:`rbf_update_wss_batched` (including
    the ``dirv``/``mu2`` Conjugate-SMO extension)."""
    impl = resolve_impl(impl)
    if impl == "jnp":
        ki = ref_ops.tile_rows(KRi) if dup else KRi
        kj = ref_ops.tile_rows(KRj) if dup else KRj
        return ref_ops.update_wss_batched_from_rows(G, ki, kj, mu,
                                                    alpha_new, L, U,
                                                    act=act, dirv=dirv,
                                                    mu2=mu2)
    B, l = KRi.shape
    H = 2 if dup else 1
    lpad = pad_dims(l, 1, block_l)[0]
    bpad = pad_lanes(B)
    dtype = KRi.dtype
    conj = dirv is not None
    if conj:
        scal = jnp.stack([jnp.broadcast_to(mu, (B,)),
                          jnp.broadcast_to(mu2, (B,))], axis=1).astype(dtype)
        dirv_row = _pad_bl(dirv[:, :l].astype(dtype), bpad, lpad)
    else:
        scal = jnp.broadcast_to(mu, (B,)).astype(dtype)[:, None]
        dirv_row = None
    act_st = (None if act is None
              else _stack_halves(act.astype(dtype), H, bpad, lpad))
    out = update_wss_batched_rows_pallas(
        _pad_bl(KRi, bpad, lpad), _pad_bl(KRj, bpad, lpad),
        _stack_halves(G, H, bpad, lpad),
        _stack_halves(alpha_new, H, bpad, lpad),
        _stack_halves(L, H, bpad, lpad), _stack_halves(U, H, bpad, lpad),
        _pad_b(scal, bpad), act_st, dirv_row,
        block_l=block_l, interpret=(impl == "interpret"), base_l=l)
    G_new, bmax, barg, bmin = out[:4]
    i_next, g_i_next = _first_max(bmax, barg)
    res = (_unstack_halves(G_new, B, l), i_next[:B], g_i_next[:B],
           jnp.min(bmin, axis=1)[:B])
    if conj:
        r = out[4][:B, :l]
        return res + (ref_ops.tile_rows(r) if dup else r,)
    return res


# ---------------------------------------------------------------------------
# RowSource dispatchers: one call site per pass, any supplier x backend
# ---------------------------------------------------------------------------


def source_row_wss(src: RowSource, G, alpha, L, U, i_idx, a_i, L_i, U_i,
                   g_i, use_exact, *, impl: str = "auto",
                   block_l: int = 1024, act=None):
    """Batched pass A against any :class:`~repro.kernels.row_source.RowSource`.

    ``act`` is an optional (B, n) active-set mask (soft shrinking).
    Returns (j (B,), gain (B,)) — the per-lane WSS2 selection.
    """
    if src.is_bank:
        KR = src.query(i_idx).astype(G.dtype)
        return row_wss_batched_rows(KR, G, alpha, L, U, a_i, L_i, U_i, g_i,
                                    i_idx, use_exact, impl=impl,
                                    block_l=block_l, dup=src.dup, act=act)
    XQ, sqq = src.query(i_idx)
    return rbf_row_wss_batched(src.X, src.sqn, G, alpha, L, U, XQ, sqq,
                               a_i, L_i, U_i, g_i, i_idx, use_exact,
                               src.gammas, impl=impl, block_l=block_l,
                               dup=src.dup, act=act)


def source_update_wss(src: RowSource, G, alpha_new, L, U, i_idx, j_idx, mu,
                      *, impl: str = "auto", block_l: int = 1024, act=None,
                      dirv=None, mu2=None):
    """Batched pass B against any :class:`~repro.kernels.row_source.RowSource`.

    ``act`` is an optional (B, n) active-set mask (soft shrinking; the
    gradient update itself is never masked).
    Returns (G_new (B, n), i_next (B,), g_i_next (B,), g_dn (B,)).

    ``dirv``/``mu2`` (Conjugate-SMO): apply the extra per-lane axpy
    ``- mu2 dirv`` to the gradient in the same pass and grow the return by
    ``r = k_i - k_j`` (B, n) — the direction Q-product the caller carries
    into the next iteration.  Left at ``None`` the contract (and the
    traced jaxpr) is exactly the plain 4-tuple.
    """
    B = G.shape[0]
    stacked = jnp.concatenate([i_idx, j_idx])
    if src.is_bank:
        rows = src.query(stacked).astype(G.dtype)   # ONE (2B, l) gather
        return update_wss_batched_rows(rows[:B], rows[B:], G, alpha_new,
                                       L, U, mu, impl=impl,
                                       block_l=block_l, dup=src.dup,
                                       act=act, dirv=dirv, mu2=mu2)
    XQ, sqq = src.query(stacked)
    return rbf_update_wss_batched(src.X, src.sqn, G, alpha_new, L, U,
                                  XQ[:B], sqq[:B], XQ[B:], sqq[B:], mu,
                                  src.gammas, impl=impl, block_l=block_l,
                                  dup=src.dup, act=act, dirv=dirv, mu2=mu2)


def gram(X1, X2=None, gamma=1.0, *, impl: str = "auto",
         block_i: int = 256, block_j: int = 256):
    """(Cross-)Gram matrix k(X1, X2) -> (l1, l2)."""
    impl = resolve_impl(impl)
    if X2 is None:
        X2 = X1
    if impl == "jnp":
        return ref_ops.gram_cross(X1, X2, gamma)
    l1, d = X1.shape
    l2 = X2.shape[0]
    l1p = ((l1 + block_i - 1) // block_i) * block_i
    l2p = ((l2 + block_j - 1) // block_j) * block_j
    dpad = ((d + 127) // 128) * 128
    s1 = jnp.sum(X1 * X1, axis=-1)
    s2 = jnp.sum(X2 * X2, axis=-1)
    out = gram_pallas(
        _pad_d(_pad_l(X1, l1p), dpad), _pad_d(_pad_l(X2, l2p), dpad),
        _pad_l(s1, l1p), _pad_l(s2, l2p), gamma,
        block_i=block_i, block_j=block_j,
        interpret=(impl == "interpret"))
    return out[:l1, :l2]
