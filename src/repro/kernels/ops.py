"""jit'd wrappers around the Pallas kernels: padding, dispatch, epilogues.

``impl`` selects the backend:
  * "pallas"    — compiled Pallas (TPU target),
  * "interpret" — Pallas interpret mode (CPU correctness validation),
  * "jnp"       — pure-jnp fallback with identical semantics (XLA-fused;
                  the fast path on CPU and the numerical oracle in tests).
  * "auto"      — pallas on TPU, jnp elsewhere.

All wrappers pad the example dimension to the block multiple with *inert*
rows (L = U = 0 so they can never be selected; see sharded.py for the same
trick) and the feature dimension to a lane multiple for the MXU.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as ref_ops
from repro.kernels.gram_block import gram_pallas
from repro.kernels.rbf_row_wss import (rbf_row_wss_batched_pallas,
                                       rbf_row_wss_pallas)
from repro.kernels.rbf_update_wss import (rbf_update_wss_batched_pallas,
                                          rbf_update_wss_pallas)

NEG_INF = -jnp.inf


def resolve_impl(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def _pad_l(a, lpad, value=0.0):
    pad = lpad - a.shape[0]
    if pad == 0:
        return a
    widths = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, widths, constant_values=value)


def _pad_d(a, dpad):
    pad = dpad - a.shape[-1]
    if pad == 0:
        return a
    widths = [(0, 0)] * (a.ndim - 1) + [(0, pad)]
    return jnp.pad(a, widths)


def pad_dims(l: int, d: int, block_l: int) -> Tuple[int, int]:
    lpad = ((l + block_l - 1) // block_l) * block_l
    dpad = ((d + 127) // 128) * 128
    return lpad, dpad


def rbf_row_wss(X, sqn, G, alpha, L, U, xq, a_i, L_i, U_i, g_i, i_idx,
                use_exact, gamma, *, impl: str = "auto",
                block_l: int = 1024):
    """Pass A: returns (k_i (l,), j (int32), gain_j)."""
    impl = resolve_impl(impl)
    l, d = X.shape
    if impl == "jnp":
        return ref_ops.rbf_row_wss(X, sqn, G, alpha, L, U, xq, a_i, L_i,
                                   U_i, g_i, i_idx, use_exact, gamma)
    lpad, dpad = pad_dims(l, d, block_l)
    dtype = X.dtype
    scal = jnp.stack([jnp.dot(xq, xq), a_i, L_i, U_i, g_i,
                      jnp.asarray(gamma, dtype),
                      use_exact.astype(dtype),
                      jnp.asarray(i_idx, dtype)]).reshape(1, 8).astype(dtype)
    k, bmax, barg = rbf_row_wss_pallas(
        _pad_d(_pad_l(X, lpad), dpad), _pad_l(sqn, lpad), _pad_l(G, lpad),
        _pad_l(alpha, lpad), _pad_l(L, lpad), _pad_l(U, lpad),
        _pad_d(xq, dpad), scal,
        block_l=block_l, interpret=(impl == "interpret"))
    w = jnp.argmax(bmax)
    return k[:l], jnp.take(barg, w), jnp.take(bmax, w)


def rbf_update_wss(X, sqn, G, k_i, alpha_new, L, U, xq_j, mu, gamma,
                   *, impl: str = "auto", block_l: int = 1024):
    """Pass B: returns (G_new (l,), i_next, g_i_next, g_dn)."""
    impl = resolve_impl(impl)
    l, d = X.shape
    if impl == "jnp":
        return ref_ops.rbf_update_wss(X, sqn, G, k_i, xq_j, mu, alpha_new,
                                      L, U, gamma)
    lpad, dpad = pad_dims(l, d, block_l)
    dtype = X.dtype
    scal = jnp.stack([jnp.dot(xq_j, xq_j), jnp.asarray(mu, dtype),
                      jnp.asarray(gamma, dtype)]).reshape(1, 3).astype(dtype)
    G_new, bmax, barg, bmin = rbf_update_wss_pallas(
        _pad_d(_pad_l(X, lpad), dpad), _pad_l(sqn, lpad), _pad_l(G, lpad),
        _pad_l(k_i, lpad), _pad_l(alpha_new, lpad), _pad_l(L, lpad),
        _pad_l(U, lpad), _pad_d(xq_j, dpad), scal,
        block_l=block_l, interpret=(impl == "interpret"))
    w = jnp.argmax(bmax)
    return (G_new[:l], jnp.take(barg, w), jnp.take(bmax, w), jnp.min(bmin))


# ---------------------------------------------------------------------------
# Lane-batched wrappers (one lane = one QP; X is shared across lanes)
# ---------------------------------------------------------------------------
#
# The example dimension is padded exactly as above; the lane dimension is
# padded to a sublane multiple (8) with *inert* lanes: L = U = alpha = 0
# rows can never be selected in pass A, and mu = 0 makes pass B a no-op, so
# padded lanes never influence the epilogue reductions.

_LANE = 8


def pad_lanes(B: int) -> int:
    return ((B + _LANE - 1) // _LANE) * _LANE


def _pad_bl(a, bpad, lpad, value=0.0):
    """Pad a (B, l) per-lane state array on both axes."""
    return jnp.pad(a, ((0, bpad - a.shape[0]), (0, lpad - a.shape[1])),
                   constant_values=value)


def _pad_b(a, bpad, value=0.0):
    widths = [(0, bpad - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, widths, constant_values=value)


def rbf_row_wss_batched(X, sqn, G, alpha, L, U, XQ, sqq, a_i, L_i, U_i,
                        g_i, i_idx, use_exact, gammas, *, impl: str = "auto",
                        block_l: int = 1024, dup: bool = False):
    """Batched pass A: per-lane WSS2 selection, returns (j (B,), gain (B,)).

    ``X``/``sqn`` are shared; ``G``/``alpha``/``L``/``U`` are (B, n); ``XQ``
    is the (B, d) gathered query rows; the rest are (B,) per-lane scalars.
    ``dup=True`` runs the doubled ε-SVR operator (n = 2l over base
    ``X``/``sqn``): the jnp oracle computes the base (B, l) row and tiles
    it; the Pallas path currently tiles ``X`` itself before launch (the
    kernels stay structure-free — in-kernel row tiling is a TPU follow-up).
    """
    impl = resolve_impl(impl)
    if impl == "jnp":
        return ref_ops.rbf_row_wss_batched(X, sqn, G, alpha, L, U, XQ, sqq,
                                           a_i, L_i, U_i, g_i, i_idx,
                                           use_exact, gammas, dup=dup)
    if dup:
        X = jnp.concatenate([X, X], axis=0)
        sqn = jnp.concatenate([sqn, sqn])
    l, d = X.shape
    B = G.shape[0]
    lpad, dpad = pad_dims(l, d, block_l)
    bpad = pad_lanes(B)
    dtype = X.dtype
    scal = jnp.stack([sqq, a_i, L_i, U_i, g_i,
                      jnp.broadcast_to(gammas, (B,)),
                      use_exact.astype(dtype),
                      i_idx.astype(dtype)], axis=1).astype(dtype)
    bmax, barg = rbf_row_wss_batched_pallas(
        _pad_d(_pad_l(X, lpad), dpad), _pad_l(sqn, lpad),
        _pad_bl(G, bpad, lpad), _pad_bl(alpha, bpad, lpad),
        _pad_bl(L, bpad, lpad), _pad_bl(U, bpad, lpad),
        _pad_b(_pad_d(XQ, dpad), bpad), _pad_b(scal, bpad),
        block_l=block_l, interpret=(impl == "interpret"))
    w = jnp.argmax(bmax, axis=1)
    j = jnp.take_along_axis(barg, w[:, None], axis=1)[:, 0]
    gain = jnp.take_along_axis(bmax, w[:, None], axis=1)[:, 0]
    return j[:B], gain[:B]


def rbf_update_wss_batched(X, sqn, G, alpha_new, L, U, XQi, sqqi, XQj, sqqj,
                           mu, gammas, *, impl: str = "auto",
                           block_l: int = 1024, dup: bool = False):
    """Batched pass B: returns (G_new (B, n), i_next, g_i_next, g_dn).

    Recomputes both rows k_i/k_j against the shared X (no HBM round-trip
    for either); a lane with ``mu == 0`` leaves G bitwise unchanged.
    ``dup`` selects the doubled ε-SVR operator exactly as in
    :func:`rbf_row_wss_batched`.
    """
    impl = resolve_impl(impl)
    if impl == "jnp":
        return ref_ops.rbf_update_wss_batched(X, sqn, G, alpha_new, L, U,
                                              XQi, sqqi, XQj, sqqj, mu,
                                              gammas, dup=dup)
    if dup:
        X = jnp.concatenate([X, X], axis=0)
        sqn = jnp.concatenate([sqn, sqn])
    l, d = X.shape
    B = G.shape[0]
    lpad, dpad = pad_dims(l, d, block_l)
    bpad = pad_lanes(B)
    dtype = X.dtype
    scal = jnp.stack([sqqi, sqqj, jnp.broadcast_to(mu, (B,)),
                      jnp.broadcast_to(gammas, (B,))], axis=1).astype(dtype)
    G_new, bmax, barg, bmin = rbf_update_wss_batched_pallas(
        _pad_d(_pad_l(X, lpad), dpad), _pad_l(sqn, lpad),
        _pad_bl(G, bpad, lpad), _pad_bl(alpha_new, bpad, lpad),
        _pad_bl(L, bpad, lpad), _pad_bl(U, bpad, lpad),
        _pad_b(_pad_d(XQi, dpad), bpad), _pad_b(_pad_d(XQj, dpad), bpad),
        _pad_b(scal, bpad),
        block_l=block_l, interpret=(impl == "interpret"))
    w = jnp.argmax(bmax, axis=1)
    i_next = jnp.take_along_axis(barg, w[:, None], axis=1)[:, 0]
    g_i_next = jnp.take_along_axis(bmax, w[:, None], axis=1)[:, 0]
    return (G_new[:B, :l], i_next[:B], g_i_next[:B],
            jnp.min(bmin, axis=1)[:B])


def gram(X1, X2=None, gamma=1.0, *, impl: str = "auto",
         block_i: int = 256, block_j: int = 256):
    """(Cross-)Gram matrix k(X1, X2) -> (l1, l2)."""
    impl = resolve_impl(impl)
    if X2 is None:
        X2 = X1
    if impl == "jnp":
        return ref_ops.gram_cross(X1, X2, gamma)
    l1, d = X1.shape
    l2 = X2.shape[0]
    l1p = ((l1 + block_i - 1) // block_i) * block_i
    l2p = ((l2 + block_j - 1) // block_j) * block_j
    dpad = ((d + 127) // 128) * 128
    s1 = jnp.sum(X1 * X1, axis=-1)
    s2 = jnp.sum(X2 * X2, axis=-1)
    out = gram_pallas(
        _pad_d(_pad_l(X1, l1p), dpad), _pad_d(_pad_l(X2, l2p), dpad),
        _pad_l(s1, l1p), _pad_l(s2, l2p), gamma,
        block_i=block_i, block_j=block_j,
        interpret=(impl == "interpret"))
    return out[:l1, :l2]
