"""jit'd wrappers around the Pallas kernels: padding, dispatch, epilogues.

``impl`` selects the backend:
  * "pallas"    — compiled Pallas (TPU target),
  * "interpret" — Pallas interpret mode (CPU correctness validation),
  * "jnp"       — pure-jnp fallback with identical semantics (XLA-fused;
                  the fast path on CPU and the numerical oracle in tests).
  * "auto"      — pallas on TPU, jnp elsewhere.

All wrappers pad the example dimension to the block multiple with *inert*
rows (L = U = 0 so they can never be selected; see sharded.py for the same
trick) and the feature dimension to a lane multiple for the MXU.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as ref_ops
from repro.kernels.gram_block import gram_pallas
from repro.kernels.rbf_row_wss import rbf_row_wss_pallas
from repro.kernels.rbf_update_wss import rbf_update_wss_pallas

NEG_INF = -jnp.inf


def resolve_impl(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def _pad_l(a, lpad, value=0.0):
    pad = lpad - a.shape[0]
    if pad == 0:
        return a
    widths = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, widths, constant_values=value)


def _pad_d(a, dpad):
    pad = dpad - a.shape[-1]
    if pad == 0:
        return a
    widths = [(0, 0)] * (a.ndim - 1) + [(0, pad)]
    return jnp.pad(a, widths)


def pad_dims(l: int, d: int, block_l: int) -> Tuple[int, int]:
    lpad = ((l + block_l - 1) // block_l) * block_l
    dpad = ((d + 127) // 128) * 128
    return lpad, dpad


def rbf_row_wss(X, sqn, G, alpha, L, U, xq, a_i, L_i, U_i, g_i, i_idx,
                use_exact, gamma, *, impl: str = "auto",
                block_l: int = 1024):
    """Pass A: returns (k_i (l,), j (int32), gain_j)."""
    impl = resolve_impl(impl)
    l, d = X.shape
    if impl == "jnp":
        return ref_ops.rbf_row_wss(X, sqn, G, alpha, L, U, xq, a_i, L_i,
                                   U_i, g_i, i_idx, use_exact, gamma)
    lpad, dpad = pad_dims(l, d, block_l)
    dtype = X.dtype
    scal = jnp.stack([jnp.dot(xq, xq), a_i, L_i, U_i, g_i,
                      jnp.asarray(gamma, dtype),
                      use_exact.astype(dtype),
                      jnp.asarray(i_idx, dtype)]).reshape(1, 8).astype(dtype)
    k, bmax, barg = rbf_row_wss_pallas(
        _pad_d(_pad_l(X, lpad), dpad), _pad_l(sqn, lpad), _pad_l(G, lpad),
        _pad_l(alpha, lpad), _pad_l(L, lpad), _pad_l(U, lpad),
        _pad_d(xq, dpad), scal,
        block_l=block_l, interpret=(impl == "interpret"))
    w = jnp.argmax(bmax)
    return k[:l], jnp.take(barg, w), jnp.take(bmax, w)


def rbf_update_wss(X, sqn, G, k_i, alpha_new, L, U, xq_j, mu, gamma,
                   *, impl: str = "auto", block_l: int = 1024):
    """Pass B: returns (G_new (l,), i_next, g_i_next, g_dn)."""
    impl = resolve_impl(impl)
    l, d = X.shape
    if impl == "jnp":
        return ref_ops.rbf_update_wss(X, sqn, G, k_i, xq_j, mu, alpha_new,
                                      L, U, gamma)
    lpad, dpad = pad_dims(l, d, block_l)
    dtype = X.dtype
    scal = jnp.stack([jnp.dot(xq_j, xq_j), jnp.asarray(mu, dtype),
                      jnp.asarray(gamma, dtype)]).reshape(1, 3).astype(dtype)
    G_new, bmax, barg, bmin = rbf_update_wss_pallas(
        _pad_d(_pad_l(X, lpad), dpad), _pad_l(sqn, lpad), _pad_l(G, lpad),
        _pad_l(k_i, lpad), _pad_l(alpha_new, lpad), _pad_l(L, lpad),
        _pad_l(U, lpad), _pad_d(xq_j, dpad), scal,
        block_l=block_l, interpret=(impl == "interpret"))
    w = jnp.argmax(bmax)
    return (G_new[:l], jnp.take(barg, w), jnp.take(bmax, w), jnp.min(bmin))


def gram(X1, X2=None, gamma=1.0, *, impl: str = "auto",
         block_i: int = 256, block_j: int = 256):
    """(Cross-)Gram matrix k(X1, X2) -> (l1, l2)."""
    impl = resolve_impl(impl)
    if X2 is None:
        X2 = X1
    if impl == "jnp":
        return ref_ops.gram_cross(X1, X2, gamma)
    l1, d = X1.shape
    l2 = X2.shape[0]
    l1p = ((l1 + block_i - 1) // block_i) * block_i
    l2p = ((l2 + block_j - 1) // block_j) * block_j
    dpad = ((d + 127) // 128) * 128
    s1 = jnp.sum(X1 * X1, axis=-1)
    s2 = jnp.sum(X2 * X2, axis=-1)
    out = gram_pallas(
        _pad_d(_pad_l(X1, l1p), dpad), _pad_d(_pad_l(X2, l2p), dpad),
        _pad_l(s1, l1p), _pad_l(s2, l2p), gamma,
        block_i=block_i, block_j=block_j,
        interpret=(impl == "interpret"))
    return out[:l1, :l2]
