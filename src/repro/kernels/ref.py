"""Pure-jnp oracles for the Pallas kernels (the allclose reference)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

TAU = 1e-12


def _act_bool(act):
    """Boolean view of an active-set mask.

    The jnp path hands the mask through as bool; the Pallas path pads it
    as 0/1 floats.  Comparing a bool mask against the Python float 0.5
    weak-promotes it to f64 under x64, so only the float form gets the
    threshold compare.
    """
    return act if act.dtype == jnp.bool_ else act > 0.5


def rbf_row(X, sqn, xq, gamma):
    """k(x_q, X) for one query row."""
    d2 = jnp.dot(xq, xq) + sqn - 2.0 * (X @ xq)
    return jnp.exp(-gamma * jnp.maximum(d2, 0.0))


def rbf_row_wss(X, sqn, G, alpha, L, U, xq, a_i, L_i, U_i, g_i, i_idx,
                use_exact, gamma):
    """Pass A oracle: kernel row k_i + WSS2 j-selection.

    Returns (k_i, j, gain_j).  RBF diag == 1 is hardcoded (paper setting).
    """
    k = rbf_row(X, sqn, xq, gamma)
    l = g_i - G
    q = jnp.maximum(2.0 - 2.0 * k, TAU)
    g_tilde = 0.5 * l * l / q
    lo = jnp.maximum(L_i - a_i, alpha - U)
    hi = jnp.minimum(U_i - a_i, alpha - L)
    mu_c = jnp.clip(l / q, lo, hi)
    g_exact = l * mu_c - 0.5 * q * mu_c * mu_c
    gains = jnp.where(use_exact, g_exact, g_tilde)
    idx = jnp.arange(X.shape[0], dtype=jnp.int32)
    mask = (alpha > L) & (l > 0) & (idx != i_idx)
    vals = jnp.where(mask, gains, -jnp.inf)
    j = jax.lax.argmax(vals, 0, jnp.int32)
    return k, j, vals[j]


def rbf_update_wss(X, sqn, G, k_i, xq_j, mu, alpha_new, L, U, gamma):
    """Pass B oracle: row k_j + gradient update + next i-pick + gap ends.

    Returns (G_new, i_next, g_i_next, g_dn).
    """
    k_j = rbf_row(X, sqn, xq_j, gamma)
    G_new = G - mu * (k_i - k_j)
    up = alpha_new < U
    dn = alpha_new > L
    vals_up = jnp.where(up, G_new, -jnp.inf)
    i_next = jax.lax.argmax(vals_up, 0, jnp.int32)
    g_dn = jnp.min(jnp.where(dn, G_new, jnp.inf))
    return G_new, i_next, vals_up[i_next], g_dn


# ---------------------------------------------------------------------------
# Batched (lane-dimension) oracles
# ---------------------------------------------------------------------------
#
# One lane = one QP (a (C, gamma, labels) grid point).  All lanes share the
# same X / sqn; per-lane state is stacked on a leading B axis.  The O(l d B)
# part — the squared-distance rows — is ONE (B, d) x (d, l) matmul over the
# shared X, and per-lane gamma costs one extra exp on that shared d2 row
# (mirroring the solve_grid factorization).  Unlike the single-lane pass A,
# the batched pass A returns only the selection (j, gain): pass B recomputes
# both rows k_i / k_j in place of an HBM round-trip, which also lets the
# Alg. 3 candidate swap the i-row without a data-dependent relaunch.


def tile_rows(k):
    """Doubled-operator row tiling: (B, l) base rows -> (B, 2l).

    Row k of ``Q = [[K, K], [K, K]]`` is the base row tiled — the jnp
    oracle's counterpart of the Pallas kernels' in-kernel half reads.
    """
    return jnp.concatenate([k, k], axis=1)


def rbf_rows_batched(X, sqn, XQ, sqq, gammas, dup: bool = False):
    """k(x_q^b, X) for a batch of query rows -> (B, l).

    ``dup=True`` returns the *doubled-operator* rows (B, 2l) used by the
    ε-SVR dual (:func:`tile_rows`): the O(B l d) distance matmul runs
    against the base ``X`` only and the 2l half is a free broadcast —
    never a 2l-wide matmul, never a 2l x 2l Gram.
    """
    d2 = sqq[:, None] + sqn[None, :] - 2.0 * (XQ @ X.T)
    k = jnp.exp(-gammas[:, None] * jnp.maximum(d2, 0.0))
    return tile_rows(k) if dup else k


def row_wss_batched_from_k(k, G, alpha, L, U, a_i, L_i, U_i, g_i, i_idx,
                           use_exact, act=None):
    """Pass A selection algebra given the (B, l) kernel rows ``k``.

    Shared by the X-backed oracle below and the Gram-bank gather mode of
    :func:`repro.core.solver_fused.solve_fused_batched`.  RBF diag == 1 is
    hardcoded (paper setting).  ``act`` optionally restricts the j-scan to
    a per-lane (B, n) active set (soft shrinking: G stays exact
    everywhere, only the selection is masked).  Returns
    (j (B,) int32, gain_j (B,)).
    """
    lv = g_i[:, None] - G
    q = jnp.maximum(2.0 - 2.0 * k, TAU)
    g_tilde = 0.5 * lv * lv / q
    lo = jnp.maximum((L_i - a_i)[:, None], alpha - U)
    hi = jnp.minimum((U_i - a_i)[:, None], alpha - L)
    mu_c = jnp.clip(lv / q, lo, hi)
    g_exact = lv * mu_c - 0.5 * q * mu_c * mu_c
    gains = jnp.where(use_exact[:, None], g_exact, g_tilde)
    idx = jnp.arange(G.shape[1], dtype=jnp.int32)
    mask = (alpha > L) & (lv > 0) & (idx[None, :] != i_idx[:, None])
    if act is not None:
        mask = mask & _act_bool(act)
    vals = jnp.where(mask, gains, -jnp.inf)
    j = jax.lax.argmax(vals, 1, jnp.int32)
    return j, jnp.take_along_axis(vals, j[:, None], axis=1)[:, 0]


def rbf_row_wss_batched(X, sqn, G, alpha, L, U, XQ, sqq, a_i, L_i, U_i,
                        g_i, i_idx, use_exact, gammas, dup: bool = False,
                        act=None):
    """Batched pass A oracle: WSS2 j-selection per lane.

    ``G``/``alpha``/``L``/``U`` are (B, n); ``XQ`` is (B, d); the remaining
    per-lane scalars are (B,).  With ``dup=True`` the lane state is doubled
    (n = 2l, the ε-SVR dual) while ``X``/``sqn`` stay the base (l, d)/(l,)
    — the selection algebra is box-general, so the only structural change
    is the tiled row.  Returns (j (B,) int32, gain_j (B,)).
    """
    k = rbf_rows_batched(X, sqn, XQ, sqq, gammas, dup=dup)
    return row_wss_batched_from_k(k, G, alpha, L, U, a_i, L_i, U_i, g_i,
                                  i_idx, use_exact, act=act)


def update_wss_batched_from_rows(G, k_i, k_j, mu, alpha_new, L, U, act=None,
                                 dirv=None, mu2=None):
    """Pass B update + stopping-scan algebra given both (B, l) rows.

    A lane with ``mu == 0`` is a bitwise no-op on G (the in-kernel
    lane-freeze used by ``solve_fused_batched``).  ``act`` optionally
    restricts the next-i scan and the gap endpoints to a per-lane active
    set; the gradient update itself is NEVER masked (soft shrinking keeps
    G exact on every coordinate, so unshrinking is free).  Returns
    (G_new (B, l), i_next (B,), g_i_next (B,), g_dn (B,)).

    ``dirv``/``mu2`` engage the Conjugate-SMO second direction: ``dirv``
    is the carried (B, n) previous update direction's Q-product and the
    gradient update becomes ``G - mu (k_i - k_j) - mu2 dirv`` (a rejected
    conjugate step has ``mu2 == 0``, keeping the plain trajectory bitwise).
    The return grows a fifth element ``r = k_i - k_j`` — next iteration's
    ``dirv`` — ONLY when engaged, so the plain contract is unchanged.
    """
    G_new = G - mu[:, None] * (k_i - k_j)
    if dirv is not None:
        G_new = G_new - mu2[:, None] * dirv
    up = alpha_new < U
    dn = alpha_new > L
    if act is not None:
        up = up & _act_bool(act)
        dn = dn & _act_bool(act)
    vals_up = jnp.where(up, G_new, -jnp.inf)
    i_next = jax.lax.argmax(vals_up, 1, jnp.int32)
    g_i_next = jnp.take_along_axis(vals_up, i_next[:, None], axis=1)[:, 0]
    g_dn = jnp.min(jnp.where(dn, G_new, jnp.inf), axis=1)
    if dirv is not None:
        return G_new, i_next, g_i_next, g_dn, k_i - k_j
    return G_new, i_next, g_i_next, g_dn


def rbf_update_wss_batched(X, sqn, G, alpha_new, L, U, XQi, sqqi, XQj, sqqj,
                           mu, gammas, dup: bool = False, act=None,
                           dirv=None, mu2=None):
    """Batched pass B oracle: k_i/k_j recompute + update + next i + gap ends.

    Both rows come from one stacked (2B, d) x (d, l) matmul (against the
    base ``X`` even when ``dup=True`` doubles the lane state to n = 2l).
    Returns (G_new (B, n), i_next (B,), g_i_next (B,), g_dn (B,)); with
    ``dirv``/``mu2`` (Conjugate-SMO, see
    :func:`update_wss_batched_from_rows`) a fifth ``r = k_i - k_j``.
    """
    B = G.shape[0]
    Kr = rbf_rows_batched(X, sqn,
                          jnp.concatenate([XQi, XQj], axis=0),
                          jnp.concatenate([sqqi, sqqj]),
                          jnp.concatenate([gammas, gammas]), dup=dup)
    return update_wss_batched_from_rows(G, Kr[:B], Kr[B:], mu, alpha_new,
                                        L, U, act=act, dirv=dirv, mu2=mu2)


def gram(X, gamma):
    """Full RBF Gram matrix."""
    sq = jnp.sum(X * X, axis=-1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (X @ X.T)
    return jnp.exp(-gamma * jnp.maximum(d2, 0.0))


def gram_cross(X1, X2, gamma):
    """Cross Gram matrix k(X1, X2) -> (l1, l2)."""
    s1 = jnp.sum(X1 * X1, axis=-1)
    s2 = jnp.sum(X2 * X2, axis=-1)
    d2 = s1[:, None] + s2[None, :] - 2.0 * (X1 @ X2.T)
    return jnp.exp(-gamma * jnp.maximum(d2, 0.0))
