"""Pure-jnp oracles for the Pallas kernels (the allclose reference)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

TAU = 1e-12


def rbf_row(X, sqn, xq, gamma):
    """k(x_q, X) for one query row."""
    d2 = jnp.dot(xq, xq) + sqn - 2.0 * (X @ xq)
    return jnp.exp(-gamma * jnp.maximum(d2, 0.0))


def rbf_row_wss(X, sqn, G, alpha, L, U, xq, a_i, L_i, U_i, g_i, i_idx,
                use_exact, gamma):
    """Pass A oracle: kernel row k_i + WSS2 j-selection.

    Returns (k_i, j, gain_j).  RBF diag == 1 is hardcoded (paper setting).
    """
    k = rbf_row(X, sqn, xq, gamma)
    l = g_i - G
    q = jnp.maximum(2.0 - 2.0 * k, TAU)
    g_tilde = 0.5 * l * l / q
    lo = jnp.maximum(L_i - a_i, alpha - U)
    hi = jnp.minimum(U_i - a_i, alpha - L)
    mu_c = jnp.clip(l / q, lo, hi)
    g_exact = l * mu_c - 0.5 * q * mu_c * mu_c
    gains = jnp.where(use_exact, g_exact, g_tilde)
    idx = jnp.arange(X.shape[0], dtype=jnp.int32)
    mask = (alpha > L) & (l > 0) & (idx != i_idx)
    vals = jnp.where(mask, gains, -jnp.inf)
    j = jnp.argmax(vals).astype(jnp.int32)
    return k, j, vals[j]


def rbf_update_wss(X, sqn, G, k_i, xq_j, mu, alpha_new, L, U, gamma):
    """Pass B oracle: row k_j + gradient update + next i-pick + gap ends.

    Returns (G_new, i_next, g_i_next, g_dn).
    """
    k_j = rbf_row(X, sqn, xq_j, gamma)
    G_new = G - mu * (k_i - k_j)
    up = alpha_new < U
    dn = alpha_new > L
    vals_up = jnp.where(up, G_new, -jnp.inf)
    i_next = jnp.argmax(vals_up).astype(jnp.int32)
    g_dn = jnp.min(jnp.where(dn, G_new, jnp.inf))
    return G_new, i_next, vals_up[i_next], g_dn


def gram(X, gamma):
    """Full RBF Gram matrix."""
    sq = jnp.sum(X * X, axis=-1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (X @ X.T)
    return jnp.exp(-gamma * jnp.maximum(d2, 0.0))


def gram_cross(X1, X2, gamma):
    """Cross Gram matrix k(X1, X2) -> (l1, l2)."""
    s1 = jnp.sum(X1 * X1, axis=-1)
    s2 = jnp.sum(X2 * X2, axis=-1)
    d2 = s1[:, None] + s2[None, :] - 2.0 * (X1 @ X2.T)
    return jnp.exp(-gamma * jnp.maximum(d2, 0.0))
