"""Tiled RBF Gram-matrix Pallas kernel (MXU matmul + fused exp epilogue).

2D grid over (BI, BJ) output tiles.  Each step loads one (BI, d) and one
(BJ, d) tile of the inputs, runs the (BI, d) x (d, BJ) contraction on the
MXU with f32 accumulation, and applies the squared-distance + exp epilogue
on the VPU before a single HBM write of the tile — the distance matrix is
never materialized.  Used by batch/precompute mode, the SVM probe head and
the cross-kernel at prediction time.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(g_ref, X1_ref, X2_ref, s1_ref, s2_ref, out_ref):
    gamma = g_ref[0, 0]
    x1 = X1_ref[...]                       # (BI, d)
    x2 = X2_ref[...]                       # (BJ, d)
    prod = jax.lax.dot_general(x1, x2, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.promote_types(x1.dtype, jnp.float32))
    d2 = s1_ref[...].T + s2_ref[...] - 2.0 * prod   # (BI, BJ)
    out_ref[...] = jnp.exp(-gamma * jnp.maximum(d2, 0.0)).astype(
        out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_i", "block_j",
                                             "interpret"))
def gram_pallas(X1, X2, s1, s2, gamma, *, block_i: int = 256,
                block_j: int = 256, interpret: bool = False):
    """Cross Gram matrix k(X1, X2): (l1, l2).  Inputs padded to block
    multiples by the ops wrapper; padded rows give harmless extra entries
    that the wrapper slices off."""
    l1, d = X1.shape
    l2, _ = X2.shape
    assert l1 % block_i == 0 and l2 % block_j == 0
    out = pl.pallas_call(
        _kernel,
        grid=(l1 // block_i, l2 // block_j),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),           # gamma
            pl.BlockSpec((block_i, d), lambda i, j: (i, 0)),     # X1
            pl.BlockSpec((block_j, d), lambda i, j: (j, 0)),     # X2
            pl.BlockSpec((1, block_i), lambda i, j: (0, i)),     # s1
            pl.BlockSpec((1, block_j), lambda i, j: (0, j)),     # s2
        ],
        out_specs=pl.BlockSpec((block_i, block_j), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((l1, l2), X1.dtype),
        interpret=interpret,
    )(jnp.asarray(gamma, X1.dtype).reshape(1, 1), X1, X2,
      s1.reshape(1, l1), s2.reshape(1, l2))
    return out
