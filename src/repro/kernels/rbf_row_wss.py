"""Pass A Pallas kernel: fused RBF kernel-row + WSS2 j-selection.

Grid: 1D over blocks of the example dimension l (block size BL, a multiple
of 128 so the lane dimension is hardware-aligned).  Per grid step the VMEM
working set is one (BL, d) tile of X plus six (1, BL) vectors — for the
default BL=1024, d<=512 that is ~2.3 MB in f32, comfortably inside the
~16 MB v5e VMEM with double buffering.

The (BL, d) x (d,) matvec runs on the MXU (d padded to a multiple of 128 by
the ops wrapper); the gain algebra and the masked argmax run on the VPU in
the same pass, so G, alpha, L, U are read from HBM exactly once and the
gains are never materialized to HBM.  Outputs: the kernel row k_i (pass B
needs it), and per-block (max, argmax) pairs that the O(nblocks) epilogue
reduces on-chip.

The selection algebra is dual-generic: L/U are arbitrary per-coordinate
boxes (classification, class-weighted, ε-SVR doubled, one-class lanes all
look identical from here); only the RBF ``diag == 1`` identity is
specialized.  Row sources (see :mod:`repro.kernels.row_source`):

* **rbf** — the (B, d) x (d, BL) matmul against the shared X tile;
* **doubled rbf** (``H = 2`` state halves) — the ε-SVR operator: the lane
  state arrives as an (2, B, lpad) stack of the two variable halves, the
  base row tile is computed ONCE per grid step and the selection algebra
  reads it twice via half-offset index arithmetic — the matmul stays
  l-wide (no pre-tiled X, half the VMEM X footprint and HBM traffic of
  the old ops-layer ``concatenate([X, X])`` launch);
* **rows** — pre-gathered base kernel rows (Gram-bank mode): no X at all,
  the tile is a (B, BL) slab of the gathered row block (also honouring
  the doubled half structure).

Working-set indices travel through a dedicated int32 channel (``iscal``),
never through the data dtype — exact for any l (a float32 round-trip is
lossy beyond 2^24).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TAU = 1e-12


def _kernel(xq_ref, scal_ref, iscal_ref, X_ref, sqn_ref, G_ref, alpha_ref,
            L_ref, U_ref, k_out, bmax_out, barg_out, *, block_l: int):
    b = pl.program_id(0)
    # scalars: [sqq, a_i, L_i, U_i, g_i, gamma, use_exact]; int: [i_idx]
    sqq = scal_ref[0, 0]
    a_i = scal_ref[0, 1]
    L_i = scal_ref[0, 2]
    U_i = scal_ref[0, 3]
    g_i = scal_ref[0, 4]
    gamma = scal_ref[0, 5]
    use_exact = scal_ref[0, 6] > 0.5
    i_idx = iscal_ref[0, 0]

    x = X_ref[...]                      # (BL, d)
    q = xq_ref[...]                     # (1, d)
    prod = jax.lax.dot_general(x, q, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.promote_types(x.dtype, jnp.float32))  # (BL, 1)
    d2 = sqq + sqn_ref[...] - 2.0 * prod.reshape(1, block_l)        # (1, BL)
    k = jnp.exp(-gamma * jnp.maximum(d2, 0.0))
    k_out[...] = k.astype(k_out.dtype)

    G = G_ref[...]
    alpha = alpha_ref[...]
    L = L_ref[...]
    U = U_ref[...]
    l_vec = g_i - G
    q_vec = jnp.maximum(2.0 - 2.0 * k, TAU)      # RBF diag == 1
    g_tilde = 0.5 * l_vec * l_vec / q_vec
    lo = jnp.maximum(L_i - a_i, alpha - U)
    hi = jnp.minimum(U_i - a_i, alpha - L)
    mu_c = jnp.clip(l_vec / q_vec, lo, hi)
    g_exact = l_vec * mu_c - 0.5 * q_vec * mu_c * mu_c
    gains = jnp.where(use_exact, g_exact, g_tilde)

    gidx = (b * block_l
            + jax.lax.broadcasted_iota(jnp.int32, (1, block_l), 1))
    mask = (alpha > L) & (l_vec > 0) & (gidx != i_idx)
    vals = jnp.where(mask, gains, -jnp.inf)
    arg = jax.lax.argmax(vals[0], 0, jnp.int32)
    bmax_out[0, 0] = vals[0, arg]
    barg_out[0, 0] = b * block_l + arg


def _select_from_k(k, G, alpha, L, U, scal, i_idx, b, *, block_l: int,
                   base_l: int, act=None):
    """Shared WSS2 selection algebra over the (H, B, BL) state halves.

    ``k`` is the (B, BL) *base* kernel-row tile; the doubled ε-SVR operator
    (H = 2) reads it once per half — row k of Q = [[K, K], [K, K]] is the
    base row tiled, so the duplication is index arithmetic, not a second
    matmul.  The global coordinate of half h is ``h * base_l + offset``
    (``base_l`` is the TRUE base example count — padded tails are inert).
    ``act`` is an optional (H, B, BL) active-set tile in the data dtype
    (1.0 active / 0.0 shrunk) that further masks the j-scan.
    Returns the per-block (best (B, 1), arg (B, 1) int32) pair.
    """
    H = G.shape[0]
    # per-lane scalars: [a_i, L_i, U_i, g_i, use_exact] columns of scal
    a_i = scal[:, 0:1]
    L_i = scal[:, 1:2]
    U_i = scal[:, 2:3]
    g_i = scal[:, 3:4]
    use_exact = scal[:, 4:5] > 0.5
    q_vec = jnp.maximum(2.0 - 2.0 * k, TAU)      # RBF diag == 1
    best = None
    barg = None
    for h in range(H):
        Gh, ah, Lh, Uh = G[h], alpha[h], L[h], U[h]
        l_vec = g_i - Gh
        g_tilde = 0.5 * l_vec * l_vec / q_vec
        lo = jnp.maximum(L_i - a_i, ah - Uh)
        hi = jnp.minimum(U_i - a_i, ah - Lh)
        mu_c = jnp.clip(l_vec / q_vec, lo, hi)
        g_exact = l_vec * mu_c - 0.5 * q_vec * mu_c * mu_c
        gains = jnp.where(use_exact, g_exact, g_tilde)
        gidx = (h * base_l + b * block_l
                + jax.lax.broadcasted_iota(jnp.int32, k.shape, 1))
        mask = (ah > Lh) & (l_vec > 0) & (gidx != i_idx)
        if act is not None:
            mask = mask & (act[h] > 0.5)
        vals = jnp.where(mask, gains, -jnp.inf)
        arg = jax.lax.argmax(vals, 1, jnp.int32)
        m = jnp.max(vals, axis=1)
        g_arg = h * base_l + b * block_l + arg
        if best is None:
            best, barg = m, g_arg
        else:
            barg = jnp.where(m > best, g_arg, barg)
            best = jnp.maximum(m, best)
    return best[:, None], barg[:, None]


def _kernel_batched(*refs, block_l: int, base_l: int, masked: bool = False):
    """Lane-batched pass A (rbf row source): every lane shares the (BL, d)
    X tile.

    The B query rows hit the tile as ONE (B, d) x (d, BL) MXU matmul; the
    per-lane gain algebra and masked argmax run on the VPU over (B, BL)
    registers.  Unlike the single-lane kernel no k-row is written back —
    the batched pass B recomputes it, trading one extra matmul for an HBM
    round-trip of (B, l) and for launch-free Alg. 3 candidate swaps.
    With ``masked=True`` an (H, B, BL) active-set tile rides first in the
    ref list and restricts the j-scan (soft shrinking).
    """
    act_ref, refs = (refs[0], refs[1:]) if masked else (None, refs)
    (xq_ref, scal_ref, iscal_ref, X_ref, sqn_ref, G_ref, alpha_ref,
     L_ref, U_ref, bmax_out, barg_out) = refs
    b = pl.program_id(0)
    sqq = scal_ref[:, 0:1]
    gamma = scal_ref[:, 1:2]

    x = X_ref[...]                      # (BL, d) shared tile
    q = xq_ref[...]                     # (B, d) per-lane query rows
    prod = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.promote_types(x.dtype, jnp.float32))
    d2 = sqq + sqn_ref[...] - 2.0 * prod                    # (B, BL)
    k = jnp.exp(-gamma * jnp.maximum(d2, 0.0))

    bmax, barg = _select_from_k(
        k, G_ref[...], alpha_ref[...], L_ref[...], U_ref[...],
        scal_ref[:, 2:], iscal_ref[...], b, block_l=block_l, base_l=base_l,
        act=None if act_ref is None else act_ref[...])
    bmax_out[...] = bmax
    barg_out[...] = barg


def _kernel_batched_rows(*refs, block_l: int, base_l: int,
                         masked: bool = False):
    """Lane-batched pass A (rows source): the kernel-row tile arrives
    pre-gathered (Gram-bank mode) — same selection algebra, no matmul."""
    act_ref, refs = (refs[0], refs[1:]) if masked else (None, refs)
    (kr_ref, scal_ref, iscal_ref, G_ref, alpha_ref, L_ref, U_ref,
     bmax_out, barg_out) = refs
    b = pl.program_id(0)
    bmax, barg = _select_from_k(
        kr_ref[...], G_ref[...], alpha_ref[...], L_ref[...], U_ref[...],
        scal_ref[...], iscal_ref[...], b, block_l=block_l, base_l=base_l,
        act=None if act_ref is None else act_ref[...])
    bmax_out[...] = bmax
    barg_out[...] = barg


@functools.partial(jax.jit,
                   static_argnames=("block_l", "interpret", "base_l"))
def rbf_row_wss_batched_pallas(X, sqn, G, alpha, L, U, XQ, scalars,
                               iscalars, act=None, *, block_l: int = 1024,
                               interpret: bool = False, base_l: int = 0):
    """Launch lane-batched pass A.  ``G``/``alpha``/``L``/``U`` are
    (H, B, lpad) stacks of the variable halves (H = 1 plain, H = 2 the
    doubled ε-SVR operator) with both trailing dims padded by the ops
    wrapper; ``XQ`` is (B, d) *base* query rows; ``scalars`` is the packed
    (B, 7) float array [sqq, gamma, a_i, L_i, U_i, g_i, use_exact] and
    ``iscalars`` the (B, 1) int32 channel [i_idx] (global doubled index).
    ``base_l`` is the true base example count (half-1 coordinates are
    ``base_l + offset``).  ``act`` is an optional (H, B, lpad) active-set
    stack in the data dtype (1.0/0.0; soft shrinking).

    Returns (block_max (B, nb), block_arg (B, nb)).
    """
    H, B, lpad = G.shape
    d = X.shape[1]
    assert lpad % block_l == 0, (lpad, block_l)
    nb = lpad // block_l
    dtype = X.dtype

    lane_spec = pl.BlockSpec((H, B, block_l), lambda b: (0, 0, b))
    blk_spec = pl.BlockSpec((B, 1), lambda b: (0, b))
    out_shapes = (
        jax.ShapeDtypeStruct((B, nb), dtype),        # block max
        jax.ShapeDtypeStruct((B, nb), jnp.int32),    # block arg
    )
    masked = act is not None
    in_specs = [
        pl.BlockSpec((B, d), lambda b: (0, 0)),          # XQ
        pl.BlockSpec((B, 7), lambda b: (0, 0)),          # scalars
        pl.BlockSpec((B, 1), lambda b: (0, 0)),          # iscalars
        pl.BlockSpec((block_l, d), lambda b: (b, 0)),    # X
        pl.BlockSpec((1, block_l), lambda b: (0, b)),    # sqn
        lane_spec, lane_spec, lane_spec, lane_spec,
    ]
    args = [XQ, scalars, iscalars, X, sqn.reshape(1, lpad), G, alpha, L, U]
    if masked:
        in_specs.insert(0, lane_spec)
        args.insert(0, act)
    bmax, barg = pl.pallas_call(
        functools.partial(_kernel_batched, block_l=block_l, base_l=base_l,
                          masked=masked),
        grid=(nb,),
        in_specs=in_specs,
        out_specs=[blk_spec, blk_spec],
        out_shape=out_shapes,
        interpret=interpret,
    )(*args)
    return bmax, barg


@functools.partial(jax.jit,
                   static_argnames=("block_l", "interpret", "base_l"))
def row_wss_batched_rows_pallas(KR, G, alpha, L, U, scalars, iscalars,
                                act=None, *, block_l: int = 1024,
                                interpret: bool = False, base_l: int = 0):
    """Launch lane-batched pass A from pre-gathered base rows ``KR``
    (B, lpad) — the Gram-bank row source.  ``scalars`` is the packed
    (B, 5) float array [a_i, L_i, U_i, g_i, use_exact]; the state stack,
    optional ``act`` stack and ``iscalars``/``base_l`` are as in
    :func:`rbf_row_wss_batched_pallas`.  Returns (block_max, block_arg).
    """
    H, B, lpad = G.shape
    assert lpad % block_l == 0, (lpad, block_l)
    nb = lpad // block_l
    dtype = KR.dtype

    lane_spec = pl.BlockSpec((H, B, block_l), lambda b: (0, 0, b))
    blk_spec = pl.BlockSpec((B, 1), lambda b: (0, b))
    out_shapes = (
        jax.ShapeDtypeStruct((B, nb), dtype),
        jax.ShapeDtypeStruct((B, nb), jnp.int32),
    )
    masked = act is not None
    in_specs = [
        pl.BlockSpec((B, block_l), lambda b: (0, b)),    # KR
        pl.BlockSpec((B, 5), lambda b: (0, 0)),          # scalars
        pl.BlockSpec((B, 1), lambda b: (0, 0)),          # iscalars
        lane_spec, lane_spec, lane_spec, lane_spec,
    ]
    args = [KR, scalars, iscalars, G, alpha, L, U]
    if masked:
        in_specs.insert(0, lane_spec)
        args.insert(0, act)
    bmax, barg = pl.pallas_call(
        functools.partial(_kernel_batched_rows, block_l=block_l,
                          base_l=base_l, masked=masked),
        grid=(nb,),
        in_specs=in_specs,
        out_specs=[blk_spec, blk_spec],
        out_shape=out_shapes,
        interpret=interpret,
    )(*args)
    return bmax, barg


@functools.partial(jax.jit,
                   static_argnames=("block_l", "interpret"))
def rbf_row_wss_pallas(X, sqn, G, alpha, L, U, xq, scalars, iscalars,
                       *, block_l: int = 1024, interpret: bool = False):
    """Launch pass A.  All vector inputs must be padded to a multiple of
    ``block_l`` (the ops wrapper does this).  ``scalars`` is the packed
    (1, 7) float array [sqq, a_i, L_i, U_i, g_i, gamma, use_exact];
    ``iscalars`` the (1, 1) int32 channel [i_idx].

    Returns (k_i (l,), block_max (nb,), block_arg (nb,)).
    """
    lpad, d = X.shape
    assert lpad % block_l == 0, (lpad, block_l)
    nb = lpad // block_l
    dtype = X.dtype

    row2 = lambda a: a.reshape(1, lpad)
    vec_spec = pl.BlockSpec((1, block_l), lambda b: (0, b))
    out_shapes = (
        jax.ShapeDtypeStruct((1, lpad), dtype),      # k_i
        jax.ShapeDtypeStruct((1, nb), dtype),        # block max
        jax.ShapeDtypeStruct((1, nb), jnp.int32),    # block arg
    )
    k, bmax, barg = pl.pallas_call(
        functools.partial(_kernel, block_l=block_l),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, d), lambda b: (0, 0)),          # xq
            pl.BlockSpec((1, 7), lambda b: (0, 0)),          # scalars
            pl.BlockSpec((1, 1), lambda b: (0, 0)),          # iscalars
            pl.BlockSpec((block_l, d), lambda b: (b, 0)),    # X
            vec_spec, vec_spec, vec_spec, vec_spec, vec_spec,
        ],
        out_specs=[
            vec_spec,
            pl.BlockSpec((1, 1), lambda b: (0, b)),
            pl.BlockSpec((1, 1), lambda b: (0, b)),
        ],
        out_shape=out_shapes,
        interpret=interpret,
    )(xq.reshape(1, d), scalars, iscalars, X, row2(sqn), row2(G),
      row2(alpha), row2(L), row2(U))
    return k[0], bmax[0], barg[0]
