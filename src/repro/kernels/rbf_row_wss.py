"""Pass A Pallas kernel: fused RBF kernel-row + WSS2 j-selection.

Grid: 1D over blocks of the example dimension l (block size BL, a multiple
of 128 so the lane dimension is hardware-aligned).  Per grid step the VMEM
working set is one (BL, d) tile of X plus six (1, BL) vectors — for the
default BL=1024, d<=512 that is ~2.3 MB in f32, comfortably inside the
~16 MB v5e VMEM with double buffering.

The (BL, d) x (d,) matvec runs on the MXU (d padded to a multiple of 128 by
the ops wrapper); the gain algebra and the masked argmax run on the VPU in
the same pass, so G, alpha, L, U are read from HBM exactly once and the
gains are never materialized to HBM.  Outputs: the kernel row k_i (pass B
needs it), and per-block (max, argmax) pairs that the O(nblocks) epilogue
reduces on-chip.

The selection algebra is dual-generic: L/U are arbitrary per-coordinate
boxes (classification, class-weighted, ε-SVR doubled, one-class lanes all
look identical from here); only the RBF ``diag == 1`` identity is
specialized.  The ε-SVR doubled operator reaches this kernel with a
pre-tiled X (the ops wrapper's ``dup`` handling) — exploiting the tiled
row structure *inside* the kernel is a real-TPU follow-up (ROADMAP).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TAU = 1e-12


def _kernel(xq_ref, scal_ref, X_ref, sqn_ref, G_ref, alpha_ref, L_ref, U_ref,
            k_out, bmax_out, barg_out, *, block_l: int):
    b = pl.program_id(0)
    # scalars: [sqq, a_i, L_i, U_i, g_i, gamma, use_exact, i_idx]
    sqq = scal_ref[0, 0]
    a_i = scal_ref[0, 1]
    L_i = scal_ref[0, 2]
    U_i = scal_ref[0, 3]
    g_i = scal_ref[0, 4]
    gamma = scal_ref[0, 5]
    use_exact = scal_ref[0, 6] > 0.5
    i_idx = scal_ref[0, 7].astype(jnp.int32)

    x = X_ref[...]                      # (BL, d)
    q = xq_ref[...]                     # (1, d)
    prod = jax.lax.dot_general(x, q, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.promote_types(x.dtype, jnp.float32))  # (BL, 1)
    d2 = sqq + sqn_ref[...] - 2.0 * prod.reshape(1, block_l)        # (1, BL)
    k = jnp.exp(-gamma * jnp.maximum(d2, 0.0))
    k_out[...] = k.astype(k_out.dtype)

    G = G_ref[...]
    alpha = alpha_ref[...]
    L = L_ref[...]
    U = U_ref[...]
    l_vec = g_i - G
    q_vec = jnp.maximum(2.0 - 2.0 * k, TAU)      # RBF diag == 1
    g_tilde = 0.5 * l_vec * l_vec / q_vec
    lo = jnp.maximum(L_i - a_i, alpha - U)
    hi = jnp.minimum(U_i - a_i, alpha - L)
    mu_c = jnp.clip(l_vec / q_vec, lo, hi)
    g_exact = l_vec * mu_c - 0.5 * q_vec * mu_c * mu_c
    gains = jnp.where(use_exact, g_exact, g_tilde)

    gidx = (b * block_l
            + jax.lax.broadcasted_iota(jnp.int32, (1, block_l), 1))
    mask = (alpha > L) & (l_vec > 0) & (gidx != i_idx)
    vals = jnp.where(mask, gains, -jnp.inf)
    arg = jnp.argmax(vals[0]).astype(jnp.int32)
    bmax_out[0, 0] = vals[0, arg]
    barg_out[0, 0] = b * block_l + arg


def _kernel_batched(xq_ref, scal_ref, X_ref, sqn_ref, G_ref, alpha_ref,
                    L_ref, U_ref, bmax_out, barg_out, *, block_l: int):
    """Lane-batched pass A: every lane shares the (BL, d) X tile.

    The B query rows hit the tile as ONE (B, d) x (d, BL) MXU matmul; the
    per-lane gain algebra and masked argmax run on the VPU over (B, BL)
    registers.  Unlike the single-lane kernel no k-row is written back —
    the batched pass B recomputes it, trading one extra matmul for an HBM
    round-trip of (B, l) and for launch-free Alg. 3 candidate swaps.
    """
    b = pl.program_id(0)
    # per-lane scalars: [sqq, a_i, L_i, U_i, g_i, gamma, use_exact, i_idx]
    sqq = scal_ref[:, 0:1]
    a_i = scal_ref[:, 1:2]
    L_i = scal_ref[:, 2:3]
    U_i = scal_ref[:, 3:4]
    g_i = scal_ref[:, 4:5]
    gamma = scal_ref[:, 5:6]
    use_exact = scal_ref[:, 6:7] > 0.5
    i_idx = scal_ref[:, 7:8].astype(jnp.int32)

    x = X_ref[...]                      # (BL, d) shared tile
    q = xq_ref[...]                     # (B, d) per-lane query rows
    prod = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.promote_types(x.dtype, jnp.float32))
    d2 = sqq + sqn_ref[...] - 2.0 * prod                    # (B, BL)
    k = jnp.exp(-gamma * jnp.maximum(d2, 0.0))

    G = G_ref[...]
    alpha = alpha_ref[...]
    L = L_ref[...]
    U = U_ref[...]
    l_vec = g_i - G
    q_vec = jnp.maximum(2.0 - 2.0 * k, TAU)      # RBF diag == 1
    g_tilde = 0.5 * l_vec * l_vec / q_vec
    lo = jnp.maximum(L_i - a_i, alpha - U)
    hi = jnp.minimum(U_i - a_i, alpha - L)
    mu_c = jnp.clip(l_vec / q_vec, lo, hi)
    g_exact = l_vec * mu_c - 0.5 * q_vec * mu_c * mu_c
    gains = jnp.where(use_exact, g_exact, g_tilde)

    nb_lanes = G.shape[0]
    gidx = (b * block_l
            + jax.lax.broadcasted_iota(jnp.int32, (nb_lanes, block_l), 1))
    mask = (alpha > L) & (l_vec > 0) & (gidx != i_idx)
    vals = jnp.where(mask, gains, -jnp.inf)
    arg = jnp.argmax(vals, axis=1).astype(jnp.int32)
    bmax_out[...] = jnp.max(vals, axis=1, keepdims=True)
    barg_out[...] = (b * block_l + arg)[:, None]


@functools.partial(jax.jit, static_argnames=("block_l", "interpret"))
def rbf_row_wss_batched_pallas(X, sqn, G, alpha, L, U, XQ, scalars,
                               *, block_l: int = 1024,
                               interpret: bool = False):
    """Launch lane-batched pass A.  ``G``/``alpha``/``L``/``U`` are (B, lpad)
    with the lane dimension padded to a sublane multiple by the ops wrapper;
    ``XQ`` is (B, d); ``scalars`` is the packed (B, 8) array
    [sqq, a_i, L_i, U_i, g_i, gamma, use_exact, i_idx] per lane.

    Returns (block_max (B, nb), block_arg (B, nb)).
    """
    lpad, d = X.shape
    B = G.shape[0]
    assert lpad % block_l == 0, (lpad, block_l)
    nb = lpad // block_l
    dtype = X.dtype

    lane_spec = pl.BlockSpec((B, block_l), lambda b: (0, b))
    blk_spec = pl.BlockSpec((B, 1), lambda b: (0, b))
    out_shapes = (
        jax.ShapeDtypeStruct((B, nb), dtype),        # block max
        jax.ShapeDtypeStruct((B, nb), jnp.int32),    # block arg
    )
    bmax, barg = pl.pallas_call(
        functools.partial(_kernel_batched, block_l=block_l),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((B, d), lambda b: (0, 0)),          # XQ
            pl.BlockSpec((B, 8), lambda b: (0, 0)),          # scalars
            pl.BlockSpec((block_l, d), lambda b: (b, 0)),    # X
            pl.BlockSpec((1, block_l), lambda b: (0, b)),    # sqn
            lane_spec, lane_spec, lane_spec, lane_spec,
        ],
        out_specs=[blk_spec, blk_spec],
        out_shape=out_shapes,
        interpret=interpret,
    )(XQ, scalars, X, sqn.reshape(1, lpad), G, alpha, L, U)
    return bmax, barg


@functools.partial(jax.jit,
                   static_argnames=("block_l", "interpret"))
def rbf_row_wss_pallas(X, sqn, G, alpha, L, U, xq, scalars,
                       *, block_l: int = 1024, interpret: bool = False):
    """Launch pass A.  All vector inputs must be padded to a multiple of
    ``block_l`` (the ops wrapper does this).  ``scalars`` is the packed
    (1, 8) f32 array [sqq, a_i, L_i, U_i, g_i, gamma, use_exact, i_idx].

    Returns (k_i (l,), block_max (nb,), block_arg (nb,)).
    """
    lpad, d = X.shape
    assert lpad % block_l == 0, (lpad, block_l)
    nb = lpad // block_l
    dtype = X.dtype

    row2 = lambda a: a.reshape(1, lpad)
    vec_spec = pl.BlockSpec((1, block_l), lambda b: (0, b))
    out_shapes = (
        jax.ShapeDtypeStruct((1, lpad), dtype),      # k_i
        jax.ShapeDtypeStruct((1, nb), dtype),        # block max
        jax.ShapeDtypeStruct((1, nb), jnp.int32),    # block arg
    )
    k, bmax, barg = pl.pallas_call(
        functools.partial(_kernel, block_l=block_l),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, d), lambda b: (0, 0)),          # xq
            pl.BlockSpec((1, 8), lambda b: (0, 0)),          # scalars
            pl.BlockSpec((block_l, d), lambda b: (b, 0)),    # X
            vec_spec, vec_spec, vec_spec, vec_spec, vec_spec,
        ],
        out_specs=[
            vec_spec,
            pl.BlockSpec((1, 1), lambda b: (0, b)),
            pl.BlockSpec((1, 1), lambda b: (0, b)),
        ],
        out_shape=out_shapes,
        interpret=interpret,
    )(xq.reshape(1, d), scalars, X, row2(sqn), row2(G), row2(alpha),
      row2(L), row2(U))
    return k[0], bmax[0], barg[0]
