"""Pallas TPU kernels for the SMO iteration hot spots.

The paper's per-iteration cost is dominated by kernel-row evaluation and the
O(l) selection/update vector work (§2: steps 1, 3, 4 are O(l)).  On TPU the
iteration is restructured into exactly TWO fused passes over the sharded
example dimension (DESIGN.md §3):

* pass A (``rbf_row_wss``):   compute the kernel row k_i from X, and in the
  same VMEM-resident pass evaluate the WSS2 second-order gains (eq. 3, or
  the exact clipped gain for Alg. 3's guard branch) and their per-block
  argmax.  Outputs: k_i (needed by pass B) + per-block (max, arg).
* pass B (``rbf_update_wss``): compute k_j (never materialized to HBM),
  apply the gradient update G <- G - mu (k_i - k_j), and in the same pass
  compute the next iteration's first-order argmax over I_up and both KKT
  gap endpoints.

Everything O(1) in between (Newton step, planning-ahead eq. 8, box logic)
happens on scalars outside the kernels.  ``gram_block`` provides the tiled
Gram-matrix builder used by batch mode and the SVM-probe feature path.
"""
