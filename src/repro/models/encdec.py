"""Whisper-class encoder-decoder backbone.

The conv/mel frontend is a STUB per the harness: ``input_specs`` provides
precomputed frame embeddings (B, S_enc, d).  Encoder: bidirectional
self-attention; decoder: causal self-attention + cross-attention.
Sinusoidal absolute positions (whisper uses no RoPE).  Decode caches the
decoder self-attn ring + the once-computed encoder K/V per layer.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.transformer import stack_logical
from repro.sharding import constrain, logical as lg


class EncBlockParams(NamedTuple):
    ln1: jax.Array
    attn: L.AttnParams
    ln2: jax.Array
    mlp: L.MLPParams


class DecBlockParams(NamedTuple):
    ln1: jax.Array
    self_attn: L.AttnParams
    ln_x: jax.Array
    cross_attn: L.AttnParams
    ln2: jax.Array
    mlp: L.MLPParams


class EncDecParams(NamedTuple):
    embed: jax.Array               # (V, d) decoder token embeddings
    enc_blocks: EncBlockParams     # stacked (Le, ...)
    enc_ln_f: jax.Array
    dec_blocks: DecBlockParams     # stacked (Ld, ...)
    ln_f: jax.Array
    unembed: Optional[jax.Array]


class EncDecCache(NamedTuple):
    self_kv: L.KVCache             # stacked (Ld, ...) decoder ring
    cross_k: jax.Array             # (Ld, B, S_enc, KH, hd)
    cross_v: jax.Array


def sinusoidal(S, d, dtype=jnp.float32):
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, 2.0 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)],
                           axis=-1).astype(dtype)


def _enc_block_init(rng, cfg, dtype):
    k1, k2 = jax.random.split(rng)
    d = cfg.d_model
    return EncBlockParams(ln1=jnp.zeros((d,), dtype),
                          attn=L.attn_init(k1, cfg, dtype),
                          ln2=jnp.zeros((d,), dtype),
                          mlp=L.mlp_init(k2, cfg, dtype))


def _dec_block_init(rng, cfg, dtype):
    k1, k2, k3 = jax.random.split(rng, 3)
    d = cfg.d_model
    return DecBlockParams(ln1=jnp.zeros((d,), dtype),
                          self_attn=L.attn_init(k1, cfg, dtype),
                          ln_x=jnp.zeros((d,), dtype),
                          cross_attn=L.attn_init(k2, cfg, dtype),
                          ln2=jnp.zeros((d,), dtype),
                          mlp=L.mlp_init(k3, cfg, dtype))


def init_params(rng, cfg, dtype=jnp.float32) -> EncDecParams:
    ke, kb, kd, ku = jax.random.split(rng, 4)
    enc = jax.vmap(lambda r: _enc_block_init(r, cfg, dtype))(
        jax.random.split(kb, cfg.encoder_layers))
    dec = jax.vmap(lambda r: _dec_block_init(r, cfg, dtype))(
        jax.random.split(kd, cfg.n_layers))
    return EncDecParams(
        embed=L.embed_init(ke, cfg, dtype),
        enc_blocks=enc, enc_ln_f=jnp.zeros((cfg.d_model,), dtype),
        dec_blocks=dec, ln_f=jnp.zeros((cfg.d_model,), dtype),
        unembed=None if cfg.tie_embeddings else L.embed_init(ku, cfg, dtype))


def param_logical(cfg):
    enc = EncBlockParams(ln1=lg("embed"), attn=L.attn_logical(cfg),
                         ln2=lg("embed"), mlp=L.mlp_logical(cfg))
    dec = DecBlockParams(ln1=lg("embed"), self_attn=L.attn_logical(cfg),
                         ln_x=lg("embed"), cross_attn=L.attn_logical(cfg),
                         ln2=lg("embed"), mlp=L.mlp_logical(cfg))
    return EncDecParams(
        embed=L.embed_logical(), enc_blocks=stack_logical(enc),
        enc_ln_f=lg("embed"), dec_blocks=stack_logical(dec),
        ln_f=lg("embed"),
        unembed=None if cfg.tie_embeddings else L.embed_logical())


def encode(params: EncDecParams, cfg, frames):
    """frames: (B, S_enc, d) stub embeddings -> (B, S_enc, d)."""
    S = frames.shape[1]
    x = frames + sinusoidal(S, cfg.d_model, frames.dtype)
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(x, blk):
        h, _ = L.attn_apply(blk.attn, cfg,
                            L.rms_norm(x, blk.ln1, cfg.norm_eps), positions,
                            causal=False)
        x = x + h
        x = x + L.mlp_apply(blk.mlp, L.rms_norm(x, blk.ln2, cfg.norm_eps),
                            activation="gelu")
        return constrain(x, "batch", "seq", "embed"), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params.enc_blocks)
    return L.rms_norm(x, params.enc_ln_f, cfg.norm_eps)


def _cross_attend(p: L.AttnParams, cfg, x, enc_k, enc_v):
    """Cross attention: q from x (B, S, d), k/v precomputed (B, T, KH, hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p.wq)
    if p.bq is not None:
        q = q + p.bq
    Sq, T = q.shape[1], enc_k.shape[1]
    o = L.attention(q, enc_k, enc_v,
                    jnp.arange(Sq, dtype=jnp.int32),
                    jnp.arange(T, dtype=jnp.int32), causal=False)
    return L.attn_out(p, o)


def _enc_kv(p: L.AttnParams, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p.wk)
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p.wv)
    if p.bk is not None:
        k = k + p.bk
        v = v + p.bv
    return k, v


def apply(params: EncDecParams, cfg, tokens, frames, *, remat: str = "none",
          return_hidden: bool = False):
    """Teacher-forced training forward: (tokens (B, S_dec), frames
    (B, S_enc, d)) -> logits."""
    enc_out = encode(params, cfg, frames)
    S = tokens.shape[1]
    x = L.embed_lookup(params.embed, tokens)
    x = x + sinusoidal(S, cfg.d_model, x.dtype)
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(x, blk):
        h, _ = L.attn_apply(blk.self_attn, cfg,
                            L.rms_norm(x, blk.ln1, cfg.norm_eps), positions,
                            causal=True)
        x = x + h
        k, v = _enc_kv(blk.cross_attn, enc_out)
        x = x + _cross_attend(blk.cross_attn, cfg,
                              L.rms_norm(x, blk.ln_x, cfg.norm_eps), k, v)
        x = x + L.mlp_apply(blk.mlp, L.rms_norm(x, blk.ln2, cfg.norm_eps),
                            activation="gelu")
        return constrain(x, "batch", "seq", "embed"), None

    if remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params.dec_blocks)
    x = L.rms_norm(x, params.ln_f, cfg.norm_eps)
    if return_hidden:
        return x
    table = params.embed if params.unembed is None else params.unembed
    return L.logits_proj(table, x)


def init_cache(cfg, batch, horizon, dtype=jnp.bfloat16) -> EncDecCache:
    Ld = cfg.n_layers
    kv = jax.vmap(lambda _: L.kv_cache_init(
        batch, horizon, cfg.n_kv_heads, cfg.head_dim, dtype))(
            jnp.arange(Ld))
    return EncDecCache(
        self_kv=kv,
        cross_k=jnp.zeros((Ld, batch, cfg.encoder_seq, cfg.n_kv_heads,
                           cfg.head_dim), dtype),
        cross_v=jnp.zeros((Ld, batch, cfg.encoder_seq, cfg.n_kv_heads,
                           cfg.head_dim), dtype))


def cache_logical(cfg):
    kv = L.KVCache(
        k=lg("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
        v=lg("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
        kpos=lg("layers", "kv_seq"))
    ckv = lg("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    return EncDecCache(self_kv=kv, cross_k=ckv, cross_v=ckv)


def prefill(params: EncDecParams, cfg, tokens, frames, horizon,
            kv_dtype=jnp.bfloat16):
    """Encode + teacher-forced decoder pass building both caches."""
    enc_out = encode(params, cfg, frames)
    S = tokens.shape[1]
    x = L.embed_lookup(params.embed, tokens)
    x = x + sinusoidal(S, cfg.d_model, x.dtype)
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(x, blk):
        h, (k, v) = L.attn_apply(blk.self_attn, cfg,
                                 L.rms_norm(x, blk.ln1, cfg.norm_eps),
                                 positions, causal=True)
        x = x + h
        ck, cv = _enc_kv(blk.cross_attn, enc_out)
        x = x + _cross_attend(blk.cross_attn, cfg,
                              L.rms_norm(x, blk.ln_x, cfg.norm_eps), ck, cv)
        x = x + L.mlp_apply(blk.mlp, L.rms_norm(x, blk.ln2, cfg.norm_eps),
                            activation="gelu")
        kv = L.kv_cache_from_prefill(k, v, positions, horizon, kv_dtype)
        return (constrain(x, "batch", "seq", "embed"),
                (kv, ck.astype(kv_dtype), cv.astype(kv_dtype)))

    x, (kv, ck, cv) = jax.lax.scan(jax.checkpoint(body), x,
                                   params.dec_blocks)
    x = L.rms_norm(x, params.ln_f, cfg.norm_eps)
    table = params.embed if params.unembed is None else params.unembed
    return L.logits_proj(table, x), EncDecCache(self_kv=kv, cross_k=ck,
                                                cross_v=cv)


def decode_step(params: EncDecParams, cfg, cache: EncDecCache, tokens, pos):
    x = jnp.take(params.embed, tokens, axis=0)
    x = x + sinusoidal_at(pos, cfg.d_model, x.dtype)

    def body(x, xs):
        blk, kv, ck, cv = xs
        h, kv = L.attn_decode(blk.self_attn, cfg,
                              L.rms_norm(x, blk.ln1, cfg.norm_eps), kv, pos)
        x = x + h
        x = x + _cross_attend(blk.cross_attn, cfg,
                              L.rms_norm(x, blk.ln_x, cfg.norm_eps),
                              ck.astype(x.dtype), cv.astype(x.dtype))
        x = x + L.mlp_apply(blk.mlp, L.rms_norm(x, blk.ln2, cfg.norm_eps),
                            activation="gelu")
        return x, kv

    x, kv = jax.lax.scan(body, x, (params.dec_blocks, cache.self_kv,
                                   cache.cross_k, cache.cross_v))
    x = L.rms_norm(x, params.ln_f, cfg.norm_eps)
    table = params.embed if params.unembed is None else params.unembed
    return L.logits_proj(table, x), EncDecCache(self_kv=kv,
                                                cross_k=cache.cross_k,
                                                cross_v=cache.cross_v)


def sinusoidal_at(pos, d, dtype):
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / jnp.power(10_000.0, 2.0 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)]).astype(dtype)
