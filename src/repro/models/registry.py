"""Unified model API across families + harness input specs.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input of
a given (arch, shape) cell — weak-type-correct, shardable, no device
allocation — exactly what the multi-pod dry-run lowers against.
``demo_batch`` materializes small real batches for CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, moe, rglru, ssm, transformer, vlm

_MODULES = {
    "dense": transformer,
    "moe": moe,
    "ssm": ssm,
    "hybrid": rglru,
    "encdec": encdec,
    "vlm": vlm,
}


def get_module(cfg: ModelConfig):
    return _MODULES[cfg.family]


def init_params(rng, cfg: ModelConfig, dtype=jnp.float32):
    return get_module(cfg).init_params(rng, cfg, dtype)


def param_logical(cfg: ModelConfig):
    return get_module(cfg).param_logical(cfg)


def supports_cell(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Harness skip rules: long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full quadratic attention at 524288 tokens"
    return True, ""


# ---------------------------------------------------------------------------
# training inputs / loss
# ---------------------------------------------------------------------------

def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        specs["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    return specs


def train_input_logical(cfg: ModelConfig) -> Dict[str, Any]:
    from repro.sharding import logical as lg
    specs = {"tokens": lg("batch", "seq"), "labels": lg("batch", "seq")}
    if cfg.family == "encdec":
        specs["frames"] = lg("batch", "seq", None)
    if cfg.family == "vlm":
        specs["patches"] = lg("batch", "seq", None)
    return specs


def demo_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0,
               dtype=jnp.float32) -> Dict[str, Any]:
    rng = np.random.default_rng(seed)
    out = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, size=(batch, seq)), jnp.int32),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab, size=(batch, seq)), jnp.int32),
    }
    if cfg.family == "encdec":
        out["frames"] = jnp.asarray(
            rng.normal(size=(batch, cfg.encoder_seq, cfg.d_model)) * 0.02,
            dtype)
    if cfg.family == "vlm":
        out["patches"] = jnp.asarray(
            rng.normal(size=(batch, cfg.vision_tokens, cfg.d_model)) * 0.02,
            dtype)
    return out


def forward_logits(params, cfg: ModelConfig, batch: Dict[str, Any],
                   remat: str = "none"):
    """Family-dispatched forward.  Returns (logits, aux_loss)."""
    if cfg.family == "moe":
        logits, aux = moe.apply(params, cfg, batch["tokens"], remat=remat)
        return logits, aux
    if cfg.family == "encdec":
        return encdec.apply(params, cfg, batch["tokens"], batch["frames"],
                            remat=remat), 0.0
    if cfg.family == "vlm":
        return vlm.apply(params, cfg, batch["tokens"], batch["patches"],
                         remat=remat), 0.0
    mod = get_module(cfg)
    return mod.apply(params, cfg, batch["tokens"], remat=remat), 0.0


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, Any],
            remat: str = "none", aux_weight: float = 0.01):
    """Next-token cross entropy (+ MoE load-balance aux)."""
    logits, aux = forward_logits(params, cfg, batch, remat)
    logits = logits.astype(jnp.float32)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1).squeeze(-1)
    nll = jnp.mean(logz - gold)
    return nll + aux_weight * aux, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# serving inputs
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, horizon: int,
               dtype=jnp.bfloat16):
    return get_module(cfg).init_cache(cfg, batch, horizon, dtype)


def cache_logical(cfg: ModelConfig):
    return get_module(cfg).cache_logical(cfg)


def cache_specs(cfg: ModelConfig, batch: int, horizon: int,
                dtype=jnp.bfloat16):
    """ShapeDtypeStructs of the decode cache (no allocation)."""
    return jax.eval_shape(
        lambda: get_module(cfg).init_cache(cfg, batch, horizon, dtype))


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    B = shape.global_batch
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    return get_module(cfg).decode_step(params, cfg, cache, tokens, pos)


def prefill(params, cfg: ModelConfig, batch: Dict[str, Any], horizon: int,
            kv_dtype=jnp.bfloat16):
    if cfg.family == "encdec":
        return encdec.prefill(params, cfg, batch["tokens"], batch["frames"],
                              horizon, kv_dtype)
    if cfg.family == "vlm":
        return vlm.prefill(params, cfg, batch["tokens"], batch["patches"],
                           horizon, kv_dtype)
    return get_module(cfg).prefill(params, cfg, batch["tokens"], horizon,
                                   kv_dtype)
