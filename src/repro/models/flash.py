"""Flash attention in pure JAX with a static triangle schedule.

§Perf hillclimb change (EXPERIMENTS.md): the baseline chunked attention
computes every (q-chunk, kv-chunk) pair — rectangular compute — and lets
each score tile round-trip HBM ~6x (dot out, mask, exp, row-sums, pv dot).
This implementation:

  * enumerates only the *needed* chunk pairs at trace time (causal lower
    triangle, optionally window-banded) — a single ``lax.scan`` over a
    static pair list, so FLOPs and traffic drop ~2x for causal and more
    for windowed attention, and the HLO trip counts stay static (the
    roofline analyzer sees the true counts);
  * wraps forward+backward in ``jax.custom_vjp`` with the standard flash
    recomputation, so no O(S^2) residuals are ever saved — the backward
    replays the same static pair schedule;
  * keeps q/k/v in their storage dtype (bf16 on TPU) with f32 on-tile
    accumulation via ``preferred_element_type`` — no f32 copies of the
    inputs are materialized.

Shapes: q (B, Sq, KH, G, D); k, v (B, Skv, KH, D); GQA grouped, no kv-head
repetition.  Positions are absolute.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG = -1e30


def _pairs(nq: int, nk: int, causal: bool, window: int, cq: int, ck: int,
           q_start_chunk: int = 0):
    """Static (qi, ki) chunk-pair schedule.

    q chunk qi covers absolute positions [ (q_start_chunk+qi)*cq, +cq );
    causal keeps ki*ck <= q_end; window drops pairs entirely out of range.
    """
    out = []
    for qi in range(nq):
        q_lo = (q_start_chunk + qi) * cq
        q_hi = q_lo + cq - 1
        for ki in range(nk):
            k_lo = ki * ck
            k_hi = k_lo + ck - 1
            if causal and k_lo > q_hi:
                continue
            if window > 0 and k_hi < q_lo - window + 1 - (cq - 1):
                continue
            out.append((qi, ki))
    return out


def _tile_mask(qp, kp, causal: bool, window: int):
    m = jnp.ones((qp.shape[0], kp.shape[0]), bool)
    if causal:
        m &= qp[:, None] >= kp[None, :]
    if window > 0:
        m &= qp[:, None] - kp[None, :] < window
    return m


def _fwd_scan(q, k, v, q_pos, k_pos, causal, window, cq, ck):
    B, Sq, KH, G, D = q.shape
    Skv = k.shape[1]
    nq, nk = Sq // cq, Skv // ck
    scale = 1.0 / math.sqrt(D)

    qs = q.reshape(B, nq, cq, KH, G, D)
    ks = k.reshape(B, nk, ck, KH, D)
    vs = v.reshape(B, nk, ck, KH, D)
    qps = q_pos.reshape(nq, cq)
    kps = k_pos.reshape(nk, ck)

    pairs = _pairs(nq, nk, causal, window, cq, ck)
    qi_arr = jnp.asarray([p[0] for p in pairs], jnp.int32)
    ki_arr = jnp.asarray([p[1] for p in pairs], jnp.int32)

    m0 = jnp.full((nq, B, KH, G, cq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((nq, B, KH, G, cq), jnp.float32)
    a0 = jnp.zeros((nq, B, KH, G, cq, D), jnp.float32)

    def body(carry, idx):
        m, l, acc = carry
        qi, ki = idx
        qb = jnp.take(qs, qi, axis=1)          # (B, cq, KH, G, D)
        kb = jnp.take(ks, ki, axis=1)          # (B, ck, KH, D)
        vb = jnp.take(vs, ki, axis=1)
        qp = jnp.take(qps, qi, axis=0)
        kp = jnp.take(kps, ki, axis=0)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(_tile_mask(qp, kp, causal, window)[None, None, None],
                      s, NEG)
        mi = jnp.take(m, qi, axis=0)
        li = jnp.take(l, qi, axis=0)
        ai = jnp.take(acc, qi, axis=0)
        m_new = jnp.maximum(mi, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(mi - m_new)
        l_new = li * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v.dtype), vb,
                        preferred_element_type=jnp.float32)
        a_new = ai * corr[..., None] + pv
        return (m.at[qi].set(m_new), l.at[qi].set(l_new),
                acc.at[qi].set(a_new)), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (qi_arr, ki_arr))
    l_safe = jnp.maximum(l, 1e-37)
    out = acc / l_safe[..., None]
    # (nq, B, KH, G, cq, D) -> (B, nq, cq, KH, G, D) -> (B, Sq, KH, G, D)
    out = jnp.transpose(out, (1, 0, 4, 2, 3, 5)).reshape(B, Sq, KH, G, D)
    lse = m + jnp.log(l_safe)                  # (nq, B, KH, G, cq)
    return out.astype(q.dtype), lse


@partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def flash_attention(q, k, v, q_pos, k_pos, causal=True, window=0,
                    chunk_q=512, chunk_k=512):
    out, _ = _fwd_scan(q, k, v, q_pos, k_pos, causal, window, chunk_q,
                       chunk_k)
    return out


def _flash_fwd(q, k, v, q_pos, k_pos, causal, window, cq, ck):
    out, lse = _fwd_scan(q, k, v, q_pos, k_pos, causal, window, cq, ck)
    return out, (q, k, v, q_pos, k_pos, out, lse)


def _flash_bwd(causal, window, cq, ck, res, do):
    q, k, v, q_pos, k_pos, out, lse = res
    B, Sq, KH, G, D = q.shape
    Skv = k.shape[1]
    nq, nk = Sq // cq, Skv // ck
    scale = 1.0 / math.sqrt(D)

    qs = q.reshape(B, nq, cq, KH, G, D)
    ks = k.reshape(B, nk, ck, KH, D)
    vs = v.reshape(B, nk, ck, KH, D)
    qps = q_pos.reshape(nq, cq)
    kps = k_pos.reshape(nk, ck)
    dos = do.reshape(B, nq, cq, KH, G, D)
    # delta = rowsum(do * out) per query
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                       # (B, Sq, KH, G)
    deltas = jnp.moveaxis(
        delta.reshape(B, nq, cq, KH, G), (1, 3, 4), (0, 2, 3))
    # -> (nq, B, KH, G, cq)

    pairs = _pairs(nq, nk, causal, window, cq, ck)
    qi_arr = jnp.asarray([p[0] for p in pairs], jnp.int32)
    ki_arr = jnp.asarray([p[1] for p in pairs], jnp.int32)

    dq0 = jnp.zeros((nq, B, cq, KH, G, D), jnp.float32)
    dk0 = jnp.zeros((nk, B, ck, KH, D), jnp.float32)
    dv0 = jnp.zeros((nk, B, ck, KH, D), jnp.float32)

    def body(carry, idx):
        dq, dk, dv = carry
        qi, ki = idx
        qb = jnp.take(qs, qi, axis=1)
        kb = jnp.take(ks, ki, axis=1)
        vb = jnp.take(vs, ki, axis=1)
        qp = jnp.take(qps, qi, axis=0)
        kp = jnp.take(kps, ki, axis=0)
        dob = jnp.take(dos, qi, axis=1)            # (B, cq, KH, G, D)
        lse_b = jnp.take(lse, qi, axis=0)          # (B, KH, G, cq)
        del_b = jnp.take(deltas, qi, axis=0)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(_tile_mask(qp, kp, causal, window)[None, None, None],
                      s, NEG)
        p = jnp.exp(s - lse_b[..., None])          # (B, KH, G, cq, ck)
        dp = jnp.einsum("bqhgd,bkhd->bhgqk", dob, vb,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - del_b[..., None]) * scale
        dqb = jnp.einsum("bhgqk,bkhd->bqhgd", ds.astype(k.dtype), kb,
                         preferred_element_type=jnp.float32)
        dkb = jnp.einsum("bhgqk,bqhgd->bkhd", ds.astype(q.dtype), qb,
                         preferred_element_type=jnp.float32)
        dvb = jnp.einsum("bhgqk,bqhgd->bkhd", p.astype(do.dtype), dob,
                         preferred_element_type=jnp.float32)
        return (dq.at[qi].add(dqb), dk.at[ki].add(dkb),
                dv.at[ki].add(dvb)), None

    (dq, dk, dv), _ = jax.lax.scan(body, (dq0, dk0, dv0),
                                   (qi_arr, ki_arr))
    dq = jnp.moveaxis(dq, 0, 1).reshape(B, Sq, KH, G, D).astype(q.dtype)
    dk = jnp.moveaxis(dk, 0, 1).reshape(B, Skv, KH, D).astype(k.dtype)
    dv = jnp.moveaxis(dv, 0, 1).reshape(B, Skv, KH, D).astype(v.dtype)
    return dq, dk, dv, None, None


flash_attention.defvjp(_flash_fwd, _flash_bwd)
