"""Shared transformer layers: RMSNorm, RoPE, chunked attention, gated MLP.

Attention is computed with an online-softmax chunked scan (flash-attention
algorithm in pure JAX): the S x S score matrix is never materialized — per
(q-chunk, kv-chunk) tiles live in registers/VMEM and the kv scan body is
``jax.checkpoint``-ed so backward recomputes tiles instead of saving them.
GQA is computed with grouped einsums (no materialized kv-head repeat).

The baseline computes all (q, kv) chunk pairs and masks — i.e. rectangular
compute even for causal masks; the causal-skip optimization is a §Perf
hillclimb item (see EXPERIMENTS.md).
"""

from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.sharding import constrain


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def trunc_normal(rng, shape, std, dtype):
    return (std * jax.random.truncated_normal(rng, -2.0, 2.0, shape,
                                              jnp.float32)).astype(dtype)


def dense_init(rng, fan_in, shape, dtype):
    return trunc_normal(rng, shape, 1.0 / math.sqrt(fan_in), dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(
        jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, theta=10_000.0):
    """Rotary embedding, llama-style half rotation.

    x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                        # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * cos - xf2 * sin,
                            xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked online-softmax attention
# ---------------------------------------------------------------------------

NEG = -1e30


def _chunk_attn(q, k, v, q_pos, k_pos, causal, window, chunk_k):
    """Online-softmax attention for one q block against all kv chunks.

    q: (B, Sq, KH, G, D); k, v: (B, T, KH, D);
    q_pos: (Sq,), k_pos: (T,).  Returns (B, Sq, KH, G, D)."""
    B, Sq, KH, G, D = q.shape
    T = k.shape[1]
    nkc = T // chunk_k
    scale = 1.0 / math.sqrt(D)
    qf = q.astype(jnp.float32) * scale

    k_c = k.reshape(B, nkc, chunk_k, KH, D)
    v_c = v.reshape(B, nkc, chunk_k, KH, D)
    kp_c = k_pos.reshape(nkc, chunk_k)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, kp = xs          # (B, ck, KH, D), (B, ck, KH, D), (ck,)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kb.astype(jnp.float32))
        mask = jnp.ones((Sq, chunk_k), bool)
        if causal:
            mask &= q_pos[:, None] >= kp[None, :]
        if window > 0:
            mask &= q_pos[:, None] - kp[None, :] < window
        s = jnp.where(mask[None, None, None], s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KH, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KH, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KH, G, Sq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body),
        (m0, l0, a0),
        (jnp.moveaxis(k_c, 1, 0), jnp.moveaxis(v_c, 1, 0), kp_c))
    out = acc / jnp.maximum(l[..., None], 1e-37)
    return jnp.moveaxis(out, (1, 2, 3), (2, 3, 1)).astype(q.dtype)


import os as _os

# "flash" (triangle-scheduled custom_vjp, the §Perf optimized path) or
# "rect" (the baseline rectangular scan — kept for A/B roofline artifacts).
ATTN_BACKEND = _os.environ.get("REPRO_ATTN", "flash")


def attention(q, k, v, q_positions, k_positions, *, causal=True,
              window=0, chunk_q=512, chunk_k=512):
    """Chunked GQA attention.

    q: (B, Sq, H, D); k, v: (B, T, KH, D).  H % KH == 0.
    positions are absolute (RoPE already applied by the caller)."""
    B, Sq, H, D = q.shape
    T, KH = k.shape[1], k.shape[2]
    G = H // KH
    qg = q.reshape(B, Sq, KH, G, D)
    chunk_q = min(chunk_q, Sq)
    chunk_k = min(chunk_k, T)
    if Sq % chunk_q != 0 or T % chunk_k != 0:
        # fall back to a single chunk when shapes don't tile (smoke sizes)
        chunk_q, chunk_k = Sq, T

    nqc = Sq // chunk_q
    if ATTN_BACKEND == "flash" and (nqc > 1 or T // chunk_k > 1):
        from repro.models.flash import flash_attention
        out = flash_attention(qg, k, v, q_positions, k_positions, causal,
                              window, chunk_q, chunk_k)
    elif nqc == 1:
        out = _chunk_attn(qg, k, v, q_positions, k_positions, causal,
                          window, chunk_k)
    else:
        qs = qg.reshape(B, nqc, chunk_q, KH, G, D)
        qp = q_positions.reshape(nqc, chunk_q)
        out = jax.lax.map(
            lambda xs: _chunk_attn(xs[0], k, v, xs[1], k_positions,
                                   causal, window, chunk_k),
            (jnp.moveaxis(qs, 1, 0), qp))
        out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, KH, G, D)
    return out.reshape(B, Sq, H, D)


# ---------------------------------------------------------------------------
# attention block (pre-norm GQA with RoPE)
# ---------------------------------------------------------------------------

class AttnParams(NamedTuple):
    wq: jax.Array            # (d, H, hd)
    wk: jax.Array            # (d, KH, hd)
    wv: jax.Array            # (d, KH, hd)
    wo: jax.Array            # (H, hd, d)
    bq: Optional[jax.Array]  # (H, hd) or None
    bk: Optional[jax.Array]
    bv: Optional[jax.Array]


def attn_init(rng, cfg, dtype):
    d, H, KH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 4)
    bias = cfg.qkv_bias
    return AttnParams(
        wq=dense_init(ks[0], d, (d, H, hd), dtype),
        wk=dense_init(ks[1], d, (d, KH, hd), dtype),
        wv=dense_init(ks[2], d, (d, KH, hd), dtype),
        wo=dense_init(ks[3], H * hd, (H, hd, d), dtype),
        bq=jnp.zeros((H, hd), dtype) if bias else None,
        bk=jnp.zeros((KH, hd), dtype) if bias else None,
        bv=jnp.zeros((KH, hd), dtype) if bias else None)


def attn_logical(cfg):
    from repro.sharding import logical as lg
    bias = cfg.qkv_bias
    return AttnParams(
        wq=lg("embed", "heads", "head_dim"),
        wk=lg("embed", "kv_heads", "head_dim"),
        wv=lg("embed", "kv_heads", "head_dim"),
        wo=lg("heads", "head_dim", "embed"),
        bq=lg("heads", "head_dim") if bias else None,
        bk=lg("kv_heads", "head_dim") if bias else None,
        bv=lg("kv_heads", "head_dim") if bias else None)


def attn_qkv(p: AttnParams, x, positions, theta):
    """Project + RoPE (theta=None skips rotary — whisper-style absolute).

    x: (B, S, d) -> q (B,S,H,hd), k/v (B,S,KH,hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p.wq)
    k = jnp.einsum("bsd,dhk->bshk", x, p.wk)
    v = jnp.einsum("bsd,dhk->bshk", x, p.wv)
    if p.bq is not None:
        q = q + p.bq
        k = k + p.bk
        v = v + p.bv
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "kv_heads", None)
    if theta is not None:
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    return q, k, v


def attn_out(p: AttnParams, o):
    y = jnp.einsum("bshk,hkd->bsd", o, p.wo)
    return constrain(y, "batch", "seq", "embed")


def attn_apply(p: AttnParams, cfg, x, positions, *, causal=True, window=0):
    """Full-sequence self-attention (train / prefill)."""
    theta = cfg.rope_theta if cfg.use_rope else None
    q, k, v = attn_qkv(p, x, positions, theta)
    o = attention(q, k, v, positions, positions, causal=causal,
                  window=window)
    return attn_out(p, o), (k, v)


class KVCache(NamedTuple):
    """Ring-buffer KV cache: slot t holds the token whose absolute position
    is ``kpos[t]`` (-1 = empty).  For full attention the ring never wraps
    (capacity == horizon); for sliding-window / local attention the capacity
    is the window size, so a 500k-token stream needs only O(window) HBM."""

    k: jax.Array     # (B, Tc, KH, hd)
    v: jax.Array     # (B, Tc, KH, hd)
    kpos: jax.Array  # (Tc,) int32 absolute positions; -1 = empty


def kv_cache_init(batch, capacity, kv_heads, head_dim, dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, capacity, kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, capacity, kv_heads, head_dim), dtype),
        kpos=jnp.full((capacity,), -1, jnp.int32))


def kv_cache_from_prefill(k, v, positions, capacity, dtype) -> KVCache:
    """Keep the last ``capacity`` tokens of a prefill (window semantics)."""
    S = k.shape[1]
    if S <= capacity:
        pad = capacity - S
        return KVCache(
            k=jnp.pad(k.astype(dtype), ((0, 0), (0, pad), (0, 0), (0, 0))),
            v=jnp.pad(v.astype(dtype), ((0, 0), (0, pad), (0, 0), (0, 0))),
            kpos=jnp.pad(positions.astype(jnp.int32), (0, pad),
                         constant_values=-1))
    # ring layout: token at absolute position p sits in slot p % capacity
    tail_pos = positions[S - capacity:]
    slots = tail_pos % capacity
    kc = jnp.zeros((k.shape[0], capacity) + k.shape[2:], dtype)
    vc = jnp.zeros_like(kc)
    kc = kc.at[:, slots].set(k[:, S - capacity:].astype(dtype))
    vc = vc.at[:, slots].set(v[:, S - capacity:].astype(dtype))
    kpos = jnp.zeros((capacity,), jnp.int32).at[slots].set(tail_pos)
    return KVCache(k=kc, v=vc, kpos=kpos)


def attn_decode(p: AttnParams, cfg, x, cache: KVCache, pos, *, window=0):
    """One-token decode against a ring cache.

    x: (B, 1, d); pos: scalar int32 absolute position of the new token.
    Returns (y, cache)."""
    B, _, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p.wq)
    k = jnp.einsum("bsd,dhk->bshk", x, p.wk)
    v = jnp.einsum("bsd,dhk->bshk", x, p.wv)
    if p.bq is not None:
        q = q + p.bq
        k = k + p.bk
        v = v + p.bv
    posv = jnp.full((1,), pos, jnp.int32)
    if cfg.use_rope:
        q = rope(q, posv, cfg.rope_theta)
        k = rope(k, posv, cfg.rope_theta)
    Tc = cache.k.shape[1]
    slot = pos % Tc
    cache = KVCache(
        k=jax.lax.dynamic_update_slice_in_dim(
            cache.k, k.astype(cache.k.dtype), slot, axis=1),
        v=jax.lax.dynamic_update_slice_in_dim(
            cache.v, v.astype(cache.v.dtype), slot, axis=1),
        kpos=jax.lax.dynamic_update_slice_in_dim(
            cache.kpos, posv, slot, axis=0))
    KH = cache.k.shape[2]
    H = q.shape[2]
    G = H // KH
    qg = q.reshape(B, KH, G, -1)
    scale = 1.0 / math.sqrt(q.shape[-1])
    # §Perf: keep the cache in its storage dtype (bf16) — no f32 copies of
    # the whole cache; accumulate the dots in f32 on-tile.
    s = jnp.einsum("bkgd,btkd->bkgt", qg.astype(cache.k.dtype), cache.k,
                   preferred_element_type=jnp.float32) * scale
    kp = cache.kpos
    mask = (kp >= 0) & (kp <= pos)
    if window > 0:
        mask &= kp > pos - window
    s = jnp.where(mask[None, None, None, :], s, NEG)
    pattn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", pattn.astype(cache.v.dtype), cache.v,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    o = o.reshape(B, 1, H, -1)
    return attn_out_decode(p, o), cache


def attn_out_decode(p: AttnParams, o):
    return jnp.einsum("bshk,hkd->bsd", o, p.wo)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

class MLPParams(NamedTuple):
    w_gate: jax.Array   # (d, f)
    w_up: jax.Array     # (d, f)
    w_down: jax.Array   # (f, d)


def mlp_init(rng, cfg, dtype, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    return MLPParams(w_gate=dense_init(ks[0], d, (d, f), dtype),
                     w_up=dense_init(ks[1], d, (d, f), dtype),
                     w_down=dense_init(ks[2], f, (f, d), dtype))


def mlp_logical(cfg):
    from repro.sharding import logical as lg
    return MLPParams(w_gate=lg("embed", "mlp"), w_up=lg("embed", "mlp"),
                     w_down=lg("mlp", "embed"))


def mlp_apply(p: MLPParams, x, activation="silu"):
    g = jnp.einsum("bsd,df->bsf", x, p.w_gate)
    u = jnp.einsum("bsd,df->bsf", x, p.w_up)
    g = constrain(g, "batch", "seq", "mlp")
    act = jax.nn.silu if activation == "silu" else jax.nn.gelu
    y = jnp.einsum("bsf,fd->bsd", act(g) * u, p.w_down)
    return constrain(y, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# embeddings / logits
# ---------------------------------------------------------------------------

def embed_init(rng, cfg, dtype):
    return trunc_normal(rng, (cfg.vocab, cfg.d_model), 0.02, dtype)


def embed_logical():
    from repro.sharding import logical as lg
    return lg("vocab", "embed")


def embed_lookup(table, tokens):
    x = jnp.take(table, tokens, axis=0)
    return constrain(x, "batch", "seq", "embed")


def logits_proj(table_or_w, x):
    """Final projection; ``table_or_w`` is (V, d) (tied or untied)."""
    y = jnp.einsum("bsd,vd->bsv", x, table_or_w)
    return constrain(y, "batch", "seq", "vocab")
