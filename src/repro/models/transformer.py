"""Dense GQA decoder-only LM (stablelm / qwen / deepseek / VLM backbone).

Layers are stacked along a leading L axis and traversed with ``lax.scan``
(HLO size independent of depth); the block function is wrapped in
``jax.checkpoint`` according to the remat policy.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding import constrain, logical as lg


class BlockParams(NamedTuple):
    ln1: jax.Array
    attn: L.AttnParams
    ln2: jax.Array
    mlp: L.MLPParams


class DenseParams(NamedTuple):
    embed: jax.Array                  # (V, d)
    blocks: BlockParams               # stacked (L, ...)
    ln_f: jax.Array                   # (d,)
    unembed: Optional[jax.Array]      # (V, d) or None when tied


class Cache(NamedTuple):
    kv: L.KVCache                     # stacked (L, ...) ring caches


def _block_init(rng, cfg, dtype):
    k1, k2 = jax.random.split(rng)
    d = cfg.d_model
    return BlockParams(ln1=jnp.zeros((d,), dtype),
                       attn=L.attn_init(k1, cfg, dtype),
                       ln2=jnp.zeros((d,), dtype),
                       mlp=L.mlp_init(k2, cfg, dtype))


def init_params(rng, cfg, dtype=jnp.float32) -> DenseParams:
    ke, kb, ku = jax.random.split(rng, 3)
    blocks = jax.vmap(lambda r: _block_init(r, cfg, dtype))(
        jax.random.split(kb, cfg.n_layers))
    return DenseParams(
        embed=L.embed_init(ke, cfg, dtype),
        blocks=blocks,
        ln_f=jnp.zeros((cfg.d_model,), dtype),
        unembed=None if cfg.tie_embeddings
        else L.embed_init(ku, cfg, dtype))


def stack_logical(tree):
    """Prepend the 'layers' axis to every leaf annotation."""
    return jax.tree.map(lambda x: lg("layers", *x.names), tree,
                        is_leaf=lambda x: isinstance(x, lg))


def param_logical(cfg):
    block = BlockParams(ln1=lg("embed"), attn=L.attn_logical(cfg),
                        ln2=lg("embed"), mlp=L.mlp_logical(cfg))
    return DenseParams(
        embed=L.embed_logical(), blocks=stack_logical(block),
        ln_f=lg("embed"),
        unembed=None if cfg.tie_embeddings else L.embed_logical())


def _block_apply(cfg, x, blk: BlockParams, positions, window):
    h, _ = L.attn_apply(blk.attn, cfg, L.rms_norm(x, blk.ln1, cfg.norm_eps),
                        positions, causal=True, window=window)
    x = x + h
    x = x + L.mlp_apply(blk.mlp, L.rms_norm(x, blk.ln2, cfg.norm_eps))
    return constrain(x, "batch", "seq", "embed")


def apply(params: DenseParams, cfg, tokens, *, remat: str = "none",
          prefix_embeds: Optional[jax.Array] = None,
          return_hidden: bool = False) -> jax.Array:
    """Train/eval forward: (B, S) int32 -> (B, S, V) logits.

    ``prefix_embeds`` (B, P, d) overrides the first P embedding rows (VLM
    patch embeddings).  ``return_hidden`` yields the final normed hidden
    states (B, S, d) instead of logits (feature extraction / SVM probes)."""
    x = L.embed_lookup(params.embed, tokens)
    if prefix_embeds is not None:
        P = prefix_embeds.shape[1]
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x[:, P:]],
                            axis=1)
    S = tokens.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(x, blk):
        return _block_apply(cfg, x, blk, positions, cfg.sliding_window), None

    if remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params.blocks)
    x = L.rms_norm(x, params.ln_f, cfg.norm_eps)
    if return_hidden:
        return x
    table = params.embed if params.unembed is None else params.unembed
    return L.logits_proj(table, x)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def cache_capacity(cfg, horizon: int) -> int:
    return min(horizon, cfg.sliding_window) if cfg.sliding_window > 0 \
        else horizon


def init_cache(cfg, batch, horizon, dtype=jnp.bfloat16) -> Cache:
    cap = cache_capacity(cfg, horizon)
    kv = jax.vmap(
        lambda _: L.kv_cache_init(batch, cap, cfg.n_kv_heads, cfg.head_dim,
                                  dtype))(jnp.arange(cfg.n_layers))
    return Cache(kv=kv)


def cache_logical(cfg):
    return Cache(kv=L.KVCache(
        k=lg("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
        v=lg("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
        kpos=lg("layers", "kv_seq")))


def prefill(params: DenseParams, cfg, tokens, horizon,
            kv_dtype=jnp.bfloat16,
            prefix_embeds: Optional[jax.Array] = None):
    """Full forward + cache build: returns (logits, Cache)."""
    x = L.embed_lookup(params.embed, tokens)
    if prefix_embeds is not None:
        P = prefix_embeds.shape[1]
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x[:, P:]],
                            axis=1)
    S = tokens.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    cap = cache_capacity(cfg, horizon)

    def body(x, blk):
        h, (k, v) = L.attn_apply(
            blk.attn, cfg, L.rms_norm(x, blk.ln1, cfg.norm_eps), positions,
            causal=True, window=cfg.sliding_window)
        x = x + h
        x = x + L.mlp_apply(blk.mlp, L.rms_norm(x, blk.ln2, cfg.norm_eps))
        kv = L.kv_cache_from_prefill(k, v, positions, cap, kv_dtype)
        return constrain(x, "batch", "seq", "embed"), kv

    x, kv = jax.lax.scan(jax.checkpoint(body), x, params.blocks)
    x = L.rms_norm(x, params.ln_f, cfg.norm_eps)
    table = params.embed if params.unembed is None else params.unembed
    return L.logits_proj(table, x), Cache(kv=kv)


def decode_step(params: DenseParams, cfg, cache: Cache, tokens, pos):
    """One-token decode: tokens (B, 1) int32, pos scalar int32 absolute
    position.  Returns (logits (B, 1, V), Cache)."""
    x = jnp.take(params.embed, tokens, axis=0)

    def body(x, xs):
        blk, kv = xs
        h, kv = L.attn_decode(blk.attn, cfg,
                              L.rms_norm(x, blk.ln1, cfg.norm_eps), kv, pos,
                              window=cfg.sliding_window)
        x = x + h
        x = x + L.mlp_apply(blk.mlp, L.rms_norm(x, blk.ln2, cfg.norm_eps))
        return x, kv

    x, kv = jax.lax.scan(body, x, (params.blocks, cache.kv))
    x = L.rms_norm(x, params.ln_f, cfg.norm_eps)
    table = params.embed if params.unembed is None else params.unembed
    return L.logits_proj(table, x), Cache(kv=kv)
