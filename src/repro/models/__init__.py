"""Model zoo: one module per architecture family.

Every model exposes the same functional interface (no framework deps):

    init_params(rng, cfg, dtype)        -> params pytree (stacked layers)
    param_logical(cfg)                  -> matching tree of sharding.logical
    apply(params, cfg, batch, ...)      -> logits           (train forward)
    init_cache(cfg, batch, max_seq, dt) -> decode cache/state
    prefill(params, cfg, tokens)        -> (logits, cache)
    decode_step(params, cfg, cache, tok, pos) -> (logits, cache)

Families: dense GQA decoder (transformer.py), MoE top-2 (moe.py), Mamba2
SSD (ssm.py), RG-LRU + local-attention hybrid (rglru.py), encoder-decoder
(encdec.py), ViT-stub VLM (vlm.py).
"""

from repro.models import registry
