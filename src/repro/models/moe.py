"""Mixture-of-Experts decoder (grok-1 / mixtral): top-2 router, GShard-style
capacity dispatch, expert-parallel over the ``model`` mesh axis (+ FSDP on
``data``).  Sliding-window attention supported (mixtral).

The dispatch/combine einsums are local per token shard; with experts
sharded on ``model`` XLA inserts the all-to-all between the token-sharded
and expert-sharded layouts.  Capacity drops overflow tokens (cf=1.25), the
standard TPU-friendly dropping MoE (documented DESIGN.md §6).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T
from repro.sharding import constrain, logical as lg


class MoEParams(NamedTuple):
    router: jax.Array   # (d, E)
    w_gate: jax.Array   # (E, d, f)
    w_up: jax.Array     # (E, d, f)
    w_down: jax.Array   # (E, f, d)


class MoEBlockParams(NamedTuple):
    ln1: jax.Array
    attn: L.AttnParams
    ln2: jax.Array
    moe: MoEParams


class MoEModelParams(NamedTuple):
    embed: jax.Array
    blocks: MoEBlockParams
    ln_f: jax.Array
    unembed: Optional[jax.Array]


def moe_init(rng, cfg, dtype) -> MoEParams:
    ks = jax.random.split(rng, 4)
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return MoEParams(
        router=L.dense_init(ks[0], d, (d, E), dtype),
        w_gate=L.dense_init(ks[1], d, (E, d, f), dtype),
        w_up=L.dense_init(ks[2], d, (E, d, f), dtype),
        w_down=L.dense_init(ks[3], f, (E, f, d), dtype))


def moe_logical(cfg):
    return MoEParams(router=lg("embed", None),
                     w_gate=lg("expert", None, "moe_ff"),
                     w_up=lg("expert", None, "moe_ff"),
                     w_down=lg("expert", "moe_ff", None))


def capacity(cfg, seq: int) -> int:
    cap = int(cfg.capacity_factor * cfg.top_k * seq / cfg.n_experts)
    return max(8, min(seq, (cap + 7) // 8 * 8))  # 8-aligned


def moe_apply(p: MoEParams, cfg, x) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss).  GShard top-2 with capacity drop."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = capacity(cfg, S)

    logits = jnp.einsum("bsd,de->bse", x, p.router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (B,S,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)              # (B,S,K)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # load-balancing auxiliary loss (Switch/GShard)
    me = jnp.mean(probs, axis=(0, 1))                          # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_idx, E, dtype=jnp.float32), axis=2),
        axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    # position of each (token, k) inside its expert's capacity buffer;
    # k-loop keeps the largest intermediate at (B, S, E, C)
    onehot_e = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (B,S,K,E)
    flat = onehot_e.reshape(B, S * K, E)
    pos_in_e = (jnp.cumsum(flat, axis=1) - flat).reshape(B, S, K, E)
    dispatch = jnp.zeros((B, S, E, C), x.dtype)
    combine = jnp.zeros((B, S, E, C), x.dtype)
    for k in range(K):
        # one_hot clips out-of-capacity positions (>= C) to all-zero rows
        oc = jax.nn.one_hot(pos_in_e[:, :, k, :].astype(jnp.int32), C,
                            dtype=x.dtype)                     # (B,S,E,C)
        dpk = onehot_e[:, :, k, :, None].astype(x.dtype) * oc
        dispatch = dispatch + dpk
        combine = combine + gate_vals[:, :, k, None, None].astype(
            x.dtype) * dpk

    xin = jnp.einsum("bsec,bsd->ebcd", dispatch, x)
    xin = constrain(xin, "expert", "batch", None, None)
    g = jnp.einsum("ebcd,edf->ebcf", xin, p.w_gate)
    u = jnp.einsum("ebcd,edf->ebcf", xin, p.w_up)
    g = constrain(g, "expert", "batch", None, "moe_ff")
    h = jnp.einsum("ebcf,efd->ebcd", jax.nn.silu(g) * u, p.w_down)
    h = constrain(h, "expert", "batch", None, None)
    y = jnp.einsum("bsec,ebcd->bsd", combine, h)
    return constrain(y, "batch", "seq", "embed"), aux


def _block_init(rng, cfg, dtype):
    k1, k2 = jax.random.split(rng)
    d = cfg.d_model
    return MoEBlockParams(ln1=jnp.zeros((d,), dtype),
                          attn=L.attn_init(k1, cfg, dtype),
                          ln2=jnp.zeros((d,), dtype),
                          moe=moe_init(k2, cfg, dtype))


def init_params(rng, cfg, dtype=jnp.float32) -> MoEModelParams:
    ke, kb, ku = jax.random.split(rng, 3)
    blocks = jax.vmap(lambda r: _block_init(r, cfg, dtype))(
        jax.random.split(kb, cfg.n_layers))
    return MoEModelParams(
        embed=L.embed_init(ke, cfg, dtype), blocks=blocks,
        ln_f=jnp.zeros((cfg.d_model,), dtype),
        unembed=None if cfg.tie_embeddings else L.embed_init(ku, cfg, dtype))


def param_logical(cfg):
    block = MoEBlockParams(ln1=lg("embed"), attn=L.attn_logical(cfg),
                           ln2=lg("embed"), moe=moe_logical(cfg))
    return MoEModelParams(
        embed=L.embed_logical(), blocks=T.stack_logical(block),
        ln_f=lg("embed"),
        unembed=None if cfg.tie_embeddings else L.embed_logical())


def apply(params: MoEModelParams, cfg, tokens, *, remat: str = "none",
          return_hidden: bool = False):
    """Returns (logits, aux_loss_mean)."""
    x = L.embed_lookup(params.embed, tokens)
    S = tokens.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(x, blk):
        h, _ = L.attn_apply(blk.attn, cfg,
                            L.rms_norm(x, blk.ln1, cfg.norm_eps), positions,
                            causal=True, window=cfg.sliding_window)
        x = x + h
        y, aux = moe_apply(blk.moe, cfg,
                           L.rms_norm(x, blk.ln2, cfg.norm_eps))
        x = constrain(x + y, "batch", "seq", "embed")
        return x, aux

    if remat == "full":
        body = jax.checkpoint(body)
    x, auxs = jax.lax.scan(body, x, params.blocks)
    x = L.rms_norm(x, params.ln_f, cfg.norm_eps)
    if return_hidden:
        return x, jnp.mean(auxs)
    table = params.embed if params.unembed is None else params.unembed
    return L.logits_proj(table, x), jnp.mean(auxs)


def init_cache(cfg, batch, horizon, dtype=jnp.bfloat16) -> T.Cache:
    return T.init_cache(cfg, batch, horizon, dtype)


def cache_logical(cfg):
    return T.cache_logical(cfg)


def prefill(params: MoEModelParams, cfg, tokens, horizon,
            kv_dtype=jnp.bfloat16):
    x = L.embed_lookup(params.embed, tokens)
    S = tokens.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    cap = T.cache_capacity(cfg, horizon)

    def body(x, blk):
        h, (k, v) = L.attn_apply(
            blk.attn, cfg, L.rms_norm(x, blk.ln1, cfg.norm_eps), positions,
            causal=True, window=cfg.sliding_window)
        x = x + h
        y, _ = moe_apply(blk.moe, cfg, L.rms_norm(x, blk.ln2, cfg.norm_eps))
        x = constrain(x + y, "batch", "seq", "embed")
        return x, L.kv_cache_from_prefill(k, v, positions, cap, kv_dtype)

    x, kv = jax.lax.scan(jax.checkpoint(body), x, params.blocks)
    x = L.rms_norm(x, params.ln_f, cfg.norm_eps)
    table = params.embed if params.unembed is None else params.unembed
    return L.logits_proj(table, x), T.Cache(kv=kv)


def decode_step(params: MoEModelParams, cfg, cache: T.Cache, tokens, pos):
    x = jnp.take(params.embed, tokens, axis=0)

    def body(x, xs):
        blk, kv = xs
        h, kv = L.attn_decode(blk.attn, cfg,
                              L.rms_norm(x, blk.ln1, cfg.norm_eps), kv, pos,
                              window=cfg.sliding_window)
        x = x + h
        y, _ = moe_apply(blk.moe, cfg, L.rms_norm(x, blk.ln2, cfg.norm_eps))
        return x + y, kv

    x, kv = jax.lax.scan(body, x, (params.blocks, cache.kv))
    x = L.rms_norm(x, params.ln_f, cfg.norm_eps)
    table = params.embed if params.unembed is None else params.unembed
    return L.logits_proj(table, x), T.Cache(kv=kv)
