"""InternVL2-class VLM: vision stub + dense LM backbone.

The InternViT frontend is a STUB per the harness: ``input_specs`` provides
precomputed patch embeddings (B, Tv, d_model) already projected into the LM
embedding space.  They replace the first Tv embedding rows of the token
sequence; everything else is the dense GQA decoder from transformer.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer as T

init_params = T.init_params
param_logical = T.param_logical
init_cache = T.init_cache
cache_logical = T.cache_logical


def apply(params, cfg, tokens, patch_embeds, *, remat: str = "none",
          return_hidden: bool = False):
    return T.apply(params, cfg, tokens, remat=remat,
                   prefix_embeds=patch_embeds, return_hidden=return_hidden)


def prefill(params, cfg, tokens, patch_embeds, horizon,
            kv_dtype=jnp.bfloat16):
    return T.prefill(params, cfg, tokens, horizon, kv_dtype,
                     prefix_embeds=patch_embeds)


def decode_step(params, cfg, cache, tokens, pos):
    return T.decode_step(params, cfg, cache, tokens, pos)
