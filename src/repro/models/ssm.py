"""Mamba2 (SSD — state-space duality) blocks, attention-free LM.

Implements the chunked SSD algorithm (Dao & Gu, 2024): within chunks the
quadratic "attention-like" form runs on the MXU; across chunks a linear
recurrence over per-chunk states keeps O(S) total work.  Decode keeps an
O(1) recurrent state (B, H, P, N) per layer — the long_500k cell costs the
same per token as short contexts.

Single B/C group (n_groups = 1, the mamba2 default).  All decay math in
f32.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding import constrain, logical as lg


class SSMBlockParams(NamedTuple):
    ln: jax.Array          # (d,)
    w_z: jax.Array         # (d, din)
    w_xbc: jax.Array       # (d, din + 2N)
    w_dt: jax.Array        # (d, H)
    dt_bias: jax.Array     # (H,)
    A_log: jax.Array       # (H,)
    D: jax.Array           # (H,)
    conv_w: jax.Array      # (K, din + 2N) depthwise
    conv_b: jax.Array      # (din + 2N,)
    norm: jax.Array        # (din,)
    w_out: jax.Array       # (din, d)


class SSMParams(NamedTuple):
    embed: jax.Array
    blocks: SSMBlockParams
    ln_f: jax.Array
    unembed: Optional[jax.Array]


class SSMCache(NamedTuple):
    """Decode state: recurrent state + causal-conv ring buffer."""

    h: jax.Array        # (layers, B, H, P, N) f32
    conv: jax.Array     # (layers, B, K-1, din + 2N)


def _dims(cfg):
    din = cfg.ssm_expand * cfg.d_model
    P = cfg.ssm_head_dim
    H = din // P
    N = cfg.ssm_state
    return din, H, P, N


def _block_init(rng, cfg, dtype):
    d = cfg.d_model
    din, H, P, N = _dims(cfg)
    K = cfg.conv_kernel
    ks = jax.random.split(rng, 6)
    dt = jnp.exp(jax.random.uniform(ks[3], (H,), jnp.float32,
                                    jnp.log(1e-3), jnp.log(1e-1)))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return SSMBlockParams(
        ln=jnp.zeros((d,), dtype),
        w_z=L.dense_init(ks[0], d, (d, din), dtype),
        w_xbc=L.dense_init(ks[1], d, (d, din + 2 * N), dtype),
        w_dt=L.dense_init(ks[2], d, (d, H), dtype),
        dt_bias=dt_bias.astype(dtype),
        A_log=jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)).astype(dtype),
        D=jnp.ones((H,), dtype),
        conv_w=L.dense_init(ks[4], K, (K, din + 2 * N), dtype),
        conv_b=jnp.zeros((din + 2 * N,), dtype),
        norm=jnp.zeros((din,), dtype),
        w_out=L.dense_init(ks[5], din, (din, d), dtype))


def block_logical(cfg):
    return SSMBlockParams(
        ln=lg("embed"), w_z=lg("embed", "mlp"), w_xbc=lg("embed", "mlp"),
        w_dt=lg("embed", None), dt_bias=lg(None), A_log=lg(None),
        D=lg(None), conv_w=lg("conv", "mlp"), conv_b=lg("mlp"),
        norm=lg("mlp"), w_out=lg("mlp", "embed"))


def init_params(rng, cfg, dtype=jnp.float32) -> SSMParams:
    ke, kb, ku = jax.random.split(rng, 3)
    blocks = jax.vmap(lambda r: _block_init(r, cfg, dtype))(
        jax.random.split(kb, cfg.n_layers))
    return SSMParams(
        embed=L.embed_init(ke, cfg, dtype), blocks=blocks,
        ln_f=jnp.zeros((cfg.d_model,), dtype),
        unembed=None if cfg.tie_embeddings else L.embed_init(ku, cfg, dtype))


def param_logical(cfg):
    from repro.models.transformer import stack_logical
    return SSMParams(
        embed=L.embed_logical(), blocks=stack_logical(block_logical(cfg)),
        ln_f=lg("embed"),
        unembed=None if cfg.tie_embeddings else L.embed_logical())


def _causal_conv(x, w, b):
    """Depthwise causal conv: x (B, S, ch), w (K, ch)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, k:k + x.shape[1], :] * w[k] for k in range(K))
    return out + b


def _segsum_exp(a_cum):
    """exp(a_cum[..., i] - a_cum[..., j]) masked to i >= j.

    a_cum: (..., Q); returns (..., Q, Q)."""
    Q = a_cum.shape[-1]
    diff = a_cum[..., :, None] - a_cum[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_chunked(xdt, dA, Bm, Cm, chunk, h0=None):
    """Chunked SSD scan.

    xdt: (B, S, H, P) inputs premultiplied by dt;
    dA:  (B, S, H) per-step log decay (dt * A, negative);
    Bm, Cm: (B, S, N) shared across heads (single group).
    Returns (y (B, S, H, P), h_final (B, H, P, N))."""
    Bsz, S, H, P = xdt.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    if S % Q != 0:
        Q = S
    nc = S // Q
    xdt = xdt.reshape(Bsz, nc, Q, H, P).astype(jnp.float32)
    dA = dA.reshape(Bsz, nc, Q, H).astype(jnp.float32)
    Bm = Bm.reshape(Bsz, nc, Q, N).astype(jnp.float32)
    Cm = Cm.reshape(Bsz, nc, Q, N).astype(jnp.float32)

    a_cum = jnp.cumsum(dA, axis=2)                       # (B,nc,Q,H)
    a_cum_h = jnp.moveaxis(a_cum, -1, 2)                 # (B,nc,H,Q)

    # 1. intra-chunk (diagonal blocks)
    Lmat = _segsum_exp(a_cum_h)                          # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cm, Bm)       # (B,nc,Q,Q)
    y_intra = jnp.einsum("bchqk,bcqk,bckhp->bcqhp", Lmat, scores, xdt)

    # 2. per-chunk states
    decay_end = jnp.exp(a_cum_h[..., -1:] - a_cum_h)     # (B,nc,H,Q)
    states = jnp.einsum("bckn,bchk,bckhp->bchpn", Bm, decay_end, xdt)

    # 3. inter-chunk recurrence
    chunk_decay = jnp.exp(a_cum_h[..., -1])              # (B,nc,H)

    def scan_fn(h, xs):
        st, dec = xs
        return h * dec[..., None, None] + st, h

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    h_final, h_prev = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                  # (B,nc,H,P,N)

    # 4. inter-chunk contribution
    decay_in = jnp.exp(a_cum_h)                          # (B,nc,H,Q)
    y_inter = jnp.einsum("bcqn,bchq,bchpn->bcqhp", Cm, decay_in, h_prev)

    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y, h_final


def _block_apply(p: SSMBlockParams, cfg, x, h0=None, conv_state=None):
    """x: (B, S, d).  Returns (y, h_final, conv_tail)."""
    din, H, P, N = _dims(cfg)
    u = L.rms_norm(x, p.ln, cfg.norm_eps)
    z = jnp.einsum("bsd,df->bsf", u, p.w_z)
    xbc = jnp.einsum("bsd,df->bsf", u, p.w_xbc)
    xbc = constrain(xbc, "batch", "seq", "mlp")
    if conv_state is not None:
        xbc_ext = jnp.concatenate([conv_state, xbc], axis=1)
        conv = _causal_conv(xbc_ext, p.conv_w, p.conv_b)[
            :, conv_state.shape[1]:]
    else:
        conv = _causal_conv(xbc, p.conv_w, p.conv_b)
    conv = jax.nn.silu(conv)
    xs = conv[..., :din]
    Bm = conv[..., din:din + N]
    Cm = conv[..., din + N:]
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", u, p.w_dt).astype(jnp.float32)
        + p.dt_bias.astype(jnp.float32))
    A = -jnp.exp(p.A_log.astype(jnp.float32))
    xh = xs.reshape(*xs.shape[:2], H, P)
    y, h_final = ssd_chunked(xh * dt[..., None], dt * A, Bm, Cm,
                             cfg.ssm_chunk, h0)
    y = y + xh.astype(jnp.float32) * p.D.astype(jnp.float32)[:, None]
    y = y.reshape(*xs.shape[:2], din).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), p.norm, cfg.norm_eps)
    out = jnp.einsum("bsf,fd->bsd", y, p.w_out)
    conv_tail = xbc[:, -(cfg.conv_kernel - 1):, :]
    return constrain(out, "batch", "seq", "embed"), h_final, conv_tail


def apply(params: SSMParams, cfg, tokens, *, remat: str = "none",
          return_hidden: bool = False):
    x = L.embed_lookup(params.embed, tokens)

    def body(x, blk):
        y, _, _ = _block_apply(blk, cfg, x)
        return x + y, None

    if remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params.blocks)
    x = L.rms_norm(x, params.ln_f, cfg.norm_eps)
    if return_hidden:
        return x
    table = params.embed if params.unembed is None else params.unembed
    return L.logits_proj(table, x)


def init_cache(cfg, batch, horizon, dtype=jnp.bfloat16) -> SSMCache:
    del horizon  # O(1) state regardless of context length
    din, H, P, N = _dims(cfg)
    Lc = cfg.n_layers
    return SSMCache(
        h=jnp.zeros((Lc, batch, H, P, N), jnp.float32),
        conv=jnp.zeros((Lc, batch, cfg.conv_kernel - 1, din + 2 * N), dtype))


def cache_logical(cfg):
    return SSMCache(h=lg("layers", "batch", "heads", None, None),
                    conv=lg("layers", "batch", None, "mlp"))


def prefill(params: SSMParams, cfg, tokens, horizon, kv_dtype=jnp.bfloat16):
    x = L.embed_lookup(params.embed, tokens)

    def body(x, blk):
        y, h, conv_tail = _block_apply(blk, cfg, x)
        return x + y, (h, conv_tail.astype(kv_dtype))

    x, (h, conv) = jax.lax.scan(jax.checkpoint(body), x, params.blocks)
    x = L.rms_norm(x, params.ln_f, cfg.norm_eps)
    table = params.embed if params.unembed is None else params.unembed
    return L.logits_proj(table, x), SSMCache(h=h, conv=conv)


def decode_step(params: SSMParams, cfg, cache: SSMCache, tokens, pos):
    del pos  # state-space models need no positional input
    x = jnp.take(params.embed, tokens, axis=0)

    def body(x, xs):
        blk, h0, conv_state = xs
        y, h, conv_tail = _block_apply(blk, cfg, x, h0=h0,
                                       conv_state=conv_state.astype(x.dtype))
        new_conv = jnp.concatenate(
            [conv_state[:, 1:], conv_tail.astype(conv_state.dtype)], axis=1)
        return x + y, (h, new_conv)

    x, (h, conv) = jax.lax.scan(body, x, (params.blocks, cache.h,
                                          cache.conv))
    x = L.rms_norm(x, params.ln_f, cfg.norm_eps)
    table = params.embed if params.unembed is None else params.unembed
    return L.logits_proj(table, x), SSMCache(h=h, conv=conv)
