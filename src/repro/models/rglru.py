"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local MQA,
(rec, rec, attn) 1:2 pattern.

Training uses ``lax.associative_scan`` for the linear recurrence
h_t = a_t h_{t-1} + b_t (log-space gates in f32); decode carries a (B, w)
recurrent state and a (K-1)-deep conv ring per recurrent layer plus a
window-sized KV ring per attention layer — long_500k decode is O(window).

Layers are stacked as scanned triples; the remainder (26 = 8*3 + 2) runs
unrolled.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.ssm import _causal_conv
from repro.sharding import constrain, logical as lg

_C = 8.0  # RG-LRU gate sharpness constant (Griffin)


class RecBlockParams(NamedTuple):
    ln1: jax.Array       # (d,)
    w_x: jax.Array       # (d, w)
    w_gate: jax.Array    # (d, w)
    conv_w: jax.Array    # (K, w)
    conv_b: jax.Array    # (w,)
    lam: jax.Array       # (w,) Lambda
    w_a: jax.Array       # (w, w) recurrence gate
    b_a: jax.Array       # (w,)
    w_i: jax.Array       # (w, w) input gate
    b_i: jax.Array       # (w,)
    w_out: jax.Array     # (w, d)
    ln2: jax.Array       # (d,)
    mlp: L.MLPParams


class AttnBlockParams(NamedTuple):
    ln1: jax.Array
    attn: L.AttnParams
    ln2: jax.Array
    mlp: L.MLPParams


class TripleParams(NamedTuple):
    rec1: RecBlockParams
    rec2: RecBlockParams
    attn: AttnBlockParams


class GriffinParams(NamedTuple):
    embed: jax.Array
    triples: TripleParams        # stacked (n_triples, ...)
    tail: Optional[RecBlockParams]  # stacked (n_tail, ...) or None
    ln_f: jax.Array
    unembed: Optional[jax.Array]


class RecState(NamedTuple):
    h: jax.Array        # (B, w) f32
    conv: jax.Array     # (B, K-1, w)


class GriffinCache(NamedTuple):
    rec1: RecState      # stacked (n_triples, ...)
    rec2: RecState
    attn: L.KVCache     # stacked (n_triples, ...)
    tail: Optional[RecState]  # stacked (n_tail, ...)


def _width(cfg):
    return cfg.rglru_width or cfg.d_model


def _rec_init(rng, cfg, dtype):
    d, w = cfg.d_model, _width(cfg)
    K = cfg.conv_kernel
    ks = jax.random.split(rng, 8)
    # Lambda init so a^c in [0.9, 0.999] (Griffin appendix)
    u = jax.random.uniform(ks[6], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # inv softplus
    return RecBlockParams(
        ln1=jnp.zeros((d,), dtype),
        w_x=L.dense_init(ks[0], d, (d, w), dtype),
        w_gate=L.dense_init(ks[1], d, (d, w), dtype),
        conv_w=L.dense_init(ks[2], K, (K, w), dtype),
        conv_b=jnp.zeros((w,), dtype),
        lam=lam.astype(dtype),
        w_a=L.dense_init(ks[3], w, (w, w), dtype),
        b_a=jnp.zeros((w,), dtype),
        w_i=L.dense_init(ks[5], w, (w, w), dtype),
        b_i=jnp.zeros((w,), dtype),
        w_out=L.dense_init(ks[4], w, (w, d), dtype),
        ln2=jnp.zeros((d,), dtype),
        mlp=L.mlp_init(ks[7], cfg, dtype))


def _rec_logical(cfg):
    return RecBlockParams(
        ln1=lg("embed"), w_x=lg("embed", "mlp"), w_gate=lg("embed", "mlp"),
        conv_w=lg("conv", "mlp"), conv_b=lg("mlp"), lam=lg("mlp"),
        w_a=lg("mlp", None), b_a=lg("mlp"), w_i=lg("mlp", None),
        b_i=lg("mlp"), w_out=lg("mlp", "embed"), ln2=lg("embed"),
        mlp=L.mlp_logical(cfg))


def _attn_init(rng, cfg, dtype):
    k1, k2 = jax.random.split(rng)
    d = cfg.d_model
    return AttnBlockParams(ln1=jnp.zeros((d,), dtype),
                           attn=L.attn_init(k1, cfg, dtype),
                           ln2=jnp.zeros((d,), dtype),
                           mlp=L.mlp_init(k2, cfg, dtype))


def _attn_logical(cfg):
    return AttnBlockParams(ln1=lg("embed"), attn=L.attn_logical(cfg),
                           ln2=lg("embed"), mlp=L.mlp_logical(cfg))


def layout(cfg) -> Tuple[int, int]:
    """(n_triples, n_tail_rec) for the (rec, rec, attn) pattern."""
    n_triples = cfg.n_layers // 3
    n_tail = cfg.n_layers - 3 * n_triples
    return n_triples, n_tail


def init_params(rng, cfg, dtype=jnp.float32) -> GriffinParams:
    ke, kt, kr, ku = jax.random.split(rng, 4)
    n_triples, n_tail = layout(cfg)

    def triple_init(r):
        r1, r2, r3 = jax.random.split(r, 3)
        return TripleParams(rec1=_rec_init(r1, cfg, dtype),
                            rec2=_rec_init(r2, cfg, dtype),
                            attn=_attn_init(r3, cfg, dtype))

    triples = jax.vmap(triple_init)(jax.random.split(kt, n_triples))
    tail = None
    if n_tail:
        tail = jax.vmap(lambda r: _rec_init(r, cfg, dtype))(
            jax.random.split(kr, n_tail))
    return GriffinParams(
        embed=L.embed_init(ke, cfg, dtype), triples=triples, tail=tail,
        ln_f=jnp.zeros((cfg.d_model,), dtype),
        unembed=None if cfg.tie_embeddings else L.embed_init(ku, cfg, dtype))


def param_logical(cfg):
    from repro.models.transformer import stack_logical
    n_triples, n_tail = layout(cfg)
    triple = TripleParams(rec1=_rec_logical(cfg), rec2=_rec_logical(cfg),
                          attn=_attn_logical(cfg))
    return GriffinParams(
        embed=L.embed_logical(),
        triples=stack_logical(triple),
        tail=stack_logical(_rec_logical(cfg)) if n_tail else None,
        ln_f=lg("embed"),
        unembed=None if cfg.tie_embeddings else L.embed_logical())


def _rglru(xb, r_gate, i_gate, lam, h0=None):
    """RG-LRU scan.  xb: (B, S, w); gates same shape; returns (y, h_last).

    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * x_t),
    a_t = exp(-c softplus(lam) r_t).
    """
    log_a = (-_C * jax.nn.softplus(lam.astype(jnp.float32))
             * r_gate.astype(jnp.float32))          # (B,S,w), negative
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * (i_gate.astype(jnp.float32) * xb.astype(jnp.float32))

    # prefix compositions: h_t = A_t h0 + B_t
    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    A, Bc = jax.lax.associative_scan(combine, (a, b), axis=1)
    if h0 is not None:
        y = A * h0[:, None, :].astype(jnp.float32) + Bc
    else:
        y = Bc
    return y.astype(xb.dtype), y[:, -1, :]  # h_last stays f32


def _rec_apply(p: RecBlockParams, cfg, x, state: Optional[RecState] = None):
    """Recurrent residual block + MLP.  Returns (x, new_state)."""
    u = L.rms_norm(x, p.ln1, cfg.norm_eps)
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", u, p.w_gate))
    xb = jnp.einsum("bsd,dw->bsw", u, p.w_x)
    xb = constrain(xb, "batch", "seq", "mlp")
    if state is not None:
        ext = jnp.concatenate([state.conv.astype(xb.dtype), xb], axis=1)
        conv = _causal_conv(ext, p.conv_w, p.conv_b)[:, state.conv.shape[1]:]
        h0 = state.h
    else:
        conv = _causal_conv(xb, p.conv_w, p.conv_b)
        h0 = None
    r_gate = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", conv, p.w_a) + p.b_a)
    i_gate = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", conv, p.w_i) + p.b_i)
    y, h_last = _rglru(conv, r_gate, i_gate, p.lam, h0)
    y = jnp.einsum("bsw,wd->bsd", y * gate, p.w_out)
    x = x + constrain(y, "batch", "seq", "embed")
    x = x + L.mlp_apply(p.mlp, L.rms_norm(x, p.ln2, cfg.norm_eps),
                        activation="gelu")
    # conv ring: always the last K-1 raw inputs (merge with prior state so
    # single-token decode keeps a full window)
    Kc = cfg.conv_kernel
    if state is not None:
        ring = jnp.concatenate([state.conv.astype(xb.dtype), xb], axis=1)
    else:
        ring = xb
    new_state = RecState(h=h_last, conv=ring[:, -(Kc - 1):, :])
    return x, new_state


def _attn_apply_block(p: AttnBlockParams, cfg, x, positions):
    h, (k, v) = L.attn_apply(p.attn, cfg,
                             L.rms_norm(x, p.ln1, cfg.norm_eps), positions,
                             causal=True, window=cfg.local_window)
    x = x + h
    x = x + L.mlp_apply(p.mlp, L.rms_norm(x, p.ln2, cfg.norm_eps),
                        activation="gelu")
    return x, (k, v)


def apply(params: GriffinParams, cfg, tokens, *, remat: str = "none",
          return_hidden: bool = False):
    x = L.embed_lookup(params.embed, tokens)
    S = tokens.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(x, trip):
        x, _ = _rec_apply(trip.rec1, cfg, x)
        x, _ = _rec_apply(trip.rec2, cfg, x)
        x, _ = _attn_apply_block(trip.attn, cfg, x, positions)
        return x, None

    if remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params.triples)
    if params.tail is not None:
        n_tail = jax.tree.leaves(params.tail)[0].shape[0]
        for t in range(n_tail):
            blk = jax.tree.map(lambda a: a[t], params.tail)
            x, _ = _rec_apply(blk, cfg, x)
    x = L.rms_norm(x, params.ln_f, cfg.norm_eps)
    if return_hidden:
        return x
    table = params.embed if params.unembed is None else params.unembed
    return L.logits_proj(table, x)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def _rec_state_init(cfg, batch, dtype):
    w = _width(cfg)
    return RecState(h=jnp.zeros((batch, w), jnp.float32),
                    conv=jnp.zeros((batch, cfg.conv_kernel - 1, w), dtype))


def init_cache(cfg, batch, horizon, dtype=jnp.bfloat16) -> GriffinCache:
    n_triples, n_tail = layout(cfg)
    cap = min(horizon, cfg.local_window)
    mk_rec = lambda _: _rec_state_init(cfg, batch, dtype)
    rec1 = jax.vmap(mk_rec)(jnp.arange(n_triples))
    rec2 = jax.vmap(mk_rec)(jnp.arange(n_triples))
    kv = jax.vmap(lambda _: L.kv_cache_init(
        batch, cap, cfg.n_kv_heads, cfg.head_dim, dtype))(
            jnp.arange(n_triples))
    tail = jax.vmap(mk_rec)(jnp.arange(n_tail)) if n_tail else None
    return GriffinCache(rec1=rec1, rec2=rec2, attn=kv, tail=tail)


def cache_logical(cfg):
    n_triples, n_tail = layout(cfg)
    rec = RecState(h=lg("layers", "batch", "mlp"),
                   conv=lg("layers", "batch", None, "mlp"))
    kv = L.KVCache(
        k=lg("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
        v=lg("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
        kpos=lg("layers", "kv_seq"))
    return GriffinCache(rec1=rec, rec2=rec, attn=kv,
                        tail=rec if n_tail else None)


def prefill(params: GriffinParams, cfg, tokens, horizon,
            kv_dtype=jnp.bfloat16):
    x = L.embed_lookup(params.embed, tokens)
    S = tokens.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    cap = min(horizon, cfg.local_window)

    def body(x, trip):
        x, s1 = _rec_apply(trip.rec1, cfg, x)
        x, s2 = _rec_apply(trip.rec2, cfg, x)
        x, (k, v) = _attn_apply_block(trip.attn, cfg, x, positions)
        kv = L.kv_cache_from_prefill(k, v, positions, cap, kv_dtype)
        s1 = RecState(h=s1.h, conv=s1.conv.astype(kv_dtype))
        s2 = RecState(h=s2.h, conv=s2.conv.astype(kv_dtype))
        return x, (s1, s2, kv)

    x, (rec1, rec2, kv) = jax.lax.scan(jax.checkpoint(body), x,
                                       params.triples)
    tail_states = None
    if params.tail is not None:
        n_tail = jax.tree.leaves(params.tail)[0].shape[0]
        ts = []
        for t in range(n_tail):
            blk = jax.tree.map(lambda a: a[t], params.tail)
            x, st = _rec_apply(blk, cfg, x)
            ts.append(RecState(h=st.h, conv=st.conv.astype(kv_dtype)))
        tail_states = jax.tree.map(lambda *xs: jnp.stack(xs), *ts)
    x = L.rms_norm(x, params.ln_f, cfg.norm_eps)
    table = params.embed if params.unembed is None else params.unembed
    return L.logits_proj(table, x), GriffinCache(rec1=rec1, rec2=rec2,
                                                 attn=kv, tail=tail_states)


def decode_step(params: GriffinParams, cfg, cache: GriffinCache, tokens,
                pos):
    x = jnp.take(params.embed, tokens, axis=0)

    def body(x, xs):
        trip, s1, s2, kv = xs
        x, s1n = _rec_apply(trip.rec1, cfg, x, state=s1)
        x, s2n = _rec_apply(trip.rec2, cfg, x, state=s2)
        h, kvn = L.attn_decode(trip.attn.attn, cfg,
                               L.rms_norm(x, trip.attn.ln1, cfg.norm_eps),
                               kv, pos, window=cfg.local_window)
        x = x + h
        x = x + L.mlp_apply(trip.attn.mlp,
                            L.rms_norm(x, trip.attn.ln2, cfg.norm_eps),
                            activation="gelu")
        s1n = RecState(h=s1n.h, conv=s1n.conv.astype(s1.conv.dtype))
        s2n = RecState(h=s2n.h, conv=s2n.conv.astype(s2.conv.dtype))
        return x, (s1n, s2n, kvn)

    x, (rec1, rec2, kv) = jax.lax.scan(
        body, x, (params.triples, cache.rec1, cache.rec2, cache.attn))
    tail_states = cache.tail
    if params.tail is not None:
        n_tail = jax.tree.leaves(params.tail)[0].shape[0]
        ts = []
        for t in range(n_tail):
            blk = jax.tree.map(lambda a: a[t], params.tail)
            st = jax.tree.map(lambda a: a[t], cache.tail)
            x, stn = _rec_apply(blk, cfg, x, state=st)
            ts.append(RecState(h=stn.h,
                               conv=stn.conv.astype(st.conv.dtype)))
        tail_states = jax.tree.map(lambda *xs: jnp.stack(xs), *ts)
    x = L.rms_norm(x, params.ln_f, cfg.norm_eps)
    table = params.embed if params.unembed is None else params.unembed
    return L.logits_proj(table, x), GriffinCache(rec1=rec1, rec2=rec2,
                                                 attn=kv, tail=tail_states)
