"""Checkpointing: msgpack + zstd pytree snapshots with elastic restore.

Layout: ``<dir>/step_<N>/state.msgpack.zst`` + ``manifest.json``.  Leaves
are stored as raw little-endian buffers keyed by their pytree path, so the
restore side can re-shard into ANY mesh: ``restore_checkpoint`` takes an
optional (mesh, shardings) and ``jax.device_put``s each leaf under its
target NamedSharding — elastic scale-up/down is a restore-time
re-partition, no resharding tool needed (DESIGN.md §7).

``AsyncCheckpointer`` snapshots to host memory synchronously (cheap) and
writes in a daemon thread so the step loop never blocks on I/O; ``wait()``
drains pending writes (called before exit and in tests).

A commit marker (``COMMIT``) is written last — torn checkpoints from a
mid-write failure are ignored by ``latest_step``.
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # zstd is an optional dep: fall back to uncompressed snapshots
    import zstandard
except ImportError:  # pragma: no cover - exercised where zstd is absent
    zstandard = None

HAS_ZSTD = zstandard is not None
_STATE_ZST = "state.msgpack.zst"
_STATE_RAW = "state.msgpack"


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "name"):
            out.append(str(p.name))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "key"):
            out.append(str(p.key))
        else:
            out.append(str(p))
    return "/".join(out)


def _pack_tree(tree) -> bytes:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    blob = {}
    for path, leaf in leaves:
        arr = np.asarray(leaf)
        blob[_path_str(path)] = {
            "dtype": arr.dtype.str,
            "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
    return msgpack.packb(blob, use_bin_type=True)


def _unpack_blob(raw: bytes):
    blob = msgpack.unpackb(raw, raw=False)
    return {k: np.frombuffer(v["data"], dtype=np.dtype(v["dtype"]))
            .reshape(v["shape"]) for k, v in blob.items()}


def save_checkpoint(directory: str, step: int, state: Any,
                    metadata: Optional[dict] = None) -> str:
    """Synchronous save.  Returns the checkpoint path."""
    ckpt_dir = os.path.join(directory, f"step_{step:010d}")
    tmp_dir = ckpt_dir + ".tmp"
    if os.path.exists(tmp_dir):  # stale torn write: never let old blobs
        shutil.rmtree(tmp_dir)   # (e.g. a .zst from a zstd-enabled run)
    os.makedirs(tmp_dir)         # shadow the snapshot written below
    packed = _pack_tree(state)
    if HAS_ZSTD:
        blob = zstandard.ZstdCompressor(level=3).compress(packed)
        fname, fmt = _STATE_ZST, "msgpack+zstd/v1"
    else:
        blob, fname, fmt = packed, _STATE_RAW, "msgpack/v1"
    with open(os.path.join(tmp_dir, fname), "wb") as f:
        f.write(blob)
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump({"step": step, "metadata": metadata or {},
                   "format": fmt}, f)
    with open(os.path.join(tmp_dir, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(ckpt_dir):
        shutil.rmtree(ckpt_dir)
    os.replace(tmp_dir, ckpt_dir)
    return ckpt_dir


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "COMMIT")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like: Any,
                       shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings for elastic re-partition onto the current mesh."""
    ckpt_dir = os.path.join(directory, f"step_{step:010d}")
    zst_path = os.path.join(ckpt_dir, _STATE_ZST)
    if os.path.exists(zst_path):
        if not HAS_ZSTD:
            raise ImportError(
                f"{zst_path} is zstd-compressed but the 'zstandard' module "
                "is not installed")
        with open(zst_path, "rb") as f:
            raw = zstandard.ZstdDecompressor().decompress(f.read())
    else:
        with open(os.path.join(ckpt_dir, _STATE_RAW), "rb") as f:
            raw = f.read()
    arrays = _unpack_blob(raw)

    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = None
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_flatten(shardings)[0]

    out = []
    for idx, (path, leaf) in enumerate(paths):
        key = _path_str(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        want = jnp.asarray(leaf).dtype if not hasattr(leaf, "dtype") \
            else leaf.dtype
        arr = arr.astype(want)
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[idx]))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """Non-blocking checkpointer: host snapshot now, disk write later."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue()
        self._errors: list = []
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_state, metadata = item
            try:
                save_checkpoint(self.directory, step, host_state, metadata)
                self._gc()
            except Exception as e:  # pragma: no cover
                self._errors.append(e)
            finally:
                self._q.task_done()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    def save(self, step: int, state: Any, metadata: Optional[dict] = None):
        # device -> host snapshot is synchronous; I/O is not
        host_state = jax.tree.map(np.asarray, state)
        self._q.put((int(step), host_state, metadata))

    def wait(self):
        self._q.join()
        if self._errors:
            raise self._errors[0]

    def close(self):
        self.wait()
        self._q.put(None)
        self._thread.join()
