from repro.checkpoint.ckpt import (save_checkpoint, restore_checkpoint,
                                   AsyncCheckpointer, latest_step)
