"""SMO step algebra: truncated Newton step (eq. 2), gains (eq. 3/4),
the planning-ahead step (eq. 7/8) and the overshoot heuristic (§7.3).

All functions are scalar jnp math (shape ()), usable under jit/vmap, and are
exercised directly by the unit/property tests against finite differences and
grid search.

Everything here is stated for the *general* dual
(:class:`repro.core.qp.DualQP`: arbitrary linear term ``p``, arbitrary box
``[L, U]``) — the algebra only ever sees the gradient ``G = p - Q a`` and
the per-coordinate bounds, so the same functions drive classification,
ε-SVR (doubled operator) and one-class lanes unchanged.

Notation follows the paper.  For a working set ``B = (i, j)`` and direction
``v_B = e_i - e_j``:

    l    = v_B . grad f(a)        (directional derivative, ``w_t`` at a^(0))
    Qtt  = v_B . K v_B = K_ii - 2 K_ij + K_jj   (curvature)
    Lt   = max(L_i - a_i, a_j - U_j)            (lower step bound)
    Ut   = min(U_i - a_i, a_j - L_j)            (upper step bound)
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.qp import TAU


class StepBounds(NamedTuple):
    lo: jax.Array  # \tilde L_t  (<= 0 at a feasible point)
    hi: jax.Array  # \tilde U_t  (>= 0 at a feasible point)


def step_bounds(ai, aj, Li, Ui, Lj, Uj) -> StepBounds:
    """Feasible interval of the step size mu along ``v_B = e_i - e_j``."""
    return StepBounds(lo=jnp.maximum(Li - ai, aj - Uj),
                      hi=jnp.minimum(Ui - ai, aj - Lj))


def newton_step(l, Qtt):
    """Unconstrained maximizer ``mu* = l / max(Qtt, tau)`` of the sub-problem."""
    return l / jnp.maximum(Qtt, TAU)


def clip_step(mu, bounds: StepBounds):
    """Eq. (2): truncate the step to the feasible interval."""
    return jnp.maximum(jnp.minimum(mu, bounds.hi), bounds.lo)


def smo_step(l, Qtt, bounds: StepBounds):
    """The standard SMO update: clipped Newton step.  Returns (mu, free).

    ``free`` is True iff the Newton step was not truncated — the paper's
    "free step" predicate that gates planning-ahead (Alg. 4).
    """
    mu_star = newton_step(l, Qtt)
    mu = clip_step(mu_star, bounds)
    free = (mu_star > bounds.lo) & (mu_star < bounds.hi)
    return mu, free


def gain_newton(l, Qtt):
    """Eq. (3): second-order gain bound ``g~_B = l^2 / (2 Qtt)``.

    Exact iff the step is free.  With the tau guard this matches LIBSVM's
    WSS2 objective.
    """
    return 0.5 * l * l / jnp.maximum(Qtt, TAU)


def gain_of_step(mu, l, Qtt):
    """Exact gain of a step of size mu: ``g = l mu - 1/2 Qtt mu^2``.

    Plugging the clipped step (eq. 2) into this yields the exact SMO gain
    ``g_B(a)`` used by Alg. 3's exact-gain branch.
    """
    return l * mu - 0.5 * Qtt * mu * mu


class PlanningTerms(NamedTuple):
    """2x2 restriction of the QP onto directions v_B1 (current), v_B2 (next)."""

    w1: jax.Array   # v_B1 . grad f(a)
    w2: jax.Array   # v_B2 . grad f(a)
    Q11: jax.Array  # v_B1 . K v_B1
    Q22: jax.Array  # v_B2 . K v_B2
    Q12: jax.Array  # v_B1 . K v_B2


def planning_step(t: PlanningTerms):
    """Eq. (8): the planning-ahead step size.

    ``mu1 = (Q22 w1 - Q12 w2) / det(Q)`` maximizes the two-step gain (eq. 7)
    under the assumption that the next (greedy Newton) step uses B2.
    Returns ``(mu1, ok)`` where ``ok`` is False when det(Q) is numerically
    degenerate (directions parallel in the K-metric) — the caller then falls
    back to the plain SMO step, mirroring Alg. 4's guard structure.
    """
    det = t.Q11 * t.Q22 - t.Q12 * t.Q12
    ok = (det > TAU) & (t.Q22 > TAU)
    mu1 = (t.Q22 * t.w1 - t.Q12 * t.w2) / jnp.where(ok, det, 1.0)
    return jnp.where(ok, mu1, 0.0), ok


def planned_second_step(mu1, t: PlanningTerms):
    """Eq. (6): the greedy Newton step on B2 after a first step mu1 on B1."""
    return (t.w2 - t.Q12 * mu1) / jnp.maximum(t.Q22, TAU)


def double_step_gain(mu1, t: PlanningTerms):
    """Eq. (7): total gain of (mu1 on B1) followed by the Newton step on B2."""
    det = t.Q11 * t.Q22 - t.Q12 * t.Q12
    q22 = jnp.maximum(t.Q22, TAU)
    return (-0.5 * det / q22 * mu1 * mu1
            + (t.Q22 * t.w1 - t.Q12 * t.w2) / q22 * mu1
            + 0.5 * t.w2 * t.w2 / q22)


def conjugate_step(t: PlanningTerms):
    """Conjugate-SMO 2-direction step (arXiv 2003.08719, §3).

    Solve the unconstrained 2x2 system on ``(v_B1, v_prev)`` exactly:
    ``mu1 = (Q22 w1 - Q12 w2) / det``, ``mu2 = (Q11 w2 - Q12 w1) / det``.
    Unlike :func:`planning_step` (which *plans* a future greedy step), both
    components are applied now — v_prev is the previous iteration's update
    direction, so the pair is conjugate in the K-metric when accepted.
    Returns ``(mu1, mu2, ok)``; ``ok`` is False on a degenerate 2x2 system
    (parallel directions, e.g. the WSS pair repeating) and the caller falls
    back to the plain clipped SMO step.
    """
    det = t.Q11 * t.Q22 - t.Q12 * t.Q12
    ok = (det > TAU) & (t.Q22 > TAU)
    safe = jnp.where(ok, det, 1.0)
    mu1 = (t.Q22 * t.w1 - t.Q12 * t.w2) / safe
    mu2 = (t.Q11 * t.w2 - t.Q12 * t.w1) / safe
    return jnp.where(ok, mu1, 0.0), jnp.where(ok, mu2, 0.0), ok


def overshoot_step(l, Qtt, bounds: StepBounds, factor: float = 1.1):
    """§7.3 heuristic: clip ``factor * mu*`` instead of ``mu*``.

    Retains ``1 - (factor-1)^2`` of the Newton gain per step (Fig. 2) while
    being a two-character patch to an existing solver.
    """
    mu_star = newton_step(l, Qtt)
    mu = clip_step(factor * mu_star, bounds)
    free = (factor * mu_star > bounds.lo) & (factor * mu_star < bounds.hi)
    return mu, free
