"""Pure-numpy reference implementation of SMO and PA-SMO.

This is the trusted oracle: a direct, sequential transcription of the
paper's Algorithm 1 (SMO with WSS2) and Algorithm 5 (the complete PA-SMO),
in float64, with LIBSVM-compatible guards.  The JAX solver in
:mod:`repro.core.solver` is tested for trajectory equality against this
module on small problems, and the paper-validation benchmarks
(EXPERIMENTS.md §Paper-validation) compare SMO vs PA-SMO iteration counts
with this pair of implementations as well as with the JAX pair.

No jax imports here on purpose.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

TAU = 1e-12


@dataclasses.dataclass
class RefResult:
    alpha: np.ndarray
    iterations: int
    objective: float
    kkt_gap: float
    converged: bool
    n_planning: int = 0
    n_free: int = 0
    n_clipped: int = 0
    n_plan_reverted: int = 0
    ratios: Optional[List[float]] = None  # mu/mu* of each planning step
    # (i, j, mu, planned) trace
    steps: Optional[List[Tuple[int, int, float, bool]]] = None


def _objective(alpha, y, K):
    return float(y @ alpha - 0.5 * alpha @ (K @ alpha))


def _bounds(y, C):
    yC = y * C
    return np.minimum(0.0, yC), np.maximum(0.0, yC)


def _argmax(values, mask, tie: str):
    """Masked argmax with 'first' (numpy/JAX) or 'last' (LIBSVM) tie-break."""
    v = np.where(mask, values, -np.inf)
    if tie == "last":
        idx = len(v) - 1 - int(np.argmax(v[::-1]))
    else:
        idx = int(np.argmax(v))
    return idx, v[idx]


def _step_bounds(ai, aj, Li, Ui, Lj, Uj):
    return max(Li - ai, aj - Uj), min(Ui - ai, aj - Lj)


def _select_wss2(G, K, diag, up, dn, tie, exact=False, alpha=None, L=None, U=None):
    """Second-order selection; exact=True uses the clipped gain g (Alg. 3)."""
    i, g_i = _argmax(G, up, tie)
    l = g_i - G
    q = np.maximum(K[i, i] - 2.0 * K[i] + diag, TAU)
    if exact:
        lo = np.maximum(L[i] - alpha[i], alpha - U)
        hi = np.minimum(U[i] - alpha[i], alpha - L)
        mu = np.clip(l / q, lo, hi)
        gains = l * mu - 0.5 * q * mu * mu
    else:
        gains = 0.5 * l * l / q
    cand = dn & (l > 0)
    cand[i] = False
    j, gain = _argmax(gains, cand, tie)
    return i, j, gain


def _cand_gain(B, G, K, up, dn, exact=False, alpha=None, L=None, U=None):
    """Gain of an explicit candidate working set; -inf if not admissible."""
    i, j = B
    l = G[i] - G[j]
    if not (up[i] and dn[j] and l > 0 and i != j):
        return -np.inf
    q = max(K[i, i] - 2.0 * K[i, j] + K[j, j], TAU)
    if exact:
        lo, hi = _step_bounds(alpha[i], alpha[j], L[i], U[i], L[j], U[j])
        mu = min(max(l / q, lo), hi)
        return l * mu - 0.5 * q * mu * mu
    return 0.5 * l * l / q


def solve_qp_smo(Q, p, L, U, alpha0=None, eps=1e-3, max_iter=10_000_000,
                 tie="last", overshoot: float = 1.0,
                 record_steps=False) -> RefResult:
    """General-dual SMO oracle: ``max p.a - 1/2 a.Q a`` over ``[L, U]``
    with the equality constraint fixed by ``alpha0`` (default 0).

    This is the dense trusted reference for EVERY instance of the general
    dual — classification (``p = y``), ε-SVR (pass the materialized
    2l x 2l doubled ``Q``; dense is fine here, it is the *oracle*, the
    production engines never build it), one-class (``p = 0`` with a
    feasible ``alpha0``).  ``overshoot`` != 1 gives the §7.3 heuristic
    (clip(overshoot * mu*)).
    """
    Q = np.asarray(Q, np.float64)
    p = np.asarray(p, np.float64)
    L = np.asarray(L, np.float64)
    U = np.asarray(U, np.float64)
    n = len(p)
    if alpha0 is None:
        alpha = np.zeros(n)
        G = p.copy()
    else:
        alpha = np.asarray(alpha0, np.float64).copy()
        G = p - Q @ alpha
    diag = np.diagonal(Q).copy()
    n_free = n_clipped = 0
    steps: List[Tuple[int, int, float, bool]] = []
    t = 0
    while t < max_iter:
        up = alpha < U
        dn = alpha > L
        g_up = np.max(np.where(up, G, -np.inf))
        g_dn = np.min(np.where(dn, G, np.inf))
        if g_up - g_dn <= eps:
            return RefResult(alpha, t, _objective(alpha, p, Q), g_up - g_dn,
                             True, 0, n_free, n_clipped, 0,
                             steps=steps if record_steps else None)
        i, j, _ = _select_wss2(G, Q, diag, up, dn, tie)
        l = G[i] - G[j]
        q = max(Q[i, i] - 2.0 * Q[i, j] + Q[j, j], TAU)
        lo, hi = _step_bounds(alpha[i], alpha[j], L[i], U[i], L[j], U[j])
        mu_star = overshoot * (l / q)
        mu = min(max(mu_star, lo), hi)
        if lo < mu_star < hi:
            n_free += 1
        else:
            n_clipped += 1
        if record_steps:
            steps.append((i, j, mu, False))
        alpha[i] += mu
        alpha[j] -= mu
        G -= mu * (Q[i] - Q[j])
        t += 1
    up = alpha < U
    dn = alpha > L
    gap = (np.max(np.where(up, G, -np.inf)) - np.min(np.where(dn, G, np.inf)))
    return RefResult(alpha, t, _objective(alpha, p, Q), gap, False,
                     0, n_free, n_clipped, 0,
                     steps=steps if record_steps else None)


def doubled_qp(K, y, C, epsilon):
    """Materialize the ε-SVR doubled dual ``(Q, p, L, U)`` for the oracle.

    Dense 2l x 2l — test/reference use only (the solvers tile base rows).
    """
    K = np.asarray(K, np.float64)
    y = np.asarray(y, np.float64)
    n = len(y)
    Q = np.tile(K, (2, 2))
    p = np.concatenate([y - epsilon, y + epsilon])
    Cv = np.broadcast_to(np.asarray(C, np.float64), (n,))
    L = np.concatenate([np.zeros(n), -Cv])
    U = np.concatenate([Cv, np.zeros(n)])
    return Q, p, L, U


def solve_smo(K, y, C, eps=1e-3, max_iter=10_000_000, tie="last",
              overshoot: float = 1.0, record_steps=False) -> RefResult:
    """Algorithm 1 with WSS2 (eq. 3) — the LIBSVM 2.84 baseline.

    The ``p = y`` classification instance of :func:`solve_qp_smo`.
    ``overshoot`` != 1 gives the §7.3 heuristic (clip(overshoot * mu*)).
    """
    y = np.asarray(y, np.float64)
    L, U = _bounds(y, C)
    return solve_qp_smo(K, y, L, U, eps=eps, max_iter=max_iter, tie=tie,
                        overshoot=overshoot, record_steps=record_steps)


def solve_pasmo(K, y, C, eps=1e-3, max_iter=10_000_000, eta=0.9, tie="last",
                record_ratios=False, record_steps=False) -> RefResult:
    """Algorithm 5 — the complete PA-SMO algorithm, transcribed faithfully.

    Selection (Alg. 3): after a planning step with ratio inside
    [1-eta, 1+eta] use the g~ objective, otherwise the exact gain g; in both
    cases B^(t-2) competes as an extra candidate.  Update (Alg. 4): plan
    ahead only after a *free* SMO step; fall back to the plain SMO step if
    the current or the planned step would end at the box boundary.
    """
    K = np.asarray(K, np.float64)
    y = np.asarray(y, np.float64)
    n = len(y)
    L, U = _bounds(y, C)
    alpha = np.zeros(n)
    G = y.copy()
    diag = np.diagonal(K).copy()

    p_smo = True          # previous iteration performed a SMO step
    prev_free = False     # ... and it was free
    prev_ratio_ok = True  # mu/mu* of the previous planning step in [1-eta, 1+eta]
    B_prev: Optional[Tuple[int, int]] = None   # B^(t-1)
    B_prev2: Optional[Tuple[int, int]] = None  # B^(t-2)
    n_planning = n_free = n_clipped = n_reverted = 0
    ratios: List[float] = []
    steps: List[Tuple[int, int, float, bool]] = []

    t = 0
    while t < max_iter:
        up = alpha < U
        dn = alpha > L
        g_up = np.max(np.where(up, G, -np.inf))
        g_dn = np.min(np.where(dn, G, np.inf))
        if g_up - g_dn <= eps:
            return RefResult(alpha, t, _objective(alpha, y, K), g_up - g_dn,
                             True, n_planning, n_free, n_clipped, n_reverted,
                             ratios if record_ratios else None,
                             steps=steps if record_steps else None)

        # --- working set selection (Alg. 3) ---------------------------------
        if p_smo:
            i, j, _ = _select_wss2(G, K, diag, up, dn, tie)
        else:
            exact = not prev_ratio_ok
            i, j, gain = _select_wss2(G, K, diag, up, dn, tie, exact=exact,
                                      alpha=alpha, L=L, U=U)
            if B_prev2 is not None:
                cg = _cand_gain(B_prev2, G, K, up, dn, exact=exact,
                                alpha=alpha, L=L, U=U)
                if cg > gain:
                    i, j = B_prev2

        # --- step computation (Alg. 4) --------------------------------------
        l = G[i] - G[j]
        q11 = max(K[i, i] - 2.0 * K[i, j] + K[j, j], TAU)
        lo, hi = _step_bounds(alpha[i], alpha[j], L[i], U[i], L[j], U[j])
        mu_star = l / q11

        planned = False
        mu = None
        if prev_free and B_prev is not None:
            pi, pj = B_prev
            w1 = l
            w2 = G[pi] - G[pj]
            q22 = K[pi, pi] - 2.0 * K[pi, pj] + K[pj, pj]
            q12 = K[i, pi] - K[i, pj] - K[j, pi] + K[j, pj]
            det = q11 * q22 - q12 * q12
            if det > TAU and q22 > TAU:
                mu1 = (q22 * w1 - q12 * w2) / det
                mu2 = (w2 - q12 * mu1) / q22
                # feasibility of the planned pair of steps (strict interior)
                a_pi = alpha[pi] + mu1 * ((pi == i) - (pi == j))
                a_pj = alpha[pj] + mu1 * ((pj == i) - (pj == j))
                lo2, hi2 = _step_bounds(a_pi, a_pj, L[pi], U[pi], L[pj], U[pj])
                if lo < mu1 < hi and lo2 < mu2 < hi2:
                    planned = True
                    mu = mu1
                    ratio = mu1 / mu_star if abs(mu_star) > 0 else np.inf
                    prev_ratio_ok = (1 - eta) <= ratio <= (1 + eta)
                    if record_ratios:
                        ratios.append(ratio)
                else:
                    n_reverted += 1
            else:
                n_reverted += 1

        if planned:
            n_planning += 1
            p_smo = False
            prev_free = False
        else:
            mu = min(max(mu_star, lo), hi)
            free = lo < mu_star < hi
            if free:
                n_free += 1
            else:
                n_clipped += 1
            p_smo = True
            prev_free = free

        if record_steps:
            steps.append((i, j, mu, planned))
        alpha[i] += mu
        alpha[j] -= mu
        G -= mu * (K[i] - K[j])
        B_prev2 = B_prev
        B_prev = (i, j)
        t += 1

    up = alpha < U
    dn = alpha > L
    gap = (np.max(np.where(up, G, -np.inf)) - np.min(np.where(dn, G, np.inf)))
    return RefResult(alpha, t, _objective(alpha, y, K), gap, False,
                     n_planning, n_free, n_clipped, n_reverted,
                     ratios if record_ratios else None,
                     steps=steps if record_steps else None)


def solve_pasmo_multi(K, y, C, N=3, eps=1e-3, max_iter=10_000_000, eta=0.9,
                      tie="last") -> RefResult:
    """§7.4 multiple planning-ahead: plan with the N most recent working sets,
    take the largest feasible double-step gain; the N sets also compete in
    working-set selection."""
    K = np.asarray(K, np.float64)
    y = np.asarray(y, np.float64)
    n = len(y)
    L, U = _bounds(y, C)
    alpha = np.zeros(n)
    G = y.copy()
    diag = np.diagonal(K).copy()

    recent: List[Tuple[int, int]] = []   # most recent first
    p_smo = True
    prev_free = False
    prev_ratio_ok = True
    n_planning = n_free = n_clipped = n_reverted = 0

    t = 0
    while t < max_iter:
        up = alpha < U
        dn = alpha > L
        g_up = np.max(np.where(up, G, -np.inf))
        g_dn = np.min(np.where(dn, G, np.inf))
        if g_up - g_dn <= eps:
            return RefResult(alpha, t, _objective(alpha, y, K), g_up - g_dn,
                             True, n_planning, n_free, n_clipped, n_reverted)

        if p_smo:
            i, j, _ = _select_wss2(G, K, diag, up, dn, tie)
        else:
            exact = not prev_ratio_ok
            i, j, gain = _select_wss2(G, K, diag, up, dn, tie, exact=exact,
                                      alpha=alpha, L=L, U=U)
            for B in recent[1:]:  # sets older than B^(t-1) are WSS candidates
                cg = _cand_gain(B, G, K, up, dn, exact=exact,
                                alpha=alpha, L=L, U=U)
                if cg > gain:
                    i, j = B
                    gain = cg

        l = G[i] - G[j]
        q11 = max(K[i, i] - 2.0 * K[i, j] + K[j, j], TAU)
        lo, hi = _step_bounds(alpha[i], alpha[j], L[i], U[i], L[j], U[j])
        mu_star = l / q11

        best_gain, best_mu, best_ratio = -np.inf, None, None
        if prev_free:
            for pi, pj in recent[:N]:
                w2 = G[pi] - G[pj]
                q22 = K[pi, pi] - 2.0 * K[pi, pj] + K[pj, pj]
                q12 = K[i, pi] - K[i, pj] - K[j, pi] + K[j, pj]
                det = q11 * q22 - q12 * q12
                if det <= TAU or q22 <= TAU:
                    continue
                mu1 = (q22 * l - q12 * w2) / det
                mu2 = (w2 - q12 * mu1) / q22
                a_pi = alpha[pi] + mu1 * ((pi == i) - (pi == j))
                a_pj = alpha[pj] + mu1 * ((pj == i) - (pj == j))
                lo2, hi2 = _step_bounds(a_pi, a_pj, L[pi], U[pi], L[pj], U[pj])
                if not (lo < mu1 < hi and lo2 < mu2 < hi2):
                    continue
                g2 = (-0.5 * det / q22 * mu1 * mu1
                      + (q22 * l - q12 * w2) / q22 * mu1
                      + 0.5 * w2 * w2 / q22)
                if g2 > best_gain:
                    best_gain, best_mu = g2, mu1
                    best_ratio = mu1 / mu_star if abs(mu_star) > 0 else np.inf

        if best_mu is not None:
            mu = best_mu
            n_planning += 1
            p_smo = False
            prev_free = False
            prev_ratio_ok = (1 - eta) <= best_ratio <= (1 + eta)
        else:
            if prev_free:
                n_reverted += 1
            mu = min(max(mu_star, lo), hi)
            free = lo < mu_star < hi
            n_free += int(free)
            n_clipped += int(not free)
            p_smo = True
            prev_free = free

        alpha[i] += mu
        alpha[j] -= mu
        G -= mu * (K[i] - K[j])
        recent.insert(0, (i, j))
        del recent[N + 1:]
        t += 1

    up = alpha < U
    dn = alpha > L
    gap = (np.max(np.where(up, G, -np.inf)) - np.min(np.where(dn, G, np.inf)))
    return RefResult(alpha, t, _objective(alpha, y, K), gap, False,
                     n_planning, n_free, n_clipped, n_reverted)
