"""Dual quadratic programs: problem containers and exact math.

The paper (Glasmachers, "The Planning-ahead SMO Algorithm") states its
analysis for the *general* SMO dual

    max  f(a) = p^T a - 1/2 a^T Q a
    s.t. sum(a) = const,   L_i <= a_i <= U_i

with gradient ``grad f(a) = p - Q a``.  :class:`DualQP` is that problem
description — linear term ``p`` plus the per-coordinate box — and the
step / gain algebra in :mod:`repro.core.step` and the working-set
selection in :mod:`repro.core.wss` already operate on this form (they
only ever see ``G``, ``L``, ``U``).  Equality-constraint signs are folded
into the variables (the *signed* convention): every instance below
substitutes ``a_i <- s_i a_i`` so the constraint is always ``sum(a) =
const`` and the SMO direction is always ``e_i - e_j``; the signs survive
only in the box bounds and in ``p``.

Instances (constructors below):

* classification — ``p = y``, box ``[min(0, y_i C), max(0, y_i C)]``,
  ``sum(a) = 0`` (the historical hard-coded case; per-sample ``C_i``
  gives class-weighted SVC).
* ε-SVR — 2l doubled variables ``a = (alpha+, -alpha-)`` sharing ONE
  l x l Gram through :class:`DoubledKernel`: ``p = (y - eps, y + eps)``,
  box ``([0, C], [-C, 0])``, ``sum(a) = 0``.  The 2l x 2l matrix is
  never materialized — its rows are tiled base rows.
* one-class / ν novelty detection — ``p = 0``, box ``[0, 1/(nu l)]``,
  ``sum(a) = 1`` (feasible start from :func:`oneclass_alpha0`).

Everything in this module is pure ``jnp`` (jit/vmap friendly) and is also
the oracle used by the property tests.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# LIBSVM's guard for vanishing curvature (footnote 1 in the paper).
TAU = 1e-12


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Bounds:
    """Box bounds of the signed dual problem."""

    lower: jax.Array  # (l,)  L_i = min(0, y_i C)
    upper: jax.Array  # (l,)  U_i = max(0, y_i C)


def make_bounds(y: jax.Array, C) -> Bounds:
    """Per-coordinate box bounds ``[min(0, y_i C), max(0, y_i C)]``.

    ``C`` broadcasts: a scalar is the classic shared budget, an (l,)
    vector gives per-sample budgets (class-weighted SVC).
    """
    yC = y * C
    zero = jnp.zeros_like(yC)
    return Bounds(lower=jnp.minimum(zero, yC), upper=jnp.maximum(zero, yC))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DualQP:
    """General SMO dual: ``max p^T a - 1/2 a^T Q a`` over ``bounds`` with
    one equality constraint ``sum(a) = const`` (signs folded into the box;
    the constant is fixed by the feasible starting point).

    The kernel/Gram operator ``Q`` is NOT part of the container — it comes
    from a kernel oracle (below), so one problem description works for a
    precomputed Gram, on-the-fly RBF rows, or the doubled SVR operator.
    """

    p: jax.Array       # (n,) linear term
    bounds: Bounds     # (n,) per-coordinate box


def classification_qp(y: jax.Array, C) -> DualQP:
    """The signed classification dual (eq. 1): ``p = y``, box from labels.

    ``C`` may be a scalar or an (l,) per-sample vector (class weights).
    """
    return DualQP(p=y, bounds=make_bounds(y, C))


def svr_qp(y: jax.Array, C, epsilon) -> DualQP:
    """The ε-SVR dual in signed doubled form (2l variables).

    With ``a = (alpha+, -alpha-)`` the usual ε-insensitive dual

        max  y^T (a+ - a-) - eps sum(a+ + a-) - 1/2 (a+ - a-)^T K (a+ - a-)

    becomes exactly the general form over the doubled operator
    ``Q[k, k'] = K[k mod l, k' mod l]`` (see :class:`DoubledKernel`) with

        p = (y - eps, y + eps),  box = ([0, C], [-C, 0]),  sum(a) = 0.

    The regression coefficients are ``beta = a[:l] + a[l:]``
    (:func:`svr_fold`).  Conjugate pairs ``(k, k + l)`` can never be
    selected as a working set: ``G_k - G_{k+l} = -2 eps <= 0`` identically.
    """
    y = jnp.asarray(y)
    C = jnp.broadcast_to(jnp.asarray(C, y.dtype), y.shape)
    eps = jnp.asarray(epsilon, y.dtype)
    zero = jnp.zeros_like(y)
    return DualQP(
        p=jnp.concatenate([y - eps, y + eps]),
        bounds=Bounds(lower=jnp.concatenate([zero, -C]),
                      upper=jnp.concatenate([C, zero])))


def svr_fold(alpha: jax.Array) -> jax.Array:
    """Fold a doubled SVR dual vector to coefficients ``beta = a+ - a-``."""
    n = alpha.shape[-1] // 2
    return alpha[..., :n] + alpha[..., n:]


def oneclass_qp(n: int, nu, dtype=jnp.float64) -> DualQP:
    """The one-class (ν novelty-detection) dual: ``p = 0``, box
    ``[0, 1/(nu l)]``, equality ``sum(a) = 1``.

    The zero vector is NOT feasible — start from :func:`oneclass_alpha0`
    (and a matching ``G0 = -K alpha0``).
    """
    u = 1.0 / (jnp.asarray(nu, dtype) * n)
    return DualQP(p=jnp.zeros((n,), dtype),
                  bounds=Bounds(lower=jnp.zeros((n,), dtype),
                                upper=jnp.full((n,), u, dtype)))


def oneclass_alpha0(n: int, nu: float, dtype=jnp.float64) -> jax.Array:
    """LIBSVM's feasible one-class start: the first ``floor(nu l)``
    coordinates at the upper bound ``1/(nu l)``, one fractional remainder
    coordinate, ``sum(a) = 1`` exactly."""
    nl = float(nu) * n
    m = int(np.floor(nl))
    a0 = np.zeros(n)
    a0[:m] = 1.0 / nl
    if m < n:
        a0[m] = (nl - m) / nl
    return jnp.asarray(a0, dtype)


def dual_objective(alpha: jax.Array, p: jax.Array, K: jax.Array) -> jax.Array:
    """``f(a) = p^T a - 1/2 a^T Q a`` (general form; ``p = y`` in eq. 1)."""
    return jnp.dot(p, alpha) - 0.5 * jnp.dot(alpha, K @ alpha)


def gradient(alpha: jax.Array, p: jax.Array, K: jax.Array) -> jax.Array:
    """``grad f(a) = p - Q a`` (``p = y`` in the classification instance)."""
    return p - K @ alpha


def up_mask(alpha: jax.Array, bounds: Bounds, tol: float = 0.0) -> jax.Array:
    """Indicator of ``I_up(a) = {i | a_i < U_i}``."""
    return alpha < bounds.upper - tol


def down_mask(alpha: jax.Array, bounds: Bounds, tol: float = 0.0) -> jax.Array:
    """Indicator of ``I_down(a) = {i | a_i > L_i}``."""
    return alpha > bounds.lower + tol


def kkt_gap(G: jax.Array, alpha: jax.Array, bounds: Bounds,
            active: Optional[jax.Array] = None) -> jax.Array:
    """KKT violation gap ``psi(a)`` used in the stopping rule (Alg. 1 step 4).

    ``psi(a) = max{G_i | i in I_up} - min{G_j | j in I_down}``.
    ``active`` optionally restricts the reductions (soft shrinking).
    """
    up = up_mask(alpha, bounds)
    dn = down_mask(alpha, bounds)
    if active is not None:
        up = up & active
        dn = dn & active
    neg_inf = jnp.array(-jnp.inf, G.dtype)
    pos_inf = jnp.array(jnp.inf, G.dtype)
    g_up = jnp.max(jnp.where(up, G, neg_inf))
    g_dn = jnp.min(jnp.where(dn, G, pos_inf))
    return g_up - g_dn


def finite_gap(gap: jax.Array) -> jax.Array:
    """Clamp a KKT gap from empty-endpoint reductions to a finite value.

    When one side of the box is fully pinned (tiny C, a one-class lane with
    every alpha at a bound, or a fully-shrunk active set), the masked
    max/min endpoints reduce over an empty set and the raw gap is -inf (or
    NaN downstream).  An empty ``I_up`` or ``I_down`` means *no violating
    pair exists*, so the correct gap is 0 — converged, finite.
    """
    return jnp.where(jnp.isfinite(gap), gap, jnp.zeros_like(gap))


def safe_bias(g_up: jax.Array, g_dn: jax.Array) -> jax.Array:
    """Bias from the KKT gap endpoints, robust to empty endpoint sets.

    The textbook ``b = (g_up + g_dn) / 2`` is non-finite when either
    masked reduction was empty (``g_up = -inf`` / ``g_dn = +inf``: one box
    side fully pinned).  Like LIBSVM's ``Solver::calculate_rho`` fall back
    to the surviving endpoint; 0 when both sides are empty (the C = 0
    degenerate lane).
    """
    fin_up = jnp.isfinite(g_up)
    fin_dn = jnp.isfinite(g_dn)
    gu = jnp.where(fin_up, g_up, g_dn)
    gd = jnp.where(fin_dn, g_dn, g_up)
    return jnp.where(fin_up | fin_dn, 0.5 * (gu + gd),
                     jnp.zeros_like(g_up))


def shrink_mask(G: jax.Array, alpha: jax.Array, L: jax.Array,
                U: jax.Array) -> jax.Array:
    """Conservative active mask over the trailing coordinate axis (batched).

    Drops bound-pinned variables that cannot belong to any violating pair
    under the current gap endpoints: a variable at its lower bound only
    acts as an ``i`` (up) candidate and is unpromising when
    ``G_i < min_{I_down} G``; one at its upper bound only acts as a ``j``
    (down) candidate, unpromising when ``G_j > max_{I_up} G``.  Interior
    variables always stay active.  Leading axes broadcast, so this serves
    the single-lane solver ((l,) inputs) and the fused lane batch
    ((B, n)) alike.
    """
    up = alpha < U
    dn = alpha > L
    g_up = jnp.max(jnp.where(up, G, -jnp.inf), axis=-1, keepdims=True)
    g_dn = jnp.min(jnp.where(dn, G, jnp.inf), axis=-1, keepdims=True)
    inactive = (~dn & (G < g_dn)) | (~up & (G > g_up))
    return ~inactive


def is_feasible(alpha: jax.Array, bounds: Bounds, atol: float = 1e-9) -> jax.Array:
    """Feasibility predicate for property tests."""
    box = jnp.all((alpha >= bounds.lower - atol) & (alpha <= bounds.upper + atol))
    eq = jnp.abs(jnp.sum(alpha)) <= atol * (1 + jnp.sum(jnp.abs(alpha)))
    return box & eq


# ---------------------------------------------------------------------------
# Kernel oracles
# ---------------------------------------------------------------------------
#
# The SMO loop never needs the full Gram matrix; it needs rows, the diagonal
# and tiny principal minors.  The oracle abstraction lets the same solver run
# from (a) a precomputed K (tests / small problems), or (b) on-the-fly rows
# computed from the data matrix X (production path, backed by the Pallas
# kernels in ``repro.kernels``).


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PrecomputedKernel:
    """Oracle over a dense precomputed Gram matrix."""

    K: jax.Array  # (l, l) symmetric PSD

    @property
    def n(self) -> int:
        return self.K.shape[0]

    def row(self, i: jax.Array) -> jax.Array:
        return jnp.take(self.K, i, axis=0)

    def diag(self) -> jax.Array:
        # strided slice of the flat matrix: jnp.diagonal builds an int64
        # gather-index vector under x64 (off the int32 index channel)
        n = self.K.shape[0]
        return jax.lax.slice(self.K.reshape(-1), (0,), (n * n,), (n + 1,))

    def entry(self, i: jax.Array, j: jax.Array) -> jax.Array:
        return self.K[i, j]

    def matvec(self, v: jax.Array) -> jax.Array:
        return self.K @ v


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StackedKernel:
    """Oracle over one Gram matrix inside a stacked (n_stack, l, l) bank.

    A batch of QPs that share a few distinct Gram matrices (e.g. the
    one-vs-rest heads of a multiclass C/gamma grid: ``k`` lanes per gamma)
    vmaps with ``Ks`` un-mapped and ``g`` lane-mapped, so every access is a
    gather into the shared bank — no per-lane (l, l) copy is ever
    materialized (``jnp.repeat`` on the bank costs k-fold memory).
    """

    Ks: jax.Array  # (n_stack, l, l) symmetric PSD bank
    g: jax.Array   # scalar int32 index into the bank

    @property
    def n(self) -> int:
        return self.Ks.shape[-1]

    def row(self, i: jax.Array) -> jax.Array:
        return self.Ks[self.g, i]

    def diag(self) -> jax.Array:
        idx = jnp.arange(self.n, dtype=jnp.int32)
        return self.Ks[self.g, idx, idx]

    def entry(self, i: jax.Array, j: jax.Array) -> jax.Array:
        return self.Ks[self.g, i, j]

    def matvec(self, v: jax.Array) -> jax.Array:
        # NOTE: gathers the full (l, l) matrix — under vmap this is the
        # per-lane copy the row/entry accessors avoid.  Only reached by
        # alpha0-without-G0 restarts, which the grid drivers never use
        # (they always carry the closed-form G0).
        return jnp.take(self.Ks, self.g, axis=0) @ v


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RBFKernel:
    """Gaussian kernel oracle ``k(x, z) = exp(-gamma ||x - z||^2)``.

    Rows are recomputed on demand (TPU adaptation of the LIBSVM kernel
    cache — see DESIGN.md §3).  ``sq_norms`` is precomputed once.
    """

    X: jax.Array          # (l, d)
    gamma: jax.Array      # scalar
    sq_norms: jax.Array   # (l,)

    @property
    def n(self) -> int:
        return self.X.shape[0]

    def row(self, i: jax.Array) -> jax.Array:
        xi = jnp.take(self.X, i, axis=0)
        ni = jnp.take(self.sq_norms, i)
        d2 = ni + self.sq_norms - 2.0 * (self.X @ xi)
        return jnp.exp(-self.gamma * jnp.maximum(d2, 0.0))

    def diag(self) -> jax.Array:
        return jnp.ones_like(self.sq_norms)

    def entry(self, i: jax.Array, j: jax.Array) -> jax.Array:
        # same expansion as row() so both paths are numerically consistent
        xi = jnp.take(self.X, i, axis=0)
        xj = jnp.take(self.X, j, axis=0)
        d2 = (jnp.take(self.sq_norms, i) + jnp.take(self.sq_norms, j)
              - 2.0 * jnp.dot(xj, xi))
        return jnp.exp(-self.gamma * jnp.maximum(d2, 0.0))

    def matvec(self, v: jax.Array, block: int = 256) -> jax.Array:
        """``K v`` without materializing K: row-blocked (O(block*l) memory,
        one fused (block, l) distance+exp+dot per step — warm starts)."""
        l, d = self.X.shape
        pad = (-l) % block
        Xp = jnp.pad(self.X, ((0, pad), (0, 0)))
        sp = jnp.pad(self.sq_norms, (0, pad))

        def blk(args):
            Xb, nb = args
            d2 = nb[:, None] + self.sq_norms[None, :] - 2.0 * (Xb @ self.X.T)
            return jnp.exp(-self.gamma * jnp.maximum(d2, 0.0)) @ v

        out = jax.lax.map(blk, (Xp.reshape(-1, block, d),
                                sp.reshape(-1, block)))
        return out.reshape(-1)[:l]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LinearKernel:
    """Linear kernel oracle ``k(x, z) = x . z``."""

    X: jax.Array  # (l, d)

    @property
    def n(self) -> int:
        return self.X.shape[0]

    def row(self, i: jax.Array) -> jax.Array:
        return self.X @ jnp.take(self.X, i, axis=0)

    def diag(self) -> jax.Array:
        return jnp.sum(self.X * self.X, axis=-1)

    def entry(self, i: jax.Array, j: jax.Array) -> jax.Array:
        return jnp.dot(jnp.take(self.X, i, axis=0), jnp.take(self.X, j, axis=0))

    def matvec(self, v: jax.Array) -> jax.Array:
        return self.X @ (self.X.T @ v)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DoubledKernel:
    """The ε-SVR doubled operator ``Q[k, k'] = K[k mod l, k' mod l]``.

    In the signed substitution ``a = (alpha+, -alpha-)`` the sign pattern
    of the classic ``(alpha+, alpha-)`` dual folds into the box, so all
    four l x l blocks of the 2l x 2l operator equal the base Gram ``K`` —
    a row of ``Q`` is the base row *tiled*, the diagonal is the base
    diagonal tiled, and a matvec contracts the two halves first.  Nothing
    of size 2l x 2l ever exists; ``base`` may itself be any oracle
    (precomputed, stacked-bank, RBF-on-the-fly).
    """

    base: object  # any kernel oracle from this module (pytree)

    @property
    def n(self) -> int:
        return 2 * self.base.n

    def row(self, i: jax.Array) -> jax.Array:
        r = self.base.row(i % self.base.n)
        return jnp.concatenate([r, r])

    def diag(self) -> jax.Array:
        d = self.base.diag()
        return jnp.concatenate([d, d])

    def entry(self, i: jax.Array, j: jax.Array) -> jax.Array:
        return self.base.entry(i % self.base.n, j % self.base.n)

    def matvec(self, v: jax.Array) -> jax.Array:
        m = self.base.matvec(v[: self.base.n] + v[self.base.n:])
        return jnp.concatenate([m, m])


def make_rbf(X: jax.Array, gamma) -> RBFKernel:
    X = jnp.asarray(X)
    return RBFKernel(X=X, gamma=jnp.asarray(gamma, X.dtype),
                     sq_norms=jnp.sum(X * X, axis=-1))


def materialize(kernel) -> jax.Array:
    """Dense Gram matrix from any oracle (tests / tiny problems only)."""
    idx = jnp.arange(kernel.n, dtype=jnp.int32)
    return jax.vmap(kernel.row)(idx)
