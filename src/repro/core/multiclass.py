"""One-vs-rest multiclass layer: batched label vectors, shared-kernel solves.

A k-class SVM in the one-vs-rest (OVR) reduction is k *independent* binary
QPs (eq. 1) that differ only in the sign pattern of ``y`` (and hence in the
box bounds ``[min(0, y_i C), max(0, y_i C)]``) — the Gram matrix is shared.
Because the PA-SMO iteration is O(1) beyond the kernel row, the whole stack
of solves batches under ``vmap``: one ``lax.while_loop`` advances all class
heads together, and with a :class:`~repro.core.qp.PrecomputedKernel` mapped
with ``in_axes=None`` the Gram work is done once per row of K — a gather per
class, not a recompute per class.

Conventions:

* ``y_idx``  — integer class indices, shape (l,), values in [0, k).
* ``Y``      — stacked signed label vectors, shape (k, l), rows in {-1, +1}.
* Batched results carry a leading class axis on every ``SolveResult`` leaf.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qp as qp_mod
from repro.core.solver import SolveResult, SolverConfig, solve


def class_index(y) -> Tuple[np.ndarray, np.ndarray]:
    """Map arbitrary label values to dense indices.

    Returns ``(classes, y_idx)`` where ``classes`` is the sorted unique label
    array and ``y_idx[i]`` is the position of ``y[i]`` in it.  Host-side
    (numpy): label vocabularies are data-dependent shapes, not trace-time
    values.
    """
    classes, y_idx = np.unique(np.asarray(y), return_inverse=True)
    return classes, y_idx.astype(np.int32)


def ovr_labels(y_idx, n_classes: int, dtype=jnp.float64) -> jax.Array:
    """Stacked one-vs-rest signed label vectors, shape (k, l).

    Row ``c`` is ``+1`` where ``y_idx == c`` and ``-1`` elsewhere — the
    label vector of the binary "class c vs rest" problem.
    """
    y_idx = jnp.asarray(y_idx)
    onehot = y_idx[None, :] == jnp.arange(n_classes, dtype=y_idx.dtype)[:, None]
    return jnp.where(onehot, 1.0, -1.0).astype(dtype)


def ovr_bounds(Y: jax.Array, C) -> qp_mod.Bounds:
    """Per-class box bounds: ``Bounds`` with (k, l) leaves.

    ``C`` may be a scalar (shared) or a (k,) vector (per-class budgets, e.g.
    to rebalance rare classes in the OVR reduction).
    """
    C = jnp.broadcast_to(jnp.asarray(C, Y.dtype), (Y.shape[0],))
    return qp_mod.make_bounds(Y, C[:, None])


@partial(jax.jit, static_argnames=("cfg",))
def solve_ovr(kernel, Y: jax.Array, C,
              cfg: SolverConfig = SolverConfig(),
              alpha0: Optional[jax.Array] = None,
              G0: Optional[jax.Array] = None) -> SolveResult:
    """Solve all one-vs-rest heads in one vmapped ``while_loop``.

    ``kernel`` is a single (unbatched) oracle shared across classes — it is
    mapped with ``in_axes=None``, so a precomputed Gram matrix is gathered,
    never recomputed, per class.  ``Y`` is (k, l); ``C`` is scalar, (k,)
    per-class, or (k, l) per-sample budgets (class-weighted SVC); optional
    ``alpha0``/``G0`` are (k, l) warm starts.  Returns a
    :class:`SolveResult` whose leaves carry a leading class axis.
    """
    Y = jnp.asarray(Y)
    k = Y.shape[0]
    C = jnp.asarray(C, Y.dtype)
    if C.ndim < 2:
        C = jnp.broadcast_to(C, (k,))
    if alpha0 is None:
        return jax.vmap(
            lambda y, c: solve(kernel, y, c, cfg),
            in_axes=(0, 0))(Y, C)
    return jax.vmap(
        lambda y, c, a0, g0: solve(kernel, y, c, cfg, alpha0=a0, G0=g0),
        in_axes=(0, 0, 0, 0))(Y, C, alpha0, G0)


def solve_ovr_fused(X, Y: jax.Array, C, gamma,
                    cfg: SolverConfig = SolverConfig(), *,
                    impl: str = "auto", block_l: int = 1024,
                    precompute: bool = False, mesh=None, devices=None,
                    telemetry=None):
    """Solve all one-vs-rest heads through the fused two-pass batched engine.

    Unlike :func:`solve_ovr` this consumes the raw ``X`` (l, d); every
    iteration advances the whole class stack through two batched kernel
    passes (:func:`repro.core.solver_fused.solve_fused_batched`).  With
    ``precompute=True`` on the jnp backend the single shared Gram matrix
    is built once and rows become gathers (CPU throughput mode); otherwise
    rows are recomputed from ``X`` and no Gram is ever materialized.
    ``C`` is scalar, (k,) per-class, or (k, l) per-sample budgets
    (class-weighted SVC); ``gamma`` is the shared RBF width.  Returns a
    :class:`~repro.core.solver_fused.FusedResult` with a
    leading class axis on every leaf.  Requires
    ``cfg.algorithm in ("smo", "pasmo")`` and ``plan_candidates == 1``.
    ``mesh``/``devices`` shard the class-head lanes over a device mesh
    (:mod:`repro.core.sharded_lanes`) — identical results, one while_loop
    per device slab.  ``telemetry`` (a static
    :class:`~repro.telemetry.ring.RingConfig`) turns on the fused
    engine's flight recorder; the return value becomes the
    ``(FusedResult, TelemetryRing)`` pair with class-leading ring leaves.
    """
    from repro.core.solver_fused import solve_fused_batched
    from repro.kernels import ops as kernel_ops
    X = jnp.asarray(X)
    Y = jnp.asarray(Y)
    bank_kw = {}
    if precompute and kernel_ops.resolve_impl(impl) == "jnp":
        K = kernel_ops.gram(X, gamma=gamma, impl=impl)
        bank_kw = dict(gram=K[None].astype(Y.dtype),
                       gram_idx=jnp.zeros((Y.shape[0],), jnp.int32))
    if mesh is not None or devices is not None:
        from repro.core.sharded_lanes import solve_fused_sharded
        return solve_fused_sharded(X, Y, C, gamma, cfg, mesh=mesh,
                                   devices=devices, impl=impl,
                                   block_l=block_l, telemetry=telemetry,
                                   **bank_kw)
    return solve_fused_batched(X, Y, C, gamma, cfg,
                               impl=impl, block_l=block_l,
                               telemetry=telemetry, **bank_kw)


def ovr_decision(Kq: jax.Array, alpha: jax.Array, b: jax.Array) -> jax.Array:
    """OVR decision scores for query cross-kernel ``Kq`` (m, l).

    ``alpha`` (k, l) carries the label signs (signed dual), ``b`` is (k,).
    Returns (m, k): one binary decision value per class head.
    """
    return Kq @ alpha.T + b[None, :]


def ovr_predict(Kq: jax.Array, alpha: jax.Array, b: jax.Array) -> jax.Array:
    """argmax-of-scores OVR prediction -> (m,) int32 class indices."""
    return jnp.argmax(ovr_decision(Kq, alpha, b), axis=-1).astype(jnp.int32)
