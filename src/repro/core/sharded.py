"""Distributed PA-SMO: the example dimension ℓ sharded over a mesh axis.

This is how SMO actually runs on a pod (DESIGN.md §3): the training set X,
the dual variables alpha and the gradient G live sharded over the ``data``
axis.  SMO's minimal working set is exactly what makes it distributable —
per iteration the collectives are:

  1. all_gather of P (value, index) candidates for the first-order i-pick,
  2. one psum broadcasting x_i plus O(1) scalars      (payload d + 3),
  3. all_gather of P j-candidates (WSS2 second-order),
  4. one psum broadcasting x_j plus O(1) scalars      (payload d + 3),
  5. one psum fetching O(1) gradient entries for planning / Alg. 3,
  6. one pmax/pmin pair for the KKT stopping gap      (payload 2).

Everything else — the two kernel-row blocks, the gradient update, the
masked reductions — is embarrassingly parallel over ℓ/P local rows.  All
O(1) cross terms (the ≤4x4 principal minor of K the paper's planning step
needs) are computed locally from *replicated* support-point vectors
(x_i, x_j and the previous working set's x's), so planning-ahead adds ZERO
extra collectives — the paper's O(1)-per-step property survives sharding.

RBF kernel only (the paper's experimental setting); the oracle diag is 1.
The padded tail (to make ℓ divisible by the axis size) gets L = U = 0 so it
can never enter a working set.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

if hasattr(jax, "shard_map"):  # jax >= 0.6 public API
    _shard_map = jax.shard_map
    _SHARD_MAP_CHECK = {"check_vma": False}
else:  # older jax: experimental namespace, check_rep spelling
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_CHECK = {"check_rep": False}

from repro.core.qp import TAU
from repro.core import step as step_mod
from repro.core.solver import SolverConfig


class ShardedResult(NamedTuple):
    alpha: jax.Array       # (l_padded,) sharded
    iterations: jax.Array
    objective: jax.Array
    kkt_gap: jax.Array
    converged: jax.Array
    n_planning: jax.Array
    b: jax.Array


def _pad_to(x, n, value=0.0):
    pad = n - x.shape[0]
    if pad == 0:
        return x
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths, constant_values=value)


def solve_sharded(X, y, C, gamma, mesh: Mesh, cfg: SolverConfig,
                  axis: str = "data") -> ShardedResult:
    """Solve the dual SVM QP with ℓ sharded over ``mesh[axis]``.

    Supports algorithm in {"smo", "pasmo"} with plan_candidates == 1.
    """
    assert cfg.algorithm in ("smo", "pasmo")
    assert cfg.plan_candidates == 1, "sharded path implements N=1"
    Pn = mesh.shape[axis]
    l, d = X.shape
    lp = ((l + Pn - 1) // Pn) * Pn
    X = _pad_to(jnp.asarray(X), lp)
    y = _pad_to(jnp.asarray(y), lp)  # padded labels 0 -> L = U = 0
    dtype = X.dtype
    C = jnp.asarray(C, dtype)
    gamma = jnp.asarray(gamma, dtype)
    eps = cfg.eps
    eta = cfg.eta
    planning = cfg.algorithm == "pasmo"

    nloc = lp // Pn

    def rbf_block(Xl, sql, xq):
        """Local kernel-row block k(x_q, X_local)."""
        d2 = jnp.dot(xq, xq) + sql - 2.0 * (Xl @ xq)
        return jnp.exp(-gamma * jnp.maximum(d2, 0.0))

    def local_solve(Xl, yl):
        me = jax.lax.axis_index(axis)
        offset = me * nloc
        gidx = offset + jnp.arange(nloc, dtype=jnp.int32)
        sql = jnp.sum(Xl * Xl, axis=-1)
        Ll = jnp.minimum(0.0, yl * C)
        Ul = jnp.maximum(0.0, yl * C)

        def fetch(vec, g):
            """Replicate vec[g] (global index) to all shards."""
            lidx = g % nloc
            mine = (g // nloc) == me
            return jax.lax.psum(
                jnp.where(mine, jnp.take(vec, lidx), 0.0), axis)

        def bcast_point(g, alpha):
            """Replicate (x_g, alpha_g, y_g) in one psum of (d+2,)."""
            lidx = g % nloc
            mine = (g // nloc) == me
            row = jnp.where(mine, jnp.take(Xl, lidx, axis=0),
                            jnp.zeros((d,), dtype))
            sc = jnp.where(mine,
                           jnp.stack([jnp.take(alpha, lidx),
                                      jnp.take(yl, lidx)]),
                           jnp.zeros((2,), dtype))
            out = jax.lax.psum(jnp.concatenate([row, sc]), axis)
            return out[:d], out[d], out[d + 1]

        def global_argmax(val_loc, idx_loc):
            vals = jax.lax.all_gather(val_loc, axis)   # (P,)
            idxs = jax.lax.all_gather(idx_loc.astype(jnp.int32), axis)
            w = jax.lax.argmax(vals, 0, jnp.int32)
            return jnp.take(idxs, w), jnp.take(vals, w)

        class Carry(NamedTuple):
            alpha: jax.Array
            G: jax.Array
            t: jax.Array
            done: jax.Array
            gap: jax.Array
            # previous / prev-prev working sets: global ids + replicated x
            pi: jax.Array
            pj: jax.Array
            qi: jax.Array
            qj: jax.Array
            x_pi: jax.Array
            x_pj: jax.Array
            x_qi: jax.Array
            x_qj: jax.Array
            n_hist: jax.Array
            p_smo: jax.Array
            prev_free: jax.Array
            prev_ratio_ok: jax.Array
            n_planning: jax.Array

        def body(c: Carry) -> Carry:
            alpha, G = c.alpha, c.G
            up = alpha < Ul
            dn = alpha > Ll

            # ---- i selection (first-order part of WSS2) -------------------
            vi = jnp.where(up, G, -jnp.inf)
            li = jax.lax.argmax(vi, 0, jnp.int32)
            i_g, g_i = global_argmax(jnp.take(vi, li), offset + li)
            x_i, a_i, y_i = bcast_point(i_g, alpha)
            L_i = jnp.minimum(0.0, y_i * C)
            U_i = jnp.maximum(0.0, y_i * C)
            k_i = rbf_block(Xl, sql, x_i)

            # ---- j selection ----------------------------------------------
            use_exact = planning & (~c.p_smo) & (~c.prev_ratio_ok)
            lvec = g_i - G
            qvec = jnp.maximum(1.0 - 2.0 * k_i + 1.0, TAU)  # RBF diag = 1
            g_tilde = 0.5 * lvec * lvec / qvec
            lo_v = jnp.maximum(L_i - a_i, alpha - Ul)
            hi_v = jnp.minimum(U_i - a_i, alpha - Ll)
            mu_v = jnp.clip(lvec / qvec, lo_v, hi_v)
            g_exact = lvec * mu_v - 0.5 * qvec * mu_v * mu_v
            gains = jnp.where(use_exact, g_exact, g_tilde)
            cand = dn & (lvec > 0) & (gidx != i_g)
            vj = jnp.where(cand, gains, -jnp.inf)
            lj = jax.lax.argmax(vj, 0, jnp.int32)
            j_g, best_gain = global_argmax(jnp.take(vj, lj), offset + lj)

            # ---- Alg. 3 extra candidate B^(t-2) ----------------------------
            # O(1) gradient entries for the candidate and for planning, one
            # fused psum: [G_pi, G_pj, G_qi, G_qj, a_qi, a_qj]
            fetch_idx = jnp.stack([c.pi, c.pj, c.qi, c.qj])
            lidx = fetch_idx % nloc
            mine = (fetch_idx // nloc) == me
            gvals = jax.lax.psum(
                jnp.where(mine, jnp.take(G, lidx), 0.0), axis)
            avals = jax.lax.psum(
                jnp.where(mine[2:], jnp.take(alpha, lidx[2:]), 0.0), axis)
            G_pi, G_pj, G_qi, G_qj = gvals[0], gvals[1], gvals[2], gvals[3]
            a_qi, a_qj = avals[0], avals[1]

            i_sel, j_sel = i_g, j_g
            if planning:
                y_qi = fetch(yl, c.qi)
                y_qj = fetch(yl, c.qj)
                K_qq = jnp.exp(-gamma * jnp.maximum(
                    jnp.sum((c.x_qi - c.x_qj) ** 2), 0.0))
                l_q = G_qi - G_qj
                q_q = jnp.maximum(2.0 - 2.0 * K_qq, TAU)
                lo_q = jnp.maximum(jnp.minimum(0.0, y_qi * C) - a_qi,
                                   a_qj - jnp.maximum(0.0, y_qj * C))
                hi_q = jnp.minimum(jnp.maximum(0.0, y_qi * C) - a_qi,
                                   a_qj - jnp.minimum(0.0, y_qj * C))
                mu_q = jnp.clip(l_q / q_q, lo_q, hi_q)
                cg_exact = l_q * mu_q - 0.5 * q_q * mu_q * mu_q
                cg_tilde = 0.5 * l_q * l_q / q_q
                cg = jnp.where(use_exact, cg_exact, cg_tilde)
                adm = ((a_qi < jnp.maximum(0.0, y_qi * C))
                       & (a_qj > jnp.minimum(0.0, y_qj * C))
                       & (l_q > 0) & (c.qi != c.qj) & (c.n_hist > 1))
                take = (~c.p_smo) & adm & (cg > best_gain)
                i_sel = jnp.where(take, c.qi, i_g)
                j_sel = jnp.where(take, c.qj, j_g)
            else:
                take = jnp.asarray(False)

            # replicated data of the selected pair
            x_i2, a_i2, y_i2 = bcast_point(i_sel, alpha)
            x_j2, a_j2, y_j2 = bcast_point(j_sel, alpha)
            k_i2 = rbf_block(Xl, sql, x_i2)
            k_j2 = rbf_block(Xl, sql, x_j2)
            G_i2 = jnp.where(take, G_qi, g_i)
            G_j2 = fetch(G, j_sel)

            # ---- step (Alg. 4 / eq. 2) -------------------------------------
            L_i2 = jnp.minimum(0.0, y_i2 * C)
            U_i2 = jnp.maximum(0.0, y_i2 * C)
            L_j2 = jnp.minimum(0.0, y_j2 * C)
            U_j2 = jnp.maximum(0.0, y_j2 * C)
            lw = G_i2 - G_j2
            K_ij = jnp.exp(-gamma * jnp.maximum(
                jnp.sum((x_i2 - x_j2) ** 2), 0.0))
            q11 = jnp.maximum(2.0 - 2.0 * K_ij, TAU)
            sb = step_mod.step_bounds(a_i2, a_j2, L_i2, U_i2, L_j2, U_j2)
            mu_star = lw / q11
            mu_smo, free_smo = step_mod.smo_step(lw, q11, sb)

            do_plan = jnp.asarray(False)
            mu_plan = mu_smo
            ratio_ok = c.prev_ratio_ok
            if planning:
                # all 2x2 cross terms local thanks to replicated x vectors
                def k(xa, xb):
                    return jnp.exp(-gamma * jnp.maximum(
                        jnp.sum((xa - xb) ** 2), 0.0))

                w2 = G_pi - G_pj
                q22 = jnp.maximum(2.0 - 2.0 * k(c.x_pi, c.x_pj), TAU)
                q12 = (k(x_i2, c.x_pi) - k(x_i2, c.x_pj)
                       - k(x_j2, c.x_pi) + k(x_j2, c.x_pj))
                terms = step_mod.PlanningTerms(w1=lw, w2=w2, Q11=q11,
                                               Q22=q22, Q12=q12)
                mu1, okdet = step_mod.planning_step(terms)
                mu2 = step_mod.planned_second_step(mu1, terms)
                interior1 = (sb.lo < mu1) & (mu1 < sb.hi)
                y_pi = fetch(yl, c.pi)
                y_pj = fetch(yl, c.pj)
                a_pi = fetch(alpha, c.pi) + mu1 * (
                    (c.pi == i_sel).astype(dtype)
                    - (c.pi == j_sel).astype(dtype))
                a_pj = fetch(alpha, c.pj) + mu1 * (
                    (c.pj == i_sel).astype(dtype)
                    - (c.pj == j_sel).astype(dtype))
                sb2 = step_mod.step_bounds(
                    a_pi, a_pj,
                    jnp.minimum(0.0, y_pi * C), jnp.maximum(0.0, y_pi * C),
                    jnp.minimum(0.0, y_pj * C), jnp.maximum(0.0, y_pj * C))
                interior2 = (sb2.lo < mu2) & (mu2 < sb2.hi)
                feasible = okdet & interior1 & interior2 & (c.n_hist > 0)
                do_plan = c.prev_free & feasible
                mu_plan = jnp.where(do_plan, mu1, mu_smo)
                ratio = mu1 / jnp.where(jnp.abs(mu_star) > 0, mu_star, 1.0)
                ratio_ok = jnp.where(do_plan,
                                     (ratio >= 1.0 - eta)
                                     & (ratio <= 1.0 + eta),
                                     c.prev_ratio_ok)

            mu = jnp.where(do_plan, mu_plan, mu_smo)

            # ---- update -----------------------------------------------------
            sel_vec = ((gidx == i_sel).astype(dtype)
                       - (gidx == j_sel).astype(dtype))
            alpha_new = alpha + mu * sel_vec
            G_new = G - mu * (k_i2 - k_j2)

            # ---- stopping ---------------------------------------------------
            up2 = alpha_new < Ul
            dn2 = alpha_new > Ll
            g_up = jax.lax.pmax(
                jnp.max(jnp.where(up2, G_new, -jnp.inf)), axis)
            g_dn = -jax.lax.pmax(
                jnp.max(jnp.where(dn2, -G_new, -jnp.inf)), axis)
            gap = g_up - g_dn

            return Carry(
                alpha=alpha_new, G=G_new, t=c.t + 1, done=gap <= eps,
                gap=gap,
                pi=i_sel, pj=j_sel, qi=c.pi, qj=c.pj,
                x_pi=x_i2, x_pj=x_j2, x_qi=c.x_pi, x_qj=c.x_pj,
                n_hist=jnp.minimum(c.n_hist + 1, 2),
                p_smo=~do_plan,
                prev_free=(~do_plan) & free_smo,
                prev_ratio_ok=ratio_ok,
                n_planning=c.n_planning + do_plan.astype(jnp.int32))

        alpha0 = jnp.zeros((nloc,), dtype)
        G0 = yl
        up0 = alpha0 < Ul
        dn0 = alpha0 > Ll
        g_up0 = jax.lax.pmax(jnp.max(jnp.where(up0, G0, -jnp.inf)), axis)
        g_dn0 = -jax.lax.pmax(jnp.max(jnp.where(dn0, -G0, -jnp.inf)), axis)
        zero_i = jnp.asarray(0, jnp.int32)
        zd = jnp.zeros((d,), dtype)
        c0 = Carry(alpha=alpha0, G=G0, t=zero_i,
                   done=(g_up0 - g_dn0) <= eps, gap=g_up0 - g_dn0,
                   pi=zero_i, pj=zero_i, qi=zero_i, qj=zero_i,
                   x_pi=zd, x_pj=zd, x_qi=zd, x_qj=zd,
                   n_hist=zero_i,
                   p_smo=jnp.asarray(True), prev_free=jnp.asarray(False),
                   prev_ratio_ok=jnp.asarray(True),
                   n_planning=zero_i)

        c = jax.lax.while_loop(
            lambda c: (~c.done) & (c.t < cfg.max_iter), body, c0)

        # finalize: objective f = 1/2 (y.a + G.a) (local dot + psum)
        obj = jax.lax.psum(0.5 * (jnp.dot(yl, c.alpha)
                                  + jnp.dot(c.G, c.alpha)), axis)
        up = c.alpha < Ul
        dn = c.alpha > Ll
        g_up = jax.lax.pmax(jnp.max(jnp.where(up, c.G, -jnp.inf)), axis)
        g_dn = -jax.lax.pmax(jnp.max(jnp.where(dn, -c.G, -jnp.inf)), axis)
        b = 0.5 * (g_up + g_dn)
        return (c.alpha, c.t, obj, c.gap, c.done, c.n_planning, b)

    spec_l = P(axis)
    out = jax.jit(_shard_map(
        local_solve, mesh=mesh,
        in_specs=(P(axis, None), spec_l),
        out_specs=(spec_l, P(), P(), P(), P(), P(), P()),
        **_SHARD_MAP_CHECK))(X, y)
    return ShardedResult(*out)
