"""Working-set selection policies.

* ``select_mvp``      — first-order most-violating pair (Keerthi et al.).
* ``select_wss2``     — second-order selection of Fan et al. (eq. 3), the
                        LIBSVM 2.8x default and the paper's baseline.
* ``select_wss2_exact`` — same ``i`` rule but ``j`` maximizes the *exact*
                        (clipped) SMO gain ``g`` — Alg. 3's guard branch.
* ``alg3_select``     — the full convergence-preserving selection of Alg. 3,
                        including the ``B^(t-2)`` extra candidate.

All selectors are O(l), fully vectorized, mask-based (soft shrinking), and
work under jit.  The j-reduction consumes one kernel row ``K_i`` — exactly
the quantity the Pallas kernels in ``repro.kernels`` produce fused with the
gradient update.

Like :mod:`repro.core.step`, selection is dual-generic: it reads only
``G``, the box masks and kernel entries, so the general
:class:`repro.core.qp.DualQP` instances (ε-SVR doubled coordinates,
one-class) select through the identical code path.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.qp import TAU, Bounds
from repro.core import step as step_mod

NEG_INF = -jnp.inf


class Selection(NamedTuple):
    i: jax.Array          # int32 ()
    j: jax.Array          # int32 ()
    gain: jax.Array       # selection objective value of (i, j)
    violation: jax.Array  # first-order KKT gap psi(a) (for stopping)


def _masked_argmax(values: jax.Array, mask: jax.Array):
    v = jnp.where(mask, values, NEG_INF)
    idx = jax.lax.argmax(v, 0, jnp.int32)
    return idx, v[idx]


def select_i(G: jax.Array, up: jax.Array):
    """``i = argmax{G_n | n in I_up}`` (shared by all second-order rules)."""
    return _masked_argmax(G, up)


def pair_curvature(K_i: jax.Array, K_ii, diag: jax.Array):
    """``Q_(i,n),(i,n) = K_ii - 2 K_in + K_nn`` for all n, tau-guarded."""
    return jnp.maximum(K_ii - 2.0 * K_i + diag, TAU)


def select_wss2(G: jax.Array, K_i: jax.Array, diag: jax.Array,
                up: jax.Array, down: jax.Array,
                i: Optional[jax.Array] = None,
                g_i: Optional[jax.Array] = None) -> Selection:
    """Second-order selection (eq. 3): maximize the Newton gain bound g~.

    ``K_i`` is the kernel row of the selected ``i``; pass (i, g_i) to reuse a
    precomputed first index.
    """
    if i is None:
        i, g_i = select_i(G, up)
    l = g_i - G                                  # l_(i,n) for every candidate n
    q = pair_curvature(K_i, jnp.take(diag, i), diag)
    gains = 0.5 * l * l / q
    cand = down & (l > 0) & (jnp.arange(G.shape[0],
                                        dtype=jnp.int32) != i)
    j, gain = _masked_argmax(gains, cand)
    g_dn = jnp.min(jnp.where(down, G, jnp.inf))
    return Selection(i=i.astype(jnp.int32), j=j.astype(jnp.int32),
                     gain=gain, violation=g_i - g_dn)


def select_wss2_exact(G: jax.Array, K_i: jax.Array, diag: jax.Array,
                      alpha: jax.Array, bounds: Bounds,
                      up: jax.Array, down: jax.Array,
                      i: Optional[jax.Array] = None,
                      g_i: Optional[jax.Array] = None) -> Selection:
    """Alg. 3 exact-gain branch: ``j`` maximizes the clipped SMO gain ``g``.

    The exact gain needs the per-candidate feasible interval, i.e. the box
    state of both i and every candidate n.
    """
    if i is None:
        i, g_i = select_i(G, up)
    n_idx = jnp.arange(G.shape[0], dtype=jnp.int32)
    l = g_i - G
    q = pair_curvature(K_i, jnp.take(diag, i), diag)
    ai = jnp.take(alpha, i)
    Li, Ui = jnp.take(bounds.lower, i), jnp.take(bounds.upper, i)
    sb = step_mod.step_bounds(ai, alpha, Li, Ui, bounds.lower, bounds.upper)
    mu = step_mod.clip_step(l / q, sb)
    gains = step_mod.gain_of_step(mu, l, q)
    cand = down & (l > 0) & (n_idx != i)
    j, gain = _masked_argmax(gains, cand)
    g_dn = jnp.min(jnp.where(down, G, jnp.inf))
    return Selection(i=i.astype(jnp.int32), j=j.astype(jnp.int32),
                     gain=gain, violation=g_i - g_dn)


def select_mvp(G: jax.Array, up: jax.Array, down: jax.Array) -> Selection:
    """First-order most-violating pair (for ablations)."""
    i, g_i = _masked_argmax(G, up)
    j, neg_g_j = _masked_argmax(-G, down)
    return Selection(i=i.astype(jnp.int32), j=j.astype(jnp.int32),
                     gain=g_i + neg_g_j, violation=g_i + neg_g_j)


# ---------------------------------------------------------------------------
# Candidate working-set evaluation (for the B^(t-2) extra candidate and the
# multiple-planning-ahead variant §7.4)
# ---------------------------------------------------------------------------


def candidate_newton_gain(B_i, B_j, G, Kii, Kij, Kjj, up, down):
    """g~ of an explicit candidate tuple (B_i, B_j); -inf if infeasible.

    Needs only the 2x2 principal minor — O(1) given the kernel entries.
    """
    l = jnp.take(G, B_i) - jnp.take(G, B_j)
    q = jnp.maximum(Kii - 2.0 * Kij + Kjj, TAU)
    ok = jnp.take(up, B_i) & jnp.take(down, B_j) & (l > 0) & (B_i != B_j)
    return jnp.where(ok, 0.5 * l * l / q, NEG_INF)


def candidate_exact_gain(B_i, B_j, G, Kii, Kij, Kjj, alpha, bounds, up, down):
    """Exact clipped gain g of an explicit candidate tuple; -inf if infeasible."""
    l = jnp.take(G, B_i) - jnp.take(G, B_j)
    q = jnp.maximum(Kii - 2.0 * Kij + Kjj, TAU)
    sb = step_mod.step_bounds(
        jnp.take(alpha, B_i), jnp.take(alpha, B_j),
        jnp.take(bounds.lower, B_i), jnp.take(bounds.upper, B_i),
        jnp.take(bounds.lower, B_j), jnp.take(bounds.upper, B_j))
    mu = step_mod.clip_step(l / q, sb)
    g = step_mod.gain_of_step(mu, l, q)
    ok = jnp.take(up, B_i) & jnp.take(down, B_j) & (l > 0) & (B_i != B_j)
    return jnp.where(ok, g, NEG_INF)
