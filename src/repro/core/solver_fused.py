"""Fused two-pass PA-SMO solver (the beyond-paper optimized iteration).

The standard solver (:mod:`repro.core.solver`) mirrors LIBSVM's structure:
row fetch, selection, second row fetch, update, stopping scan — ~4 logical
passes over O(l) state per iteration.  This solver restructures the
iteration into exactly the two fused passes implemented by the Pallas
kernels in :mod:`repro.kernels`:

  pass A: k_i  + second-order j-selection           (reads X, G, masks)
  pass B: k_j (VMEM-only) + gradient update + next i-pick + KKT gap ends

All O(1) work in between — the truncated Newton step, the planning-ahead
step size (eq. 8), the ≤4x4 kernel minor, Alg. 3's B^(t-2) candidate —
runs on scalars, with single-row RBF evaluations costing O(d).

Semantics are identical to ``solver.solve`` with an RBF oracle (same
Algorithms 3/4/5); trajectories agree modulo floating-point reassociation.
``impl`` selects pallas/interpret/jnp exactly as in ``repro.kernels.ops``.

:func:`solve_fused_batched_qp` runs a whole *batch of lanes* — one lane
per *general* dual QP (:mod:`repro.core.qp`: per-lane linear term ``P``
and box ``L``/``U``; classification, ε-SVR with ``doubled=True`` lanes
over a shared base ``X``, one-class via feasible warm starts) — through
ONE ``lax.while_loop`` whose
body is TWO batched kernel launches plus O(B) per-lane algebra.  The lane
batching differs from the single-lane shape in one structural way: pass A
returns only the selection, and pass B recomputes both rows k_i/k_j
against the shared X tile.  That removes the k_i HBM round-trip and —
crucially — the data-dependent pass-A relaunch when Alg. 3's B^(t-2)
candidate wins, which has no batched equivalent.  Converged lanes are
frozen *in kernel*: their step size is forced to 0, so pass B's update is
a bitwise no-op on G and the loop condition is simply "any lane active".
"""

from __future__ import annotations

import dataclasses
from contextlib import nullcontext
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qp as qp_mod
from repro.core import step as step_mod
from repro.core.qp import TAU
from repro.core.solver import DEFAULT_SHRINK_EVERY, SolverConfig
from repro.kernels import ops
from repro.kernels import row_source
from repro.telemetry.ring import (RingConfig, TelemetryRing, ring_init,
                                  ring_update)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FusedResult:
    alpha: jax.Array
    b: jax.Array
    G: jax.Array
    iterations: jax.Array
    objective: jax.Array
    kkt_gap: jax.Array
    converged: jax.Array
    n_planning: jax.Array
    # number of unshrink events (a lane's masked problem looked solved but
    # the full KKT check failed, forcing reactivation); 0 when shrinking is
    # off or never triggered a reconstruction
    n_unshrink: jax.Array


class _State(NamedTuple):
    alpha: jax.Array
    G: jax.Array
    i: jax.Array        # next working-set first index (from pass B)
    g_i: jax.Array      # G[i] == max gradient over I_up
    gap: jax.Array
    t: jax.Array
    done: jax.Array
    pi: jax.Array
    pj: jax.Array
    qi: jax.Array
    qj: jax.Array
    n_hist: jax.Array
    p_smo: jax.Array
    prev_free: jax.Array
    prev_ratio_ok: jax.Array
    n_planning: jax.Array


@partial(jax.jit, static_argnames=("cfg", "impl", "block_l"))
def solve_fused(X, y, C, gamma, cfg: SolverConfig = SolverConfig(),
                *, impl: str = "auto", block_l: int = 1024) -> FusedResult:
    assert cfg.algorithm in ("smo", "pasmo")
    assert cfg.plan_candidates == 1
    assert cfg.step == "plain", \
        "step='conjugate' is a lane-batched mode (solve_fused_batched_qp)"
    assert cfg.wss == "wss2", \
        "the fused passes hardcode WSS2 selection (use the standard solver)"
    assert not (cfg.record_trace or cfg.record_steps), \
        "the fused solver does not record traces/steps"
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    dtype = y.dtype
    n = y.shape[0]
    C = jnp.asarray(C, dtype)
    gamma = jnp.asarray(gamma, dtype)
    L = jnp.minimum(0.0, y * C)
    U = jnp.maximum(0.0, y * C)
    sqn = jnp.sum(X * X, axis=-1)
    eps = cfg.eps
    eta = cfg.eta
    planning = cfg.algorithm == "pasmo"

    def entry(a, b):
        """O(d) single RBF kernel entry."""
        d2 = (jnp.take(sqn, a) + jnp.take(sqn, b)
              - 2.0 * jnp.dot(jnp.take(X, b, axis=0), jnp.take(X, a, axis=0)))
        return jnp.exp(-gamma * jnp.maximum(d2, 0.0))

    def pass_a(G, alpha, i, g_i, use_exact):
        return ops.rbf_row_wss(
            X, sqn, G, alpha, L, U, jnp.take(X, i, axis=0),
            jnp.take(alpha, i), jnp.take(L, i), jnp.take(U, i), g_i,
            i, use_exact, gamma, impl=impl, block_l=block_l)

    def body(s: _State) -> _State:
        alpha, G = s.alpha, s.G
        use_exact = jnp.asarray(planning) & (~s.p_smo) & (~s.prev_ratio_ok)

        # ---- pass A: row k_i + j-selection ---------------------------------
        k_i, j0, gain0 = pass_a(G, alpha, s.i, s.g_i, use_exact)

        # ---- Alg. 3 extra candidate B^(t-2) (O(d)) -------------------------
        if planning:
            K_qq = entry(s.qi, s.qj)
            G_qi = jnp.take(G, s.qi)
            G_qj = jnp.take(G, s.qj)
            l_q = G_qi - G_qj
            q_q = jnp.maximum(2.0 - 2.0 * K_qq, TAU)
            a_qi = jnp.take(alpha, s.qi)
            a_qj = jnp.take(alpha, s.qj)
            sb_q = step_mod.step_bounds(
                a_qi, a_qj, jnp.take(L, s.qi), jnp.take(U, s.qi),
                jnp.take(L, s.qj), jnp.take(U, s.qj))
            mu_q = step_mod.clip_step(l_q / q_q, sb_q)
            cg_exact = step_mod.gain_of_step(mu_q, l_q, q_q)
            cg_tilde = 0.5 * l_q * l_q / q_q
            cg = jnp.where(use_exact, cg_exact, cg_tilde)
            adm = ((a_qi < jnp.take(U, s.qi)) & (a_qj > jnp.take(L, s.qj))
                   & (l_q > 0) & (s.qi != s.qj) & (s.n_hist > 1))
            take = (~s.p_smo) & adm & (cg > gain0)
            i_sel = jnp.where(take, s.qi, s.i)
            j_sel = jnp.where(take, s.qj, j0)
            g_i_sel = jnp.where(take, G_qi, s.g_i)
            # candidate won: the row belongs to qi — recompute pass A
            k_i = jax.lax.cond(
                take,
                lambda: pass_a(G, alpha, s.qi, G_qi, use_exact)[0],
                lambda: k_i)
        else:
            i_sel, j_sel, g_i_sel = s.i, j0, s.g_i

        # ---- O(1) step computation ----------------------------------------
        lw = g_i_sel - jnp.take(G, j_sel)
        K_ij = jnp.take(k_i, j_sel)
        q11 = jnp.maximum(2.0 - 2.0 * K_ij, TAU)
        sb = step_mod.step_bounds(
            jnp.take(alpha, i_sel), jnp.take(alpha, j_sel),
            jnp.take(L, i_sel), jnp.take(U, i_sel),
            jnp.take(L, j_sel), jnp.take(U, j_sel))
        mu_star = lw / q11
        mu_smo, free_smo = step_mod.smo_step(lw, q11, sb)

        do_plan = jnp.asarray(False)
        mu_plan = mu_smo
        ratio_ok = s.prev_ratio_ok
        if planning:
            w2 = jnp.take(G, s.pi) - jnp.take(G, s.pj)
            q22 = jnp.maximum(2.0 - 2.0 * entry(s.pi, s.pj), TAU)
            q12 = (jnp.take(k_i, s.pi) - jnp.take(k_i, s.pj)
                   - entry(j_sel, s.pi) + entry(j_sel, s.pj))
            terms = step_mod.PlanningTerms(w1=lw, w2=w2, Q11=q11, Q22=q22,
                                           Q12=q12)
            mu1, okdet = step_mod.planning_step(terms)
            mu2 = step_mod.planned_second_step(mu1, terms)
            interior1 = (sb.lo < mu1) & (mu1 < sb.hi)
            d_pi = ((s.pi == i_sel).astype(dtype)
                    - (s.pi == j_sel).astype(dtype))
            d_pj = ((s.pj == i_sel).astype(dtype)
                    - (s.pj == j_sel).astype(dtype))
            sb2 = step_mod.step_bounds(
                jnp.take(alpha, s.pi) + mu1 * d_pi,
                jnp.take(alpha, s.pj) + mu1 * d_pj,
                jnp.take(L, s.pi), jnp.take(U, s.pi),
                jnp.take(L, s.pj), jnp.take(U, s.pj))
            interior2 = (sb2.lo < mu2) & (mu2 < sb2.hi)
            feasible = okdet & interior1 & interior2 & (s.n_hist > 0)
            do_plan = s.prev_free & feasible
            mu_plan = jnp.where(do_plan, mu1, mu_smo)
            ratio = mu1 / jnp.where(jnp.abs(mu_star) > 0, mu_star, 1.0)
            ratio_ok = jnp.where(do_plan,
                                 (ratio >= 1.0 - eta) & (ratio <= 1.0 + eta),
                                 s.prev_ratio_ok)

        mu = jnp.where(do_plan, mu_plan, mu_smo)
        alpha_new = alpha.at[i_sel].add(mu).at[j_sel].add(-mu)

        # ---- pass B: update + next i + gap ---------------------------------
        G_new, i_next, g_i_next, g_dn = ops.rbf_update_wss(
            X, sqn, G, k_i, alpha_new, L, U, jnp.take(X, j_sel, axis=0),
            mu, gamma, impl=impl, block_l=block_l)
        gap = qp_mod.finite_gap(g_i_next - g_dn)

        return _State(
            alpha=alpha_new, G=G_new, i=i_next.astype(jnp.int32),
            g_i=g_i_next, gap=gap, t=s.t + 1, done=gap <= eps,
            pi=i_sel.astype(jnp.int32), pj=j_sel.astype(jnp.int32),
            qi=s.pi, qj=s.pj,
            n_hist=jnp.minimum(s.n_hist + 1, 2),
            p_smo=~do_plan, prev_free=(~do_plan) & free_smo,
            prev_ratio_ok=ratio_ok,
            n_planning=s.n_planning + do_plan.astype(jnp.int32))

    # ---- init ---------------------------------------------------------------
    alpha0 = jnp.zeros_like(y)
    G0 = y
    up0 = alpha0 < U
    dn0 = alpha0 > L
    v_up = jnp.where(up0, G0, -jnp.inf)
    i0 = jax.lax.argmax(v_up, 0, jnp.int32)
    g_i0 = v_up[i0]
    gap0 = qp_mod.finite_gap(g_i0 - jnp.min(jnp.where(dn0, G0, jnp.inf)))
    z = jnp.asarray(0, jnp.int32)
    s0 = _State(alpha=alpha0, G=G0, i=i0, g_i=g_i0, gap=gap0, t=z,
                done=gap0 <= eps, pi=z, pj=z, qi=z, qj=z, n_hist=z,
                p_smo=jnp.asarray(True), prev_free=jnp.asarray(False),
                prev_ratio_ok=jnp.asarray(True), n_planning=z)

    s = jax.lax.while_loop(lambda s: (~s.done) & (s.t < cfg.max_iter),
                           body, s0)

    up = s.alpha < U
    dn = s.alpha > L
    g_up = jnp.max(jnp.where(up, s.G, -jnp.inf))
    g_dn = jnp.min(jnp.where(dn, s.G, jnp.inf))
    return FusedResult(
        alpha=s.alpha, b=qp_mod.safe_bias(g_up, g_dn), G=s.G, iterations=s.t,
        objective=0.5 * (jnp.dot(y, s.alpha) + jnp.dot(s.G, s.alpha)),
        kkt_gap=s.gap, converged=s.done, n_planning=s.n_planning,
        n_unshrink=jnp.asarray(0, jnp.int32))


# ---------------------------------------------------------------------------
# Lane-batched fused solver
# ---------------------------------------------------------------------------


class _BatchState(NamedTuple):
    alpha: jax.Array          # (B, l)
    G: jax.Array              # (B, l)
    i: jax.Array              # (B,) next working-set first index (pass B)
    g_i: jax.Array            # (B,) G[i] == max gradient over I_up
    gap: jax.Array            # (B,)
    t: jax.Array              # () global iteration counter
    iters: jax.Array          # (B,) per-lane iterations until convergence
    done: jax.Array           # (B,)
    pi: jax.Array             # (B,) planning history B^(t-1)
    pj: jax.Array
    qi: jax.Array             # (B,) planning history B^(t-2)
    qj: jax.Array
    n_hist: jax.Array         # (B,)
    p_smo: jax.Array          # (B,)
    prev_free: jax.Array      # (B,)
    prev_ratio_ok: jax.Array  # (B,)
    n_planning: jax.Array     # (B,)
    act: jax.Array            # (B, n) bool active set ((B, 1) dummy when
                              # shrinking is off)
    n_unshrink: jax.Array     # (B,) unshrink (reactivation) events


class _ConjState(NamedTuple):
    """Per-lane Conjugate-SMO carry (``cfg.step == "conjugate"`` only).

    Rides the while_loop carry *next to* the batch state, exactly like the
    telemetry ring: with ``step="plain"`` it does not exist, so the plain
    engine's traced jaxpr stays byte-identical to the pre-conjugate
    goldens under ``tests/golden/``.
    """

    u: jax.Array    # (B, n) Q (e_pi - e_pj): previous direction's Q-product
                    # (pass B's in-VMEM row difference k_i - k_j)
    ok: jax.Array   # (B,) direction valid (reset on clip / shrink events)


def _take_lane(M, idx):
    """Per-lane gather: M (B, l), idx (B,) -> (B,)."""
    return jnp.take_along_axis(M, idx[:, None], axis=1)[:, 0]


@partial(jax.jit, static_argnames=("cfg", "impl", "block_l", "doubled",
                                   "shrinking", "telemetry"))
def solve_fused_batched_qp(X, P, L, U, gamma,
                           cfg: SolverConfig = SolverConfig(),
                           *, impl: str = "auto", block_l: int = 1024,
                           alpha0=None, G0=None, gram=None, gram_idx=None,
                           doubled: bool = False,
                           shrinking: bool = False,
                           telemetry: RingConfig | None = None):
    """Solve a batch of B *general* dual QPs over shared ``X`` in ONE
    while_loop: per-lane linear term ``P`` (B, n), per-coordinate box
    ``L``/``U`` (B, n), per-lane RBF ``gamma`` (scalar or (B,)).

    This is the general-dual core behind :func:`solve_fused_batched`
    (classification), the ε-SVR lanes (``doubled=True``) and the one-class
    lanes (``P = 0``, warm ``alpha0``/``G0`` since 0 is infeasible there).

    ``doubled=True`` runs the 2l-variable ε-SVR operator: ``X`` stays the
    base (l, d) matrix while the lane state is (B, 2l); kernel rows are
    base rows tiled (:mod:`repro.kernels` ``dup``), Gram-bank entries
    index ``k mod l`` — the 2l x 2l matrix never exists anywhere.

    Optional (B, n) ``alpha0``/``G0`` warm starts must come as a pair.
    Per iteration the body launches the batched pass A (selection) and
    pass B (both-rows + update + stopping scan) kernels; all remaining
    algebra — steps, planning, Alg. 3 candidates — is O(B) vector math
    plus O(B d) single-entry kernel evaluations.  Converged lanes freeze
    in-kernel: mu is forced to 0, so the update pass leaves their state
    bitwise unchanged while the loop runs until every lane is done (or
    ``cfg.max_iter``).  The returned :class:`FusedResult` leaves carry a
    leading lane axis; ``iterations`` counts per-lane iterations *until
    that lane converged*.

    Two row sources (:mod:`repro.kernels.row_source`):

    * default — rows are recomputed from ``X`` inside the kernels (the
      accelerator memory mode: O(B n) state, no Gram ever materialized;
      ``impl`` picks pallas/interpret/jnp as in :mod:`repro.kernels.ops`;
      with ``doubled=True`` the kernels read the base row tile once per
      variable half — the matmuls never widen past l).
    * ``gram``/``gram_idx`` — a shared (n_stack, l, l) *base* Gram bank
      plus the per-lane stack index: rows become gathers and the exp work
      is paid once per distinct gamma instead of per iteration.  This is
      the CPU throughput mode (it mirrors the vmapped engine's memory
      layout); ``impl`` applies here too — ``"jnp"`` runs the selection /
      update algebra as XLA-fused jnp, ``"interpret"``/``"pallas"`` route
      the gathered rows through the rows-variant Pallas kernels.  Lanes
      sharing a gamma index the same bank entry — no per-lane Gram copies.

    ``shrinking=True`` enables LIBSVM-style *soft* active-set shrinking:
    every ``cfg.shrink_every`` iterations (default
    :data:`~repro.core.solver.DEFAULT_SHRINK_EVERY`) bound-pinned
    variables that cannot belong to any violating pair are masked out of
    the pass A/B scans via a per-lane (B, n) active mask threaded through
    the kernels.  The gradient update itself is never masked, so G stays
    exact everywhere and unshrinking is free: a lane whose *masked* gap
    reaches ``eps`` with a partial mask is reactivated in-loop (counted in
    ``FusedResult.n_unshrink``) and only declared converged once the gap
    over the FULL active set passes the check — objectives are identical
    to the unshrunk engine up to selection-order float reassociation.
    Soft shrinking keeps the scans O(n) (masked lanes still ride through
    the kernels); the wall-clock win on CPU/host comes from
    :func:`solve_fused_chunked_qp`, which periodically *compacts* rows and
    lanes so the kernels launch over the live prefix only.

    ``telemetry`` (a static :class:`~repro.telemetry.ring.RingConfig`)
    turns on the in-loop flight recorder: a
    :class:`~repro.telemetry.ring.TelemetryRing` rides the while_loop
    carry sampling per-lane KKT gap / active-set size / unshrink counts
    every ``sample_every`` iterations (plus the freeze iteration) and
    every accepted planning-step mu/mu* ratio — the classic engine's
    Fig. 3 ``record_trace`` channel, per lane.  The return value becomes
    the ``(FusedResult, TelemetryRing)`` pair.  With ``telemetry=None``
    (default) no ring exists in the carry and the traced jaxpr is
    byte-identical to the telemetry-free engine — the hot path pays
    nothing when observability is off.

    ``cfg.step == "conjugate"`` (plain-SMO lanes only) enables the
    Conjugate-SMO two-direction step: each iteration solves the exact 2x2
    subproblem spanned by the current WSS direction and the *previous*
    update direction, whose Q-product is carried per lane as a
    :class:`_ConjState` element riding the while_loop carry next to the
    batch state (like the ring, Python-gated: with ``step="plain"`` the
    traced jaxpr is byte-identical to the pre-conjugate engine).  The
    conjugate step is accepted only when the carried direction is valid,
    the 2x2 minor is safely positive definite, all four touched
    coordinates stay strictly interior, and the exact 2-D gain dominates
    the 1-D Newton gain; otherwise the lane falls back to the plain
    clipped SMO step bitwise.  The direction resets on clipped steps,
    shrink-mask refreshes and unshrink events (reset-on-clip).  Accepted
    steps are counted in ``FusedResult.n_planning`` and surface on the
    telemetry plan-event/ratio channels (planning and conjugate are
    mutually exclusive — ``SolverConfig`` forbids ``pasmo`` here).
    """
    assert cfg.algorithm in ("smo", "pasmo")
    assert cfg.plan_candidates == 1
    assert cfg.wss == "wss2", \
        "the fused passes hardcode WSS2 selection (use the standard solver)"
    assert not (cfg.record_trace or cfg.record_steps), \
        "the fused solver does not record traces/steps"
    assert (alpha0 is None) == (G0 is None), \
        "warm starts need the (alpha0, G0) pair"
    assert (gram is None) == (gram_idx is None), \
        "the Gram bank needs the (gram, gram_idx) pair"
    bank = gram is not None
    X = jnp.asarray(X)
    P = jnp.asarray(P)
    dtype = P.dtype
    B, n = P.shape
    lb = X.shape[0]                       # base example count (n or n // 2)
    assert n == (2 * lb if doubled else lb)
    L = jnp.asarray(L, dtype)
    U = jnp.asarray(U, dtype)
    eps = cfg.eps
    eta = cfg.eta
    planning = cfg.algorithm == "pasmo"
    # Conjugate-SMO (static knob, cfg asserts algorithm == "smo"): like the
    # ring, the extra carried state is a *separate* carry element gated at
    # the Python level, so step="plain" traces byte-identical to the
    # pre-conjugate engine.
    conjugate = cfg.step == "conjugate"
    period = cfg.shrink_every if cfg.shrink_every > 0 else DEFAULT_SHRINK_EVERY
    lanes = jnp.arange(B, dtype=jnp.int32)
    # Flight recorder (static knob).  ``collect=False`` must leave the
    # traced jaxpr byte-identical to the telemetry-free engine, so every
    # telemetry hook below is a *Python-level* branch: no ring in the
    # carry, no extra traced ops, and the named scopes collapse to
    # nullcontext (jaxpr equations carry the name stack, so even scopes
    # are gated).
    collect = telemetry is not None
    scope = jax.named_scope if collect else (lambda name: nullcontext())
    if bank:
        src = row_source.bank_source(gram, gram_idx, gamma, dup=doubled)
    else:
        src = row_source.rbf_source(X, gamma, B, dup=doubled)

    # The loop body is dispatch-bound on CPU (dozens of O(B) ops between the
    # two passes), so the per-lane scalar algebra below leans on two
    # fusions: (a) paired gathers/entries stack their index vectors and
    # gather once, and (b) the two alpha scatters merge into one.

    def body(carry):
        conj = ring = None
        if collect and conjugate:
            s, conj, ring = carry
        elif conjugate:
            s, conj = carry
        elif collect:
            s, ring = carry
        else:
            s = carry
        alpha, G = s.alpha, s.G
        idx2 = jnp.concatenate([lanes, lanes])

        def at_idx(idx):
            """(alpha, G, L, U) at per-lane coordinate ``idx`` — four tiny
            (B,) gathers (the general box is data, not a label formula)."""
            return (_take_lane(alpha, idx), _take_lane(G, idx),
                    _take_lane(L, idx), _take_lane(U, idx))

        active = ~s.done
        use_exact = jnp.asarray(planning) & (~s.p_smo) & (~s.prev_ratio_ok)
        act_kw = s.act if shrinking else None

        # ---- pass A: j-selection (k_i stays in VMEM / the bank) ------------
        a_i, _, L_i, U_i = at_idx(s.i)
        with scope("fused_pass_a"):
            j0, gain0 = ops.source_row_wss(src, G, alpha, L, U, s.i, a_i,
                                           L_i, U_i, s.g_i, use_exact,
                                           impl=impl, block_l=block_l,
                                           act=act_kw)
        a_j0, G_j0, L_j0, U_j0 = at_idx(j0)

        # ---- Alg. 3 extra candidate B^(t-2) (O(B d)) -----------------------
        if planning:
            # both "historic" entries in one stacked lookup:
            # K(qi, qj) for the candidate, K(pi, pj) for planning's Q22
            e2 = src.entry_pairs(jnp.concatenate([s.qi, s.pi]),
                                 jnp.concatenate([s.qj, s.pj]), 2)
            K_qq, K_pp = e2[:B], e2[B:]
            a_qi, G_qi, L_qi, U_qi = at_idx(s.qi)
            a_qj, G_qj, L_qj, U_qj = at_idx(s.qj)
            l_q = G_qi - G_qj
            q_q = jnp.maximum(2.0 - 2.0 * K_qq, TAU)
            sb_q = step_mod.step_bounds(a_qi, a_qj, L_qi, U_qi, L_qj, U_qj)
            mu_q = step_mod.clip_step(l_q / q_q, sb_q)
            cg_exact = step_mod.gain_of_step(mu_q, l_q, q_q)
            cg_tilde = 0.5 * l_q * l_q / q_q
            cg = jnp.where(use_exact, cg_exact, cg_tilde)
            adm = ((a_qi < U_qi) & (a_qj > L_qj)
                   & (l_q > 0) & (s.qi != s.qj) & (s.n_hist > 1))
            take = (~s.p_smo) & adm & (cg > gain0)
            # no relaunch needed: pass B recomputes the winning row anyway,
            # and the candidate's scalars are selects of already-gathered
            # values — no fresh gathers for (i_sel, j_sel)
            i_sel = jnp.where(take, s.qi, s.i)
            j_sel = jnp.where(take, s.qj, j0)
            g_i_sel = jnp.where(take, G_qi, s.g_i)
            a_isel = jnp.where(take, a_qi, a_i)
            L_isel = jnp.where(take, L_qi, L_i)
            U_isel = jnp.where(take, U_qi, U_i)
            a_jsel = jnp.where(take, a_qj, a_j0)
            G_jsel = jnp.where(take, G_qj, G_j0)
            L_jsel = jnp.where(take, L_qj, L_j0)
            U_jsel = jnp.where(take, U_qj, U_j0)
        else:
            i_sel, j_sel, g_i_sel = s.i, j0, s.g_i
            a_isel, L_isel, U_isel = a_i, L_i, U_i
            a_jsel, G_jsel, L_jsel, U_jsel = a_j0, G_j0, L_j0, U_j0

        # ---- O(B) step computation ----------------------------------------
        lw = g_i_sel - G_jsel
        K_ij = src.entry_pairs(i_sel, j_sel, 1)
        q11 = jnp.maximum(2.0 - 2.0 * K_ij, TAU)
        sb = step_mod.step_bounds(a_isel, a_jsel, L_isel, U_isel,
                                  L_jsel, U_jsel)
        mu_star = lw / q11
        mu_smo, free_smo = step_mod.smo_step(lw, q11, sb)

        do_plan = jnp.zeros((B,), bool)
        mu_plan = mu_smo
        ratio_ok = s.prev_ratio_ok
        if planning:
            a_pi, G_pi, L_pi, U_pi = at_idx(s.pi)
            a_pj, G_pj, L_pj, U_pj = at_idx(s.pj)
            w2 = G_pi - G_pj
            q22 = jnp.maximum(2.0 - 2.0 * K_pp, TAU)
            e4 = src.entry_pairs(
                jnp.concatenate([i_sel, i_sel, j_sel, j_sel]),
                jnp.concatenate([s.pi, s.pj, s.pi, s.pj]), 4)
            q12 = e4[:B] - e4[B:2 * B] - e4[2 * B:3 * B] + e4[3 * B:]
            terms = step_mod.PlanningTerms(w1=lw, w2=w2, Q11=q11, Q22=q22,
                                           Q12=q12)
            mu1, okdet = step_mod.planning_step(terms)
            mu2 = step_mod.planned_second_step(mu1, terms)
            interior1 = (sb.lo < mu1) & (mu1 < sb.hi)
            d_pi = ((s.pi == i_sel).astype(dtype)
                    - (s.pi == j_sel).astype(dtype))
            d_pj = ((s.pj == i_sel).astype(dtype)
                    - (s.pj == j_sel).astype(dtype))
            sb2 = step_mod.step_bounds(a_pi + mu1 * d_pi, a_pj + mu1 * d_pj,
                                       L_pi, U_pi, L_pj, U_pj)
            interior2 = (sb2.lo < mu2) & (mu2 < sb2.hi)
            feasible = okdet & interior1 & interior2 & (s.n_hist > 0)
            do_plan = s.prev_free & feasible
            mu_plan = jnp.where(do_plan, mu1, mu_smo)
            ratio = mu1 / jnp.where(jnp.abs(mu_star) > 0, mu_star, 1.0)
            ratio_ok = jnp.where(do_plan,
                                 (ratio >= 1.0 - eta) & (ratio <= 1.0 + eta),
                                 s.prev_ratio_ok)

        if conjugate:
            # ---- Conjugate-SMO 2x2 step (O(B), no extra kernel rows) -------
            # Directions: v1 = e_i - e_j (current WSS pair), v2 = e_pi - e_pj
            # (previous pair).  Q v2 is carried in ``conj.u`` — pass B's
            # in-VMEM row difference from last iteration — so every
            # restriction term below is a per-lane gather.
            a_pi, G_pi, L_pi, U_pi = at_idx(s.pi)
            a_pj, G_pj, L_pj, U_pj = at_idx(s.pj)
            w2 = G_pi - G_pj
            q22 = _take_lane(conj.u, s.pi) - _take_lane(conj.u, s.pj)
            q12 = _take_lane(conj.u, i_sel) - _take_lane(conj.u, j_sel)
            terms = step_mod.PlanningTerms(w1=lw, w2=w2, Q11=q11, Q22=q22,
                                           Q12=q12)
            mu1c, mu2c, okdet = step_mod.conjugate_step(terms)

            def moved(c):
                # net displacement of coordinate c under mu1c v1 + mu2c v2;
                # indicator arithmetic handles overlapping pairs exactly
                return (mu1c * ((c == i_sel).astype(dtype)
                                - (c == j_sel).astype(dtype))
                        + mu2c * ((c == s.pi).astype(dtype)
                                  - (c == s.pj).astype(dtype)))

            def interior(c, a_c, L_c, U_c):
                a2 = a_c + moved(c)
                return (L_c < a2) & (a2 < U_c)

            inter = (interior(i_sel, a_isel, L_isel, U_isel)
                     & interior(j_sel, a_jsel, L_jsel, U_jsel)
                     & interior(s.pi, a_pi, L_pi, U_pi)
                     & interior(s.pj, a_pj, L_pj, U_pj))
            # exact gain of the unconstrained 2-direction solve; it
            # dominates the 1-D Newton gain along v1 for a PD minor, so
            # the comparison guards near-degenerate numerics only
            g2 = 0.5 * (lw * mu1c + w2 * mu2c)
            g1 = step_mod.gain_newton(lw, q11)
            do_plan = (conj.ok & (s.n_hist >= 1) & okdet & inter
                       & (g2 + TAU >= g1))
            mu_plan = jnp.where(do_plan, mu1c, mu_smo)
            ratio = mu1c / jnp.where(jnp.abs(mu_star) > 0, mu_star, 1.0)

        # lane freeze: converged lanes take a zero step — pass B becomes a
        # bitwise no-op on their G, alpha is untouched.  Both working-set
        # coordinates update through ONE stacked scatter.  The isfinite
        # guard freezes a lane for one repair iteration when an unshrink
        # event left it with a stale -inf g_i (empty masked I_up).
        mu = jnp.where(active & jnp.isfinite(lw),
                       jnp.where(do_plan, mu_plan, mu_smo), 0.0)
        if conjugate:
            # second-direction coefficient; 0 on rejected/frozen lanes, so
            # both the extra scatter coordinates and pass B's axpy against
            # ``conj.u`` are exact no-ops there (lane freeze stays bitwise)
            mu2v = jnp.where(active & jnp.isfinite(lw) & do_plan, mu2c, 0.0)
            idx4 = jnp.concatenate([idx2, idx2])
            alpha_new = alpha.at[
                idx4, jnp.concatenate([i_sel, j_sel, s.pi, s.pj])].add(
                jnp.concatenate([mu, -mu, mu2v, -mu2v]))
        else:
            alpha_new = alpha.at[idx2, jnp.concatenate([i_sel, j_sel])].add(
                jnp.concatenate([mu, -mu]))

        # ---- pass B: k_i/k_j + update + next i + gap -----------------------
        with scope("fused_pass_b"):
            if conjugate:
                G_new, i_next, g_i_next, g_dn, r_new = ops.source_update_wss(
                    src, G, alpha_new, L, U, i_sel, j_sel, mu, impl=impl,
                    block_l=block_l, act=act_kw, dirv=conj.u, mu2=mu2v)
            else:
                G_new, i_next, g_i_next, g_dn = ops.source_update_wss(
                    src, G, alpha_new, L, U, i_sel, j_sel, mu, impl=impl,
                    block_l=block_l, act=act_kw)
        gap_new = qp_mod.finite_gap(g_i_next - g_dn)
        if shrinking:
            # a lane only counts as converged when its mask was FULL at the
            # scan that produced the gap; a partial-mask "solved" lane is
            # unshrunk in place and keeps iterating (G is exact everywhere,
            # so reactivation costs nothing).
            full_now = jnp.all(s.act, axis=1)
            locally_done = gap_new <= eps
            done = s.done | (active & locally_done & full_now)
            refresh = (s.t % period) == (period - 1)
            act2 = jax.lax.cond(
                refresh,
                lambda: qp_mod.shrink_mask(G_new, alpha_new, L, U),
                lambda: s.act)
            act2 = act2 | (locally_done & ~full_now)[:, None]
            act_new = jnp.where((active & ~done)[:, None], act2, s.act)
            n_unshrink = s.n_unshrink + (
                active & locally_done & ~full_now).astype(jnp.int32)
        else:
            done = s.done | (gap_new <= eps)
            act_new = s.act
            n_unshrink = s.n_unshrink
        gap = jnp.where(active, gap_new, s.gap)

        if conjugate:
            # next iteration's carried direction: Q (e_i - e_j) is exactly
            # pass B's in-VMEM row difference, returned for free.  The
            # direction is reset (ok = False) whenever the step clipped
            # (plain SMO hit the box), the shrink mask refreshed, or the
            # lane unshrunk — per Conjugate-SMO's reset-on-clip rule.
            cu_new = jnp.where(active[:, None], r_new, conj.u)
            c_ok = do_plan | free_smo
            if shrinking:
                c_ok = c_ok & ~refresh & ~(locally_done & ~full_now)
            c_ok = jnp.where(active, c_ok, conj.ok)
            conj_new = _ConjState(u=cu_new, ok=c_ok)

        new_s = _BatchState(
            alpha=alpha_new, G=G_new,
            i=jnp.where(active, i_next.astype(jnp.int32), s.i),
            g_i=jnp.where(active, g_i_next, s.g_i),
            gap=gap, t=s.t + 1, iters=s.iters + active.astype(jnp.int32),
            done=done,
            pi=jnp.where(active, i_sel, s.pi).astype(jnp.int32),
            pj=jnp.where(active, j_sel, s.pj).astype(jnp.int32),
            qi=jnp.where(active, s.pi, s.qi),
            qj=jnp.where(active, s.pj, s.qj),
            n_hist=jnp.where(active, jnp.minimum(s.n_hist + 1, 2), s.n_hist),
            p_smo=jnp.where(active, ~do_plan, s.p_smo),
            prev_free=jnp.where(active, (~do_plan) & free_smo, s.prev_free),
            prev_ratio_ok=jnp.where(active, ratio_ok, s.prev_ratio_ok),
            n_planning=s.n_planning + (do_plan & active).astype(jnp.int32),
            act=act_new, n_unshrink=n_unshrink)
        if not collect:
            return (new_s, conj_new) if conjugate else new_s
        # ---- flight recorder (O(B) only; see repro.telemetry.ring) ---------
        with scope("telemetry_ring"):
            if shrinking:
                n_act = jnp.sum(act_new, axis=1).astype(jnp.int32)
            else:
                n_act = jnp.full((B,), n, jnp.int32)
            # conjugate reuses the planning channels (the modes are mutually
            # exclusive): plan_event/n_planning count accepted conjugate
            # steps and ratio samples mu1/mu* for accepted steps.
            ratio_v = (ratio if (planning or conjugate)
                       else jnp.zeros_like(mu_smo))
            ring = ring_update(
                ring, telemetry, t=s.t, active=active,
                newly_done=active & done, gap=gap, n_active=n_act,
                n_unshrink=n_unshrink, plan_event=do_plan & active,
                ratio=ratio_v)
        return (new_s, conj_new, ring) if conjugate else (new_s, ring)

    # ---- init ---------------------------------------------------------------
    if alpha0 is None:
        # grad f(0) = P; alpha = 0 must be feasible (classification, SVR —
        # NOT one-class, whose drivers always pass (alpha0, G0))
        alpha0 = jnp.zeros_like(P)
        G0 = P
    else:
        alpha0 = jnp.asarray(alpha0, dtype)
        G0 = jnp.asarray(G0, dtype)
    up0 = alpha0 < U
    dn0 = alpha0 > L
    v_up = jnp.where(up0, G0, -jnp.inf)
    i0 = jax.lax.argmax(v_up, 1, jnp.int32)
    g_i0 = _take_lane(v_up, i0)
    gap0 = qp_mod.finite_gap(
        g_i0 - jnp.min(jnp.where(dn0, G0, jnp.inf), axis=1))
    zB = jnp.zeros((B,), jnp.int32)
    fB = jnp.zeros((B,), bool)
    act0 = jnp.ones((B, n) if shrinking else (B, 1), bool)
    s0 = _BatchState(alpha=alpha0, G=G0, i=i0, g_i=g_i0, gap=gap0,
                     t=jnp.asarray(0, jnp.int32), iters=zB,
                     done=gap0 <= eps, pi=zB, pj=zB, qi=zB, qj=zB,
                     n_hist=zB, p_smo=~fB, prev_free=fB,
                     prev_ratio_ok=~fB, n_planning=zB,
                     act=act0, n_unshrink=zB)

    if conjugate:
        conj0 = _ConjState(u=jnp.zeros((B, n), dtype),
                           ok=jnp.zeros((B,), bool))
        cond = lambda c: jnp.any(~c[0].done) & (c[0].t < cfg.max_iter)
        if collect:
            ring0 = ring_init(telemetry, B, dtype)
            s, _, ring = jax.lax.while_loop(cond, body, (s0, conj0, ring0))
        else:
            s, _ = jax.lax.while_loop(cond, body, (s0, conj0))
    elif collect:
        ring0 = ring_init(telemetry, B, dtype)
        s, ring = jax.lax.while_loop(
            lambda c: jnp.any(~c[0].done) & (c[0].t < cfg.max_iter),
            body, (s0, ring0))
    else:
        s = jax.lax.while_loop(
            lambda s: jnp.any(~s.done) & (s.t < cfg.max_iter), body, s0)

    up = s.alpha < U
    dn = s.alpha > L
    g_up = jnp.max(jnp.where(up, s.G, -jnp.inf), axis=1)
    g_dn = jnp.min(jnp.where(dn, s.G, jnp.inf), axis=1)
    res = FusedResult(
        alpha=s.alpha, b=qp_mod.safe_bias(g_up, g_dn), G=s.G,
        iterations=s.iters,
        objective=0.5 * (jnp.sum(P * s.alpha, axis=1)
                         + jnp.sum(s.G * s.alpha, axis=1)),
        kkt_gap=s.gap, converged=s.done, n_planning=s.n_planning,
        n_unshrink=s.n_unshrink)
    return (res, ring) if collect else res


def solve_fused_batched(X, Y, C, gamma, cfg: SolverConfig = SolverConfig(),
                        *, impl: str = "auto", block_l: int = 1024,
                        alpha0=None, G0=None, gram=None,
                        gram_idx=None, shrinking: bool = False,
                        telemetry: RingConfig | None = None):
    """Solve a batch of B RBF *classification* QPs over shared ``X`` in ONE
    while_loop — the ``p = y`` instance of :func:`solve_fused_batched_qp`.

    ``Y`` is (B, l) signed label vectors; ``gamma`` is a scalar or (B,);
    ``C`` is a scalar, (B,) per-lane budgets, or (B, l) per-sample budgets
    (class-weighted SVC) — all traced, so heterogeneous batches share one
    compilation.  See :func:`solve_fused_batched_qp` for warm starts, the
    Gram-bank row source, lane freezing and the result layout.
    """
    Y = jnp.asarray(Y)
    dtype = Y.dtype
    B = Y.shape[0]
    C = jnp.asarray(C, dtype)
    if C.ndim < 2:
        C = jnp.broadcast_to(C, (B,))[:, None]
    YC = Y * C
    return solve_fused_batched_qp(
        X, Y, jnp.minimum(0.0, YC), jnp.maximum(0.0, YC), gamma, cfg,
        impl=impl, block_l=block_l, alpha0=alpha0, G0=G0, gram=gram,
        gram_idx=gram_idx, doubled=False, shrinking=shrinking,
        telemetry=telemetry)


# ---------------------------------------------------------------------------
# Chunked host driver: hard row compaction + lane compaction
# ---------------------------------------------------------------------------


def _pow2(n: int) -> int:
    """Smallest power of two >= n (bucketing keeps compile count log)."""
    b = 1
    while b < n:
        b *= 2
    return b


def _merge_chunk_ring(rc: RingConfig, ring, live, it_off, un_off, tel):
    """Fold one chunk's ring into the run-global host accumulators.

    Chunk rings stamp chunk-local iteration counters and chunk-local
    unshrink counts; ``it_off``/``un_off`` (per live lane, *before* this
    chunk was accumulated) rebase them to run-global values.  Slot
    assignment repeats the device-tier oldest-wins rule, so a chunked
    run's per-lane sample stream matches what one long unchunked ring
    would have kept.
    """
    m_live = len(live)
    r = {k: np.asarray(getattr(ring, k))[:m_live] for k in (
        "t", "gap", "n_active", "n_unshrink", "n_samples",
        "ratio", "ratio_t", "n_ratio")}
    tel_t, tel_gap, tel_act, tel_un, tel_ns, tel_r, tel_rt, tel_nr = tel
    for k, lane in enumerate(live):
        ns = int(min(r["n_samples"][k], rc.cap))
        if ns:
            # duplicate trailing slots resolve to the last (newest) write
            slots = np.minimum(tel_ns[lane] + np.arange(ns), rc.cap - 1)
            tel_t[lane, slots] = r["t"][k, :ns] + it_off[k]
            tel_gap[lane, slots] = r["gap"][k, :ns]
            tel_act[lane, slots] = r["n_active"][k, :ns]
            tel_un[lane, slots] = r["n_unshrink"][k, :ns] + un_off[k]
            tel_ns[lane] += int(r["n_samples"][k])
        nr = int(min(r["n_ratio"][k], rc.ratio_cap))
        if nr:
            slots = np.minimum(tel_nr[lane] + np.arange(nr),
                               rc.ratio_cap - 1)
            tel_r[lane, slots] = r["ratio"][k, :nr]
            tel_rt[lane, slots] = r["ratio_t"][k, :nr] + it_off[k]
            tel_nr[lane] += int(r["n_ratio"][k])


def solve_fused_chunked_qp(X, P, L, U, gamma,
                           cfg: SolverConfig = SolverConfig(), *,
                           impl: str = "auto", block_l: int = 1024,
                           chunk: int = 96, shrinking: bool = False,
                           doubled: bool = False, alpha0=None, G0=None,
                           gram=None, gram_idx=None, mesh=None,
                           devices=None, diagnostics=None):
    """Host-chunked :func:`solve_fused_batched_qp` with HARD compaction.

    The in-loop shrinking of the batched engine is *soft* — masked rows
    still ride through the kernels, so it saves selection work but not
    FLOPs (JAX while_loop shapes are static).  This driver runs the
    engine in chunks of ``chunk`` iterations and, between chunks,
    physically compacts BOTH axes on the host:

    * **lanes** — converged lanes are dropped from the batch (after the
      full KKT check below), so the kernels launch over the live lanes
      only;
    * **rows** — with ``shrinking=True`` the LIBSVM shrink rule
      (:func:`repro.core.qp.shrink_mask`, union over live lanes, doubled
      halves folded onto the base axis) gathers the surviving base rows
      into a dense prefix: the next chunk's kernels run at the shrunken
      width.  Both axes are power-of-two bucketed to keep the compile
      count logarithmic.

    Row compaction makes the per-chunk state G *stale on dropped
    coordinates* (updates only touch kept rows; the kept-coordinate G
    stays exact because the kept set only shrinks between unshrink
    events).  Convergence is therefore never declared from the shrunken
    problem alone: a lane whose chunk converges while rows are dropped
    gets the LIBSVM unshrink treatment — its full gradient is
    reconstructed (``G = P - Q alpha`` via
    :meth:`~repro.kernels.row_source.RowSource.matvec`) and the full-set
    KKT gap is checked on the host.  Pass -> the lane retires; fail ->
    ``n_unshrink`` increments, every live lane's gradient is
    reconstructed and the row set resets to full for the next chunk.

    Arguments mirror :func:`solve_fused_batched_qp` (including the
    Gram-bank row source, which is sliced to the kept rows per chunk);
    ``chunk`` is the iteration budget per sub-solve.  ``mesh``/``devices``
    lane-shard every chunk over a device mesh
    (:func:`repro.core.sharded_lanes.solve_fused_sharded_qp` becomes the
    chunk engine — lane compaction happens on the host between chunks, so
    sharding and compaction stack).  Returns a B-flat
    :class:`FusedResult` whose ``iterations``/``n_planning``/
    ``n_unshrink`` accumulate across chunks and whose ``G`` is exact on
    every coordinate for every lane.

    ``diagnostics`` (a :class:`repro.telemetry.Diagnostics`) turns on
    the flight recorder at this host level: each chunk solve runs under
    a phase scope (``chunk_solve`` events with wall seconds / live lane
    and row counts), a :class:`repro.runtime.fault.StepMonitor` EWMA
    over chunk wall-times emits ``straggler_warning`` events when a
    chunk breaches the deadline factor, and — when
    ``diagnostics.ring_config`` is set — the per-chunk device rings are
    rebased to run-global iteration stamps and merged per original lane,
    with the return value becoming ``(FusedResult, TelemetryRing)``.
    """
    assert (alpha0 is None) == (G0 is None), \
        "warm starts need the (alpha0, G0) pair"
    assert (gram is None) == (gram_idx is None), \
        "the Gram bank needs the (gram, gram_idx) pair"
    if mesh is not None or devices is not None:
        # local import: sharded_lanes imports this module at top level
        from repro.core.sharded_lanes import (resolve_lane_mesh,
                                              solve_fused_sharded_qp)
        mesh = resolve_lane_mesh(mesh, devices)
        chunk_solver = partial(solve_fused_sharded_qp, mesh=mesh)
    else:
        chunk_solver = solve_fused_batched_qp
    bank = gram is not None
    X = jnp.asarray(X)
    dtype = X.dtype
    P_np = np.asarray(P, np.float64)
    B, n = P_np.shape
    lb = X.shape[0]
    assert n == (2 * lb if doubled else lb)
    L_np = np.broadcast_to(np.asarray(L, np.float64), (B, n))
    U_np = np.broadcast_to(np.asarray(U, np.float64), (B, n))
    gam_np = np.broadcast_to(
        np.asarray(gamma, np.float64).reshape(-1), (B,))
    X_np = np.asarray(X, np.float64)
    gram_np = None if not bank else np.asarray(gram, np.float64)
    gidx_np = None if not bank else np.asarray(gram_idx, np.int32)
    eps = float(cfg.eps)
    ccfg = dataclasses.replace(cfg, max_iter=min(chunk, cfg.max_iter))

    if alpha0 is None:
        alpha = np.zeros((B, n))
        G = P_np.copy()
    else:
        alpha = np.asarray(alpha0, np.float64).copy()
        G = np.asarray(G0, np.float64).copy()

    out_b = np.zeros(B)
    out_gap = np.zeros(B)
    out_obj = np.zeros(B)
    out_conv = np.zeros(B, bool)
    out_iter = np.zeros(B, np.int64)
    out_plan = np.zeros(B, np.int64)
    out_unshrink = np.zeros(B, np.int64)

    live = np.arange(B)
    keep = np.arange(lb)

    # ---- flight recorder (host tier) — zero work when diagnostics=None ----
    rc = None if diagnostics is None else diagnostics.ring_config
    monitor = None
    tel = None
    if diagnostics is not None:
        import time as _time

        from repro.runtime.fault import StepMonitor
        monitor = StepMonitor(warmup_steps=1)
    if rc is not None:
        tel = (np.zeros((B, rc.cap), np.int32), np.zeros((B, rc.cap)),
               np.zeros((B, rc.cap), np.int32),
               np.zeros((B, rc.cap), np.int32), np.zeros(B, np.int32),
               np.zeros((B, rc.ratio_cap)),
               np.zeros((B, rc.ratio_cap), np.int32),
               np.zeros(B, np.int32))

    def reconstruct(idx):
        """Exact full-width G = P - Q alpha for lanes ``idx``."""
        if bank:
            src = row_source.bank_source(gram, jnp.asarray(gidx_np[idx]),
                                         dup=doubled)
        else:
            src = row_source.rbf_source(X, jnp.asarray(gam_np[idx], dtype),
                                        len(idx), dup=doubled)
        mv = src.matvec(jnp.asarray(alpha[idx], dtype))
        G[idx] = P_np[idx] - np.asarray(mv, np.float64)

    def finalize(idx):
        """Full-set (b, kkt_gap, objective) from exact host state."""
        a, g = alpha[idx], G[idx]
        up = a < U_np[idx]
        dn = a > L_np[idx]
        g_up = np.where(up, g, -np.inf).max(axis=1)
        g_dn = np.where(dn, g, np.inf).min(axis=1)
        gap = g_up - g_dn
        gap = np.where(np.isfinite(gap), gap, 0.0)
        fu, fd = np.isfinite(g_up), np.isfinite(g_dn)
        gu = np.where(fu, g_up, np.where(fd, g_dn, 0.0))
        gd = np.where(fd, g_dn, np.where(fu, g_up, 0.0))
        b = np.where(fu | fd, 0.5 * (gu + gd), 0.0)
        obj = 0.5 * np.sum((P_np[idx] + g) * a, axis=1)
        return b, gap, obj

    max_rounds = 4 * max(1, -(-cfg.max_iter // max(1, chunk))) + 16
    for rnd in range(max_rounds):
        if len(live) == 0:
            break
        m, m_live = len(keep), len(live)
        bsz, rb = _pow2(m_live), _pow2(m)
        lanes = np.concatenate([live, np.repeat(live[:1], bsz - m_live)])
        padc = rb - m

        def gather(A):
            """Kept-coordinate lane state, padded to the row bucket with
            inert coords (L = U = 0: never selectable, G irrelevant)."""
            sub = A[np.ix_(lanes, keep)]
            z = np.zeros((bsz, padc))
            if doubled:
                sub2 = A[np.ix_(lanes, keep + lb)]
                return np.concatenate([sub, z, sub2, z], axis=1)
            return np.concatenate([sub, z], axis=1)

        X_sub = jnp.asarray(np.concatenate(
            [X_np[keep], np.zeros((padc, X_np.shape[1]))]), dtype)
        bank_kw = {}
        if bank:
            gsub = np.zeros(gram_np.shape[:1] + (rb, rb))
            gsub[:, :m, :m] = gram_np[:, keep[:, None], keep[None, :]]
            bank_kw = dict(gram=jnp.asarray(gsub, dtype),
                           gram_idx=jnp.asarray(gidx_np[lanes]))

        if rc is not None:
            bank_kw["telemetry"] = rc
        t0 = 0.0 if diagnostics is None else _time.perf_counter()
        res = chunk_solver(
            X_sub, jnp.asarray(gather(P_np), dtype),
            jnp.asarray(gather(L_np), dtype),
            jnp.asarray(gather(U_np), dtype),
            jnp.asarray(gam_np[lanes], dtype), ccfg, impl=impl,
            block_l=block_l, alpha0=jnp.asarray(gather(alpha), dtype),
            G0=jnp.asarray(gather(G), dtype), doubled=doubled,
            shrinking=shrinking, **bank_kw)
        ring = None
        if rc is not None:
            res, ring = res
        if diagnostics is not None:
            jax.block_until_ready(res.alpha)
            dt = _time.perf_counter() - t0
            diagnostics.event("phase", name="chunk_solve", seconds=dt,
                              round=rnd, lanes=m_live, rows=m)
            # EWMA straggler deadline over chunk wall-times — the same
            # monitor the resilient LM step loop uses (runtime/fault.py)
            if monitor.record(dt):
                diagnostics.event(
                    "straggler_warning", round=rnd, seconds=dt,
                    deadline=monitor.deadline, lanes=live.tolist(),
                    rows=m)
        if ring is not None:
            _merge_chunk_ring(rc, ring, live, out_iter[live],
                              out_unshrink[live], tel)

        ra = np.asarray(res.alpha, np.float64)[:m_live]
        rg = np.asarray(res.G, np.float64)[:m_live]
        alpha[np.ix_(live, keep)] = ra[:, :m]
        G[np.ix_(live, keep)] = rg[:, :m]
        if doubled:
            alpha[np.ix_(live, keep + lb)] = ra[:, rb:rb + m]
            G[np.ix_(live, keep + lb)] = rg[:, rb:rb + m]
        out_iter[live] += np.asarray(res.iterations, np.int64)[:m_live]
        out_plan[live] += np.asarray(res.n_planning, np.int64)[:m_live]
        out_unshrink[live] += np.asarray(res.n_unshrink,
                                         np.int64)[:m_live]
        conv = np.asarray(res.converged)[:m_live]

        # ---- retire converged lanes (full KKT check when rows dropped) ----
        need_unshrink = False
        retired = np.zeros(m_live, bool)
        cand = live[conv]
        if len(cand):
            if m < lb:
                reconstruct(cand)
            b_c, gap_c, obj_c = finalize(cand)
            ok = gap_c <= eps
            good = cand[ok]
            out_b[good] = b_c[ok]
            out_gap[good] = gap_c[ok]
            out_obj[good] = obj_c[ok]
            out_conv[good] = True
            failed = cand[~ok]
            if len(failed):
                out_unshrink[failed] += 1
                need_unshrink = True
            retired[np.nonzero(conv)[0][ok]] = True

        # ---- retire exhausted lanes (budget spent, unconverged) -----------
        exh_pos = np.nonzero((~retired)
                             & (out_iter[live] >= cfg.max_iter))[0]
        if len(exh_pos):
            exh = live[exh_pos]
            if m < lb:
                reconstruct(exh)
            b_e, gap_e, obj_e = finalize(exh)
            out_b[exh] = b_e
            out_gap[exh] = gap_e
            out_obj[exh] = obj_e
            out_conv[exh] = gap_e <= eps
            retired[exh_pos] = True

        live = live[~retired]
        if len(live) == 0:
            break

        if need_unshrink:
            # stored G is stale on dropped coords for EVERY live lane
            if m < lb:
                reconstruct(live)
            keep = np.arange(lb)
        elif shrinking and m > 1:
            # monotone row shrink from the exact kept-coordinate state:
            # a base row survives if ANY live lane still needs it
            cols = (np.concatenate([keep, keep + lb]) if doubled else keep)
            a_k = alpha[np.ix_(live, cols)]
            g_k = G[np.ix_(live, cols)]
            L_k = L_np[np.ix_(live, cols)]
            U_k = U_np[np.ix_(live, cols)]
            up = a_k < U_k
            dn = a_k > L_k
            g_up = np.where(up, g_k, -np.inf).max(axis=1, keepdims=True)
            g_dn = np.where(dn, g_k, np.inf).min(axis=1, keepdims=True)
            act = ~((~dn & (g_k < g_dn)) | (~up & (g_k > g_up)))
            union = act.any(axis=0)
            if doubled:
                union = union[:m] | union[m:]
            if union.any() and not union.all():
                keep = keep[union]

    if len(live):
        # safety bound hit: finalize the stragglers from exact state
        if len(keep) < lb:
            reconstruct(live)
        b_l, gap_l, obj_l = finalize(live)
        out_b[live] = b_l
        out_gap[live] = gap_l
        out_obj[live] = obj_l
        out_conv[live] = gap_l <= eps

    result = FusedResult(
        alpha=jnp.asarray(alpha, dtype), b=jnp.asarray(out_b, dtype),
        G=jnp.asarray(G, dtype),
        iterations=jnp.asarray(out_iter, jnp.int32),
        objective=jnp.asarray(out_obj, dtype),
        kkt_gap=jnp.asarray(out_gap, dtype),
        converged=jnp.asarray(out_conv),
        n_planning=jnp.asarray(out_plan, jnp.int32),
        n_unshrink=jnp.asarray(out_unshrink, jnp.int32))
    if rc is None:
        return result
    tel_t, tel_gap, tel_act, tel_un, tel_ns, tel_r, tel_rt, tel_nr = tel
    ring_out = TelemetryRing(
        t=jnp.asarray(tel_t), gap=jnp.asarray(tel_gap, dtype),
        n_active=jnp.asarray(tel_act), n_unshrink=jnp.asarray(tel_un),
        n_samples=jnp.asarray(tel_ns),
        ratio=jnp.asarray(tel_r, dtype), ratio_t=jnp.asarray(tel_rt),
        n_ratio=jnp.asarray(tel_nr))
    return result, ring_out
