"""Fused two-pass PA-SMO solver (the beyond-paper optimized iteration).

The standard solver (:mod:`repro.core.solver`) mirrors LIBSVM's structure:
row fetch, selection, second row fetch, update, stopping scan — ~4 logical
passes over O(l) state per iteration.  This solver restructures the
iteration into exactly the two fused passes implemented by the Pallas
kernels in :mod:`repro.kernels`:

  pass A: k_i  + second-order j-selection           (reads X, G, masks)
  pass B: k_j (VMEM-only) + gradient update + next i-pick + KKT gap ends

All O(1) work in between — the truncated Newton step, the planning-ahead
step size (eq. 8), the ≤4x4 kernel minor, Alg. 3's B^(t-2) candidate —
runs on scalars, with single-row RBF evaluations costing O(d).

Semantics are identical to ``solver.solve`` with an RBF oracle (same
Algorithms 3/4/5); trajectories agree modulo floating-point reassociation.
``impl`` selects pallas/interpret/jnp exactly as in ``repro.kernels.ops``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import qp as qp_mod
from repro.core import step as step_mod
from repro.core.qp import TAU
from repro.core.solver import SolverConfig
from repro.kernels import ops


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FusedResult:
    alpha: jax.Array
    b: jax.Array
    G: jax.Array
    iterations: jax.Array
    objective: jax.Array
    kkt_gap: jax.Array
    converged: jax.Array
    n_planning: jax.Array


class _State(NamedTuple):
    alpha: jax.Array
    G: jax.Array
    i: jax.Array        # next working-set first index (from pass B)
    g_i: jax.Array      # G[i] == max gradient over I_up
    gap: jax.Array
    t: jax.Array
    done: jax.Array
    pi: jax.Array
    pj: jax.Array
    qi: jax.Array
    qj: jax.Array
    n_hist: jax.Array
    p_smo: jax.Array
    prev_free: jax.Array
    prev_ratio_ok: jax.Array
    n_planning: jax.Array


@partial(jax.jit, static_argnames=("cfg", "impl", "block_l"))
def solve_fused(X, y, C, gamma, cfg: SolverConfig = SolverConfig(),
                *, impl: str = "auto", block_l: int = 1024) -> FusedResult:
    assert cfg.algorithm in ("smo", "pasmo")
    assert cfg.plan_candidates == 1
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    dtype = y.dtype
    n = y.shape[0]
    C = jnp.asarray(C, dtype)
    gamma = jnp.asarray(gamma, dtype)
    L = jnp.minimum(0.0, y * C)
    U = jnp.maximum(0.0, y * C)
    sqn = jnp.sum(X * X, axis=-1)
    eps = cfg.eps
    eta = cfg.eta
    planning = cfg.algorithm == "pasmo"

    def entry(a, b):
        """O(d) single RBF kernel entry."""
        d2 = (jnp.take(sqn, a) + jnp.take(sqn, b)
              - 2.0 * jnp.dot(jnp.take(X, b, axis=0), jnp.take(X, a, axis=0)))
        return jnp.exp(-gamma * jnp.maximum(d2, 0.0))

    def pass_a(G, alpha, i, g_i, use_exact):
        return ops.rbf_row_wss(
            X, sqn, G, alpha, L, U, jnp.take(X, i, axis=0),
            jnp.take(alpha, i), jnp.take(L, i), jnp.take(U, i), g_i,
            i, use_exact, gamma, impl=impl, block_l=block_l)

    def body(s: _State) -> _State:
        alpha, G = s.alpha, s.G
        use_exact = jnp.asarray(planning) & (~s.p_smo) & (~s.prev_ratio_ok)

        # ---- pass A: row k_i + j-selection ---------------------------------
        k_i, j0, gain0 = pass_a(G, alpha, s.i, s.g_i, use_exact)

        # ---- Alg. 3 extra candidate B^(t-2) (O(d)) -------------------------
        if planning:
            K_qq = entry(s.qi, s.qj)
            G_qi = jnp.take(G, s.qi)
            G_qj = jnp.take(G, s.qj)
            l_q = G_qi - G_qj
            q_q = jnp.maximum(2.0 - 2.0 * K_qq, TAU)
            a_qi = jnp.take(alpha, s.qi)
            a_qj = jnp.take(alpha, s.qj)
            sb_q = step_mod.step_bounds(
                a_qi, a_qj, jnp.take(L, s.qi), jnp.take(U, s.qi),
                jnp.take(L, s.qj), jnp.take(U, s.qj))
            mu_q = step_mod.clip_step(l_q / q_q, sb_q)
            cg_exact = step_mod.gain_of_step(mu_q, l_q, q_q)
            cg_tilde = 0.5 * l_q * l_q / q_q
            cg = jnp.where(use_exact, cg_exact, cg_tilde)
            adm = ((a_qi < jnp.take(U, s.qi)) & (a_qj > jnp.take(L, s.qj))
                   & (l_q > 0) & (s.qi != s.qj) & (s.n_hist > 1))
            take = (~s.p_smo) & adm & (cg > gain0)
            i_sel = jnp.where(take, s.qi, s.i)
            j_sel = jnp.where(take, s.qj, j0)
            g_i_sel = jnp.where(take, G_qi, s.g_i)
            # candidate won: the row belongs to qi — recompute pass A
            k_i = jax.lax.cond(
                take,
                lambda: pass_a(G, alpha, s.qi, G_qi, use_exact)[0],
                lambda: k_i)
        else:
            i_sel, j_sel, g_i_sel = s.i, j0, s.g_i

        # ---- O(1) step computation ----------------------------------------
        lw = g_i_sel - jnp.take(G, j_sel)
        K_ij = jnp.take(k_i, j_sel)
        q11 = jnp.maximum(2.0 - 2.0 * K_ij, TAU)
        sb = step_mod.step_bounds(
            jnp.take(alpha, i_sel), jnp.take(alpha, j_sel),
            jnp.take(L, i_sel), jnp.take(U, i_sel),
            jnp.take(L, j_sel), jnp.take(U, j_sel))
        mu_star = lw / q11
        mu_smo, free_smo = step_mod.smo_step(lw, q11, sb)

        do_plan = jnp.asarray(False)
        mu_plan = mu_smo
        ratio_ok = s.prev_ratio_ok
        if planning:
            w2 = jnp.take(G, s.pi) - jnp.take(G, s.pj)
            q22 = jnp.maximum(2.0 - 2.0 * entry(s.pi, s.pj), TAU)
            q12 = (jnp.take(k_i, s.pi) - jnp.take(k_i, s.pj)
                   - entry(j_sel, s.pi) + entry(j_sel, s.pj))
            terms = step_mod.PlanningTerms(w1=lw, w2=w2, Q11=q11, Q22=q22,
                                           Q12=q12)
            mu1, okdet = step_mod.planning_step(terms)
            mu2 = step_mod.planned_second_step(mu1, terms)
            interior1 = (sb.lo < mu1) & (mu1 < sb.hi)
            d_pi = ((s.pi == i_sel).astype(dtype)
                    - (s.pi == j_sel).astype(dtype))
            d_pj = ((s.pj == i_sel).astype(dtype)
                    - (s.pj == j_sel).astype(dtype))
            sb2 = step_mod.step_bounds(
                jnp.take(alpha, s.pi) + mu1 * d_pi,
                jnp.take(alpha, s.pj) + mu1 * d_pj,
                jnp.take(L, s.pi), jnp.take(U, s.pi),
                jnp.take(L, s.pj), jnp.take(U, s.pj))
            interior2 = (sb2.lo < mu2) & (mu2 < sb2.hi)
            feasible = okdet & interior1 & interior2 & (s.n_hist > 0)
            do_plan = s.prev_free & feasible
            mu_plan = jnp.where(do_plan, mu1, mu_smo)
            ratio = mu1 / jnp.where(jnp.abs(mu_star) > 0, mu_star, 1.0)
            ratio_ok = jnp.where(do_plan,
                                 (ratio >= 1.0 - eta) & (ratio <= 1.0 + eta),
                                 s.prev_ratio_ok)

        mu = jnp.where(do_plan, mu_plan, mu_smo)
        alpha_new = alpha.at[i_sel].add(mu).at[j_sel].add(-mu)

        # ---- pass B: update + next i + gap ---------------------------------
        G_new, i_next, g_i_next, g_dn = ops.rbf_update_wss(
            X, sqn, G, k_i, alpha_new, L, U, jnp.take(X, j_sel, axis=0),
            mu, gamma, impl=impl, block_l=block_l)
        gap = g_i_next - g_dn

        return _State(
            alpha=alpha_new, G=G_new, i=i_next.astype(jnp.int32),
            g_i=g_i_next, gap=gap, t=s.t + 1, done=gap <= eps,
            pi=i_sel.astype(jnp.int32), pj=j_sel.astype(jnp.int32),
            qi=s.pi, qj=s.pj,
            n_hist=jnp.minimum(s.n_hist + 1, 2),
            p_smo=~do_plan, prev_free=(~do_plan) & free_smo,
            prev_ratio_ok=ratio_ok,
            n_planning=s.n_planning + do_plan.astype(jnp.int32))

    # ---- init ---------------------------------------------------------------
    alpha0 = jnp.zeros_like(y)
    G0 = y
    up0 = alpha0 < U
    dn0 = alpha0 > L
    v_up = jnp.where(up0, G0, -jnp.inf)
    i0 = jnp.argmax(v_up).astype(jnp.int32)
    g_i0 = v_up[i0]
    gap0 = g_i0 - jnp.min(jnp.where(dn0, G0, jnp.inf))
    z = jnp.asarray(0, jnp.int32)
    s0 = _State(alpha=alpha0, G=G0, i=i0, g_i=g_i0, gap=gap0, t=z,
                done=gap0 <= eps, pi=z, pj=z, qi=z, qj=z, n_hist=z,
                p_smo=jnp.asarray(True), prev_free=jnp.asarray(False),
                prev_ratio_ok=jnp.asarray(True), n_planning=z)

    s = jax.lax.while_loop(lambda s: (~s.done) & (s.t < cfg.max_iter),
                           body, s0)

    up = s.alpha < U
    dn = s.alpha > L
    g_up = jnp.max(jnp.where(up, s.G, -jnp.inf))
    g_dn = jnp.min(jnp.where(dn, s.G, jnp.inf))
    return FusedResult(
        alpha=s.alpha, b=0.5 * (g_up + g_dn), G=s.G, iterations=s.t,
        objective=0.5 * (jnp.dot(y, s.alpha) + jnp.dot(s.G, s.alpha)),
        kkt_gap=s.gap, converged=s.done, n_planning=s.n_planning)
