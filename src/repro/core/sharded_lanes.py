"""Lane-sharded fused engine: the flat lane batch ``shard_map``-ed over a mesh.

The fused batched engine
(:func:`repro.core.solver_fused.solve_fused_batched_qp`) advances the whole
(gamma, class, C) lane batch through ONE single-device ``lax.while_loop`` —
grid throughput is capped by one chip no matter how many are attached.  But
lanes are *embarrassingly parallel*: every per-iteration quantity of lane b
(selection, step, planning history, in-kernel freezing, the shrinking mask)
is a function of lane b's state alone, and the only shared operands — ``X``
and the optional Gram bank — are read-only.  So the lane axis shards with
ZERO collectives in the hot loop: each device runs its own independent
two-pass while_loop on its lane slab and terminates when ITS slab converges
(per-shard termination — a shard of easy lanes retires early instead of
idling on the global straggler barrier that the single-device loop pays).

Two scheduling details make the flat split balance:

* **cost-balanced round-robin** — lane iteration counts grow with the box
  budget (big-C lanes iterate longest; see ``BENCH_grid.json``), so slicing
  the flat batch contiguously would park one gamma's big-C stragglers on
  one shard.  Lanes are instead dealt round-robin in descending box-width
  order (descending C for classification/SVR lanes, descending ``1/(nu l)``
  for one-class lanes) so every shard sees the same cost spectrum; the
  inverse permutation restores the caller's lane order on gather-back
  (:func:`lane_schedule`).
* **pad lanes** — the batch pads to a multiple of the axis size with
  frozen ``L = U = 0`` lanes (:func:`pad_lanes`): the same degenerate-box
  convention the engine already handles — such a lane converges at t = 0,
  every kernel pass is a bitwise no-op on it, and its finalized
  ``kkt_gap``/``b`` are finite.  Pads are stripped from every returned
  leaf.

The per-shard body is byte-for-byte the batched engine, so every row
source (plain RBF recompute, in-kernel doubled ε-SVR halves, Gram-bank
gathers) and every backend (``jnp``/``interpret``/``pallas``) rides along
unchanged, as do warm starts and soft shrinking.  Per-lane trajectories
are independent of batch composition (all reductions run along the lane's
own row axis), so sharded results match the single-device engine lane for
lane — same objectives, same iteration counts.  One caveat, a property
of XLA codegen rather than of the sharding layer (it reproduces
*already on a single device* by just changing the batch size): the
compiled reduction/matmul order of the kernel passes can depend on the
lane-batch shape, and a small per-device slab may compile differently
than the same lanes inside the full batch (the doubled ε-SVR operator is
the most sensitive — solo vs in-batch lanes differ at ~1e-8 — but small
plain slabs reproduce it too).  When the slab codegen diverges, the two
engines take different float round-off trajectories and stop at
*different eps-optimal points*: iteration counts differ and objectives
agree to the solver tolerance, not bitwise.  For exact bitwise parity
keep the per-device slab comfortably sized (the tests pin a 2-device
mesh for their iteration-count parity case); for tight objective parity
across any slab shape, tighten ``cfg.eps`` — both engines' objectives
sit within O(eps^2)-ish of the shared optimum.

This is stage (1) of the ROADMAP's million-row plan ("shard the lanes,
then shard the rows"); stage (2) plugs a row-sharded
:class:`~repro.kernels.row_source.RowSource` with all-reduced pass A/B
partials into the same seam.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from jax.sharding import Mesh, PartitionSpec as Pspec

if hasattr(jax, "shard_map"):  # jax >= 0.6 public API
    _shard_map = jax.shard_map
    _SHARD_MAP_CHECK = {"check_vma": False}
else:  # older jax: experimental namespace, check_rep spelling
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_CHECK = {"check_rep": False}

from repro.core.solver import SolverConfig
from repro.core.solver_fused import FusedResult, solve_fused_batched_qp
from repro.launch.mesh import make_lane_mesh
from repro.telemetry.ring import RingConfig, TelemetryRing


def resolve_lane_mesh(mesh: Optional[Mesh] = None, devices=None,
                      axis: str = "data") -> Mesh:
    """Resolve the lane mesh: an explicit mesh wins, else a 1-D mesh over
    ``devices`` (default: every attached device)."""
    if mesh is not None:
        if axis not in mesh.shape:
            raise ValueError(f"mesh has no {axis!r} axis: {mesh.shape}")
        if devices is not None:
            raise ValueError("pass either mesh or devices, not both")
        return mesh
    return make_lane_mesh(devices, axis=axis)


def lane_schedule(cost: jax.Array, n_shards: int
                  ) -> Tuple[jax.Array, jax.Array]:
    """Cost-balanced round-robin lane permutation for ``n_shards`` slabs.

    ``cost`` (B,) is a per-lane straggler proxy (box width == C); B must be
    divisible by ``n_shards``.  Returns ``(order, inv)``: ``lanes[order]``
    lays the batch out shard-major so contiguous slab p holds the lanes at
    descending-cost positions ``p, p + n_shards, p + 2 n_shards, ...`` —
    every shard gets the same cost spectrum instead of one shard inheriting
    a whole big-C straggler block.  ``inv`` is the inverse permutation
    (``result[order][inv] == result``) applied on gather-back so callers
    never see the scheduling order.
    """
    B = cost.shape[0]
    assert B % n_shards == 0, (B, n_shards)
    # lax.sort with an int32 iota payload == stable argsort on the int32
    # index channel (jnp.argsort would mint int64 indices under x64)
    iota = jnp.arange(B, dtype=jnp.int32)
    _, srt = jax.lax.sort((-cost, iota), num_keys=1)   # descending, stable
    order = srt.reshape(B // n_shards, n_shards).T.reshape(-1)
    _, inv = jax.lax.sort((order, iota), num_keys=1)
    return order, inv


def pad_lanes(A: jax.Array, pad: int, value=0.0) -> jax.Array:
    """Append ``pad`` inert lanes along axis 0 (``L = U = 0`` convention:
    every padded per-lane quantity is 0 except gamma, padded by value)."""
    if pad == 0:
        return A
    widths = [(0, pad)] + [(0, 0)] * (A.ndim - 1)
    return jnp.pad(A, widths, constant_values=value)


@partial(jax.jit, static_argnames=("cfg", "mesh", "axis", "impl", "block_l",
                                   "doubled", "shrinking", "telemetry"))
def _solve_sharded(X, P, L, U, gamma, cfg, mesh, axis, impl, block_l,
                   alpha0, G0, gram, gram_idx, doubled, shrinking,
                   telemetry=None):
    nsh = mesh.shape[axis]
    X = jnp.asarray(X)
    P = jnp.asarray(P)
    dtype = P.dtype
    B, n = P.shape
    L = jnp.broadcast_to(jnp.asarray(L, dtype), (B, n))
    U = jnp.broadcast_to(jnp.asarray(U, dtype), (B, n))
    gamma = jnp.broadcast_to(jnp.asarray(gamma, dtype), (B,))
    warm = alpha0 is not None
    bank = gram is not None

    # ---- pad to a multiple of the axis size (frozen L = U = 0 lanes) ----
    pad = (-B) % nsh
    Bp = B + pad
    Pp, Lp, Up = (pad_lanes(A, pad) for A in (P, L, U))
    gp = pad_lanes(gamma, pad, value=1.0)   # any positive width is inert

    # ---- cost-balanced round-robin schedule ----------------------------
    # box width == C for classification/SVR lanes, 1/(nu l) for one-class;
    # pad lanes have width 0 and sort last, landing one per shard
    cost = jnp.max(Up - Lp, axis=1)
    order, inv = lane_schedule(cost, nsh)

    lane1, lane2, rep = Pspec(axis), Pspec(axis, None), Pspec()
    operands = [jnp.take(Pp, order, axis=0), jnp.take(Lp, order, axis=0),
                jnp.take(Up, order, axis=0), jnp.take(gp, order)]
    in_specs = [rep, lane2, lane2, lane2, lane1]
    if warm:
        operands += [jnp.take(pad_lanes(jnp.asarray(alpha0, dtype), pad),
                              order, axis=0),
                     jnp.take(pad_lanes(jnp.asarray(G0, dtype), pad),
                              order, axis=0)]
        in_specs += [lane2, lane2]
    if bank:
        gidx = pad_lanes(jnp.asarray(gram_idx, jnp.int32), pad, value=0)
        operands += [jnp.asarray(gram), jnp.take(gidx, order)]
        in_specs += [rep, lane1]

    collect = telemetry is not None

    def local_solve(Xl, *slab):
        it = iter(slab)
        Pl, Ll, Ul, gl = next(it), next(it), next(it), next(it)
        kw = {}
        if warm:
            kw["alpha0"], kw["G0"] = next(it), next(it)
        if bank:
            kw["gram"], kw["gram_idx"] = next(it), next(it)
        # the per-shard body IS the batched engine: its own while_loop,
        # per-shard termination, no collective anywhere in the hot loop
        r = solve_fused_batched_qp(Xl, Pl, Ll, Ul, gl, cfg, impl=impl,
                                   block_l=block_l, doubled=doubled,
                                   shrinking=shrinking, telemetry=telemetry,
                                   **kw)
        ring_leaves = ()
        if collect:
            r, ring = r
            # every ring leaf is lane-leading, so per-shard rings ride
            # the same lane specs as the result leaves and gather back
            # in caller lane order below
            ring_leaves = tuple(jax.tree.leaves(ring))
        return (r.alpha, r.b, r.G, r.iterations, r.objective, r.kkt_gap,
                r.converged, r.n_planning, r.n_unshrink) + ring_leaves

    n_ring = len(dataclasses.fields(TelemetryRing)) if collect else 0
    out = _shard_map(local_solve, mesh=mesh,
                     in_specs=tuple(in_specs),
                     out_specs=(lane1,) * (9 + n_ring),
                     **_SHARD_MAP_CHECK)(X, *operands)

    # gather-back: undo the schedule, strip the pad lanes
    out = tuple(jnp.take(leaf, inv[:B], axis=0) for leaf in out)
    res = FusedResult(*out[:9])
    if collect:
        return res, TelemetryRing(*out[9:])
    return res


def solve_fused_sharded_qp(X, P, L, U, gamma,
                           cfg: SolverConfig = SolverConfig(), *,
                           mesh: Optional[Mesh] = None, devices=None,
                           axis: str = "data", impl: str = "auto",
                           block_l: int = 1024, alpha0=None, G0=None,
                           gram=None, gram_idx=None, doubled: bool = False,
                           shrinking: bool = False,
                           telemetry: Optional[RingConfig] = None):
    """Lane-sharded :func:`~repro.core.solver_fused.solve_fused_batched_qp`.

    Same problem layout and result contract as the batched engine — B
    general dual QP lanes over shared ``X`` (``P``/``L``/``U`` per lane,
    per-lane ``gamma``, optional warm starts, optional Gram bank, the
    doubled ε-SVR operator, soft shrinking) — but the lane batch is
    ``shard_map``-ed over ``mesh[axis]``: each device runs its own
    two-pass while_loop on a cost-balanced slab of lanes and stops when
    that slab converges (see module docstring).  ``mesh`` must carry the
    named ``axis``; alternatively pass ``devices`` (or neither — every
    attached device) and a 1-D mesh is built.  Results come back in the
    caller's lane order with pad lanes stripped; per-lane objectives and
    iteration counts match the single-device engine exactly.

    ``telemetry`` (static :class:`~repro.telemetry.ring.RingConfig`)
    turns on the fused engine's flight recorder per shard; the per-shard
    rings gather back in caller lane order (pad lanes stripped) and the
    return value becomes ``(FusedResult, TelemetryRing)``.

    ``cfg.step == "conjugate"`` rides through unchanged (the config is
    static and the conjugate carry is per lane, so the per-shard body is
    still byte-for-byte the batched engine).
    """
    assert (alpha0 is None) == (G0 is None), \
        "warm starts need the (alpha0, G0) pair"
    assert (gram is None) == (gram_idx is None), \
        "the Gram bank needs the (gram, gram_idx) pair"
    mesh = resolve_lane_mesh(mesh, devices, axis)
    return _solve_sharded(X, P, L, U, gamma, cfg, mesh, axis, impl, block_l,
                          alpha0, G0, gram, gram_idx, doubled, shrinking,
                          telemetry=telemetry)


def solve_fused_sharded(X, Y, C, gamma, cfg: SolverConfig = SolverConfig(),
                        *, mesh: Optional[Mesh] = None, devices=None,
                        axis: str = "data", impl: str = "auto",
                        block_l: int = 1024, alpha0=None, G0=None,
                        gram=None, gram_idx=None,
                        shrinking: bool = False,
                        telemetry: Optional[RingConfig] = None):
    """Lane-sharded classification batch — the ``p = y`` instance of
    :func:`solve_fused_sharded_qp`, mirroring
    :func:`~repro.core.solver_fused.solve_fused_batched`.  ``C`` is a
    scalar, (B,) per-lane budgets, or (B, l) per-sample budgets."""
    Y = jnp.asarray(Y)
    dtype = Y.dtype
    B = Y.shape[0]
    C = jnp.asarray(C, dtype)
    if C.ndim < 2:
        C = jnp.broadcast_to(C, (B,))[:, None]
    YC = Y * C
    return solve_fused_sharded_qp(
        X, Y, jnp.minimum(0.0, YC), jnp.maximum(0.0, YC), gamma, cfg,
        mesh=mesh, devices=devices, axis=axis, impl=impl, block_l=block_l,
        alpha0=alpha0, G0=G0, gram=gram, gram_idx=gram_idx, doubled=False,
        shrinking=shrinking, telemetry=telemetry)
