"""C/gamma model-selection grids as one jit-compiled, vmapped solve.

A hyper-parameter grid over an RBF-SVM is ``n_gamma * n_class * n_C``
independent QPs that share one dataset.  Three structural facts make the
whole grid a single compiled call instead of a Python loop:

* The O(l^2 d) part of the Gram work — the squared-distance matrix — is
  *gamma-independent*: ``K_gamma = exp(-gamma * D2)`` is one elementwise
  exp per gamma on a shared ``D2``.
* (C, gamma, labels) are traced arguments of :func:`repro.core.solver.solve`
  (the config is static, the problem is data), so every grid point shares
  one compilation and batches under ``vmap``.
* The C-axis is solved by ``lax.scan`` in ascending order with *scaled
  warm starts*: ``alpha * (C_t/C_{t-1})`` is exactly feasible for the grown
  box (signs and the sum-to-zero constraint are scale-invariant, and bound
  support vectors land exactly on the new bound), and the matching gradient
  is closed-form — ``G' = (1-r) y + r G`` since ``G = y - K alpha`` — so
  the restart costs O(l), no kernel evaluations (cf. the paper's cold-start
  property in §2).

Two engines share this structure, selected by ``impl``:

* ``impl=None`` — the vmapped standard solver over per-gamma precomputed
  Gram matrices (the differential oracle; ~4 logical passes per iteration
  per lane).
* ``impl="auto"|"pallas"|"interpret"|"jnp"`` — the fused two-pass batched
  engine (:func:`repro.core.solver_fused.solve_fused_batched`): the whole
  lane batch advances through ONE while_loop with TWO batched kernel
  launches per iteration, no Gram materialization, converged lanes frozen
  in-kernel.  The direct answer to the ROADMAP's "vmapped while_loop body
  is op-dispatch bound" item.

Axis convention for all stacked results: ``(n_gamma, n_class, n_C, ...)``.
"""

from __future__ import annotations

import dataclasses
from contextlib import nullcontext
from functools import partial
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qp as qp_mod
from repro.core.solver import (SolveResult, SolverConfig, resolve_shrink_cfg,
                               solve)
from repro.core.solver_fused import (FusedResult, solve_fused_batched,
                                     solve_fused_batched_qp,
                                     solve_fused_chunked_qp)
from repro.core.sharded_lanes import (resolve_lane_mesh, solve_fused_sharded,
                                      solve_fused_sharded_qp)


def sqdist(X: jax.Array) -> jax.Array:
    """Pairwise squared distances (l, l) — the shared, gamma-free Gram work."""
    sq = jnp.sum(X * X, axis=-1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (X @ X.T)
    return jnp.maximum(d2, 0.0)


@partial(jax.jit, static_argnames=("cfg", "warm_start"))
def _solve_grid(X, Y, Cs, gammas, cfg: SolverConfig,
                warm_start: bool) -> SolveResult:
    D2 = sqdist(X)

    def per_gamma(gamma):
        kern = qp_mod.PrecomputedKernel(jnp.exp(-gamma * D2))

        def per_class(y):
            def step(carry, C):
                alpha, G, C_prev = carry
                r = C / C_prev
                a0 = alpha * r                   # exactly feasible at C
                g0 = (1.0 - r) * y + r * G       # y - K(r alpha), O(l)
                res = solve(kern, y, C, cfg, alpha0=a0, G0=g0)
                nxt = (res.alpha, res.G, C) if warm_start else carry
                return nxt, res

            # alpha=0, G=y is the C-free cold start: the scaled carry maps
            # it to itself, so the first scan step is exact for any C_prev.
            cold = (jnp.zeros_like(y), y, Cs[0])
            _, out = jax.lax.scan(step, cold, Cs)
            return out

        return jax.vmap(per_class)(Y)

    return jax.vmap(per_gamma)(gammas)


# ---------------------------------------------------------------------------
# Fused-batched engine (two kernel launches per iteration, all lanes)
# ---------------------------------------------------------------------------
#
# The vmapped engine above runs the standard ~4-pass solver body per lane —
# correct everywhere, but op-dispatch bound on CPU (the ROADMAP open item).
# The fused engine flattens ALL grid axes — gamma, class, AND C — into
# B = n_gamma * k * n_C lanes over shared X and drives the whole batch
# through ``solve_fused_batched``: ONE while_loop total, TWO batched kernel
# launches per iteration, O(B) scalar algebra in between.  In-kernel lane
# freezing is what makes the flat batch viable: the wall clock is the
# SLOWEST single lane, not the sum of per-C maxima that the scanned
# warm-start chain pays (all-C-lanes-at-once replaces the C chain, so the
# scaled warm start does not apply here; lanes cold-start).
#
# The row source is orthogonal to the backend (``precompute``): with a
# Gram bank the per-gamma matrices are built once (same (n_gamma, l, l)
# memory as the vmapped engine) and rows become gathers — on the jnp
# backend as XLA-fused algebra, on pallas/interpret through the
# rows-variant kernels; without a bank the rows are recomputed from X
# tiles (the accelerator memory mode — no Gram at all).  The default
# (``precompute=None``) banks exactly on the jnp backend.
#
# The fused engine does not track the per-step counters n_free /
# n_clipped / n_reverted — they are GENUINELY UNTRACKED, so the fused
# drivers fill all three with the -1 sentinel (UNTRACKED) instead of
# zeros: a zero would read as "this never happened" to callers comparing
# engines.  The state counter every engine shares is n_free_sv — the
# number of *free support vectors* at the optimum, computed from the
# final alpha and the box bounds (see SolveResult).

UNTRACKED = -1  # sentinel for counters the fused iteration never materializes


def _free_sv_count(alpha, L, U) -> jax.Array:
    """Per-lane count of strictly-interior (free) support vectors."""
    return jnp.sum((alpha > L) & (alpha < U), axis=-1).astype(jnp.int32)


def _use_bank(impl: str, precompute) -> bool:
    """Resolve the row-source policy: ``None`` banks exactly on jnp."""
    from repro.kernels.ops import resolve_impl
    if precompute is None:
        return resolve_impl(impl) == "jnp"
    return bool(precompute)


def _trace_fields(dims, dtype, ring=None) -> dict:
    """The ``SolveResult`` trace/step-recording buffers for grid drivers.

    The fused engines never run the classic solver's in-loop
    ``record_trace``/``record_steps`` recorders, so historically every
    driver allocated its own placeholder buffers.  This is now the ONE
    place they come from: placeholders by default, and when the flight
    recorder ran (``ring`` is the grid-shaped
    :class:`~repro.telemetry.ring.TelemetryRing`) the Fig. 3 mu/mu*
    channel fills ``trace``/``n_trace`` with the classic semantics —
    one entry per *accepted* planning step, oldest-wins at the cap, the
    count free-running past it.
    """
    cap = dims + (1,)
    fields = dict(
        trace=jnp.zeros(cap, dtype), n_trace=jnp.zeros(dims, jnp.int32),
        steps_i=jnp.zeros(cap, jnp.int32), steps_j=jnp.zeros(cap, jnp.int32),
        steps_mu=jnp.zeros(cap, dtype))
    if ring is not None:
        fields["trace"] = jnp.asarray(ring.ratio, dtype)
        fields["n_trace"] = jnp.asarray(ring.n_ratio)
    return fields


def _drain_grid_ring(diagnostics, ring, meta, result):
    """Flatten a grid-shaped ring to lanes and hand it to ``diagnostics``."""
    ndim = result.iterations.ndim if hasattr(result, "iterations") else 3
    # numpy, not jnp: the drain is host-bound and a jnp reshape per leaf
    # costs a device dispatch each
    ring_flat = jax.tree.map(
        lambda leaf: np.asarray(leaf).reshape(
            (-1,) + np.shape(leaf)[ndim:]), ring)
    flat_res = SimpleNamespace(**{
        k: np.asarray(getattr(result, k)).reshape(-1)
        for k in ("iterations", "kkt_gap", "converged", "n_planning",
                  "n_unshrink")
        if getattr(result, k, None) is not None})
    return diagnostics.drain_ring(ring_flat, meta, flat_res)


@partial(jax.jit, static_argnames=("cfg", "impl", "block_l", "precompute",
                                   "shrinking", "mesh", "telemetry"))
def _solve_grid_fused(X, Y, Cs, gammas, cfg: SolverConfig,
                      impl: str, block_l: int, precompute,
                      shrinking: bool = False, mesh=None, telemetry=None):
    k, l = Y.shape
    nG = gammas.shape[0]
    nC = Cs.shape[0]
    # lane order (gamma, class, C) row-major, matching the result axes
    Yf = jnp.repeat(jnp.tile(Y, (nG, 1)), nC, axis=0)    # (B, l)
    gf = jnp.repeat(gammas, k * nC)                      # (B,)
    Cf = jnp.tile(Cs, nG * k)                            # (B,)
    solver = (solve_fused_batched if mesh is None
              else partial(solve_fused_sharded, mesh=mesh))
    if _use_bank(impl, precompute):
        bank = jnp.exp(-gammas[:, None, None] * sqdist(X))
        bidx = jnp.repeat(jnp.arange(nG, dtype=jnp.int32), k * nC)
        out = solver(X, Yf, Cf, gf, cfg, impl=impl,
                     block_l=block_l, gram=bank, gram_idx=bidx,
                     shrinking=shrinking, telemetry=telemetry)
    else:
        out = solver(X, Yf, Cf, gf, cfg, impl=impl,
                     block_l=block_l, shrinking=shrinking,
                     telemetry=telemetry)
    ring = None
    if telemetry is not None:
        out, ring = out

    def to_grid(leaf):                                   # (B, ...) leaves
        return leaf.reshape((nG, k, nC) + leaf.shape[1:])

    fr: FusedResult = jax.tree.map(to_grid, out)
    ring_g = None if ring is None else jax.tree.map(to_grid, ring)
    YC = Y[None, :, None, :] * Cs[None, None, :, None]
    n_free_sv = _free_sv_count(fr.alpha, jnp.minimum(0.0, YC),
                               jnp.maximum(0.0, YC))
    untracked = jnp.full((nG, k, Cs.shape[0]), UNTRACKED, jnp.int32)
    res = SolveResult(
        alpha=fr.alpha, b=fr.b, G=fr.G, iterations=fr.iterations,
        objective=fr.objective, kkt_gap=fr.kkt_gap, converged=fr.converged,
        n_planning=fr.n_planning, n_free=untracked,
        n_clipped=untracked, n_reverted=untracked, n_free_sv=n_free_sv,
        **_trace_fields((nG, k, nC), X.dtype, ring_g))
    return res if ring_g is None else (res, ring_g)


def solve_grid(X, Y, Cs, gammas, cfg: SolverConfig = SolverConfig(), *,
               warm_start: bool = True, impl: str | None = None,
               block_l: int = 1024, precompute: bool | None = None,
               shrinking: bool = False, mesh=None,
               devices=None, diagnostics=None) -> SolveResult:
    """Solve the full (gamma, class, C) grid in ONE compiled call.

    ``X``: (l, d) shared inputs; ``Y``: (k, l) signed label vectors (a 1-D
    ``y`` is promoted to one class head); ``Cs``: (n_C,); ``gammas``:
    (n_gamma,) (scalars are promoted).  Returns a :class:`SolveResult` whose
    leaves have leading axes ``(n_gamma, n_class, n_C)`` aligned with the
    *input* order of ``Cs``/``gammas``.

    ``impl`` selects the engine.  ``None`` (default) is the vmapped
    standard-solver path over per-gamma precomputed Gram matrices — the
    differential oracle.  Any kernel backend name
    (``"auto"``/``"pallas"``/``"interpret"``/``"jnp"``) routes the grid
    through the fused two-pass batched engine
    (:func:`repro.core.solver_fused.solve_fused_batched`): the WHOLE
    (gamma, class, C) grid becomes one flat lane batch advanced by a
    single while_loop with two kernel launches per iteration and
    in-kernel lane freezing.  ``precompute`` picks the row source:
    ``True`` builds the shared per-gamma Gram bank (rows become gathers on
    ANY backend — jnp algebra or the rows-variant Pallas kernels),
    ``False`` recomputes rows from X tiles (no Gram ever materialized),
    ``None`` (default) banks exactly on the jnp backend.  The fused
    engine requires ``cfg.algorithm in ("smo", "pasmo")``,
    ``plan_candidates == 1``, WSS2 selection and no trace/step recording
    (asserted), and fills the untracked step-type counters
    ``n_free``/``n_clipped``/``n_reverted`` with the ``UNTRACKED`` (-1)
    sentinel while reporting the free-SV count in ``n_free_sv`` (see
    module notes).

    ``cfg.step == "conjugate"`` (with ``cfg.algorithm == "smo"``) selects
    the Conjugate-SMO two-direction step in EITHER engine — the config is
    static, so the knob threads through unchanged; on the fused path the
    per-lane conjugate carry resets at chunk boundaries in
    :func:`solve_grid_compacted` (a fresh direction history, exactly like
    the planning history).

    With ``warm_start=True`` the vmapped engine solves the C-axis in
    ascending order (results are scattered back to input order), chaining
    each solve from the previous optimum; ``warm_start=False`` gives
    independent cold starts — same optima, more iterations (used by the
    parity tests).  The fused engine runs all C lanes concurrently from
    cold starts, so ``warm_start`` has no effect there.

    ``shrinking=True`` turns on active-set shrinking in either engine:
    the fused engine masks bound-pinned variables out of its scans
    in-loop (soft shrinking, see
    :func:`~repro.core.solver_fused.solve_fused_batched_qp`); the vmapped
    engine enables its periodic ``cfg.shrink_every`` shrink-and-verify
    cycle.  Optima are unchanged either way (full KKT re-check before any
    lane converges); for the physical row-compaction speedup use
    :func:`solve_grid_compacted`.

    ``mesh``/``devices`` (fused engine only) shard the flat lane batch
    over a device mesh (:mod:`repro.core.sharded_lanes`): pass a mesh
    with a ``data`` axis, or an explicit device list to build a 1-D mesh
    over.  Each device runs its own while_loop on a cost-balanced lane
    slab (zero collectives in the hot loop); results are identical to the
    single-device engine lane for lane.

    ``diagnostics`` (a :class:`repro.telemetry.Diagnostics`, fused engine
    only) turns on the flight recorder: the solve runs under a phase
    scope, the in-loop :class:`~repro.telemetry.ring.TelemetryRing`
    samples every lane (KKT-gap trajectory, active-set size, planning
    mu/mu* ratios), the drained per-lane events land in the diagnostics
    sink keyed by (gamma, class, C), and ``trace``/``n_trace`` on the
    returned result carry the Fig. 3 planning-ratio channel — the
    classic engine's ``record_trace``, generalized to the batched
    engine.
    """
    X = jnp.asarray(X)
    Y = jnp.asarray(Y)
    if Y.ndim == 1:
        Y = Y[None, :]
    Cs_np = np.asarray(Cs, dtype=np.float64).reshape(-1)
    gammas_np = np.asarray(gammas, dtype=np.float64).reshape(-1)
    order = np.argsort(Cs_np, kind="stable")
    Cs_j = jnp.asarray(Cs_np[order], X.dtype)
    gammas_j = jnp.asarray(gammas_np, X.dtype)
    if mesh is not None or devices is not None:
        if impl is None:
            raise ValueError("lane sharding runs on the fused engine — "
                             "set impl (e.g. impl='jnp') with mesh/devices")
        mesh = resolve_lane_mesh(mesh, devices)
    if diagnostics is not None and impl is None:
        raise ValueError("diagnostics rides the fused engine — set impl "
                         "(e.g. impl='jnp') with diagnostics")
    tel = None if diagnostics is None else diagnostics.ring_config
    ring = None
    if impl is None:
        res = _solve_grid(X, Y, Cs_j, gammas_j,
                          resolve_shrink_cfg(cfg, True) if shrinking
                          else cfg, warm_start)
    else:
        k = Y.shape[0]
        cm = (nullcontext() if diagnostics is None else diagnostics.scope(
            "solve_grid_fused", lanes=len(gammas_np) * k * len(Cs_np)))
        with cm:
            res = _solve_grid_fused(X, Y, Cs_j, gammas_j, cfg, impl,
                                    block_l, precompute, shrinking, mesh,
                                    tel)
            if tel is not None:
                res, ring = res
            if diagnostics is not None:
                jax.block_until_ready(res.alpha)
    if np.any(order != np.arange(len(Cs_np))):
        inv = np.argsort(order, kind="stable")
        res = jax.tree.map(lambda leaf: jnp.take(leaf, inv, axis=2), res)
        if ring is not None:
            ring = jax.tree.map(lambda leaf: jnp.take(leaf, inv, axis=2),
                                ring)
    if ring is not None:
        meta = [{"gamma": float(g), "label": int(c), "C": float(Cv)}
                for g in gammas_np for c in range(Y.shape[0])
                for Cv in Cs_np]
        _drain_grid_ring(diagnostics, ring, meta, res)
    return res


# ---------------------------------------------------------------------------
# Chunked/compacted grid driver (CPU throughput mode)
# ---------------------------------------------------------------------------
#
# A vmapped while_loop runs until the SLOWEST lane converges, so a batch of
# heterogeneous QPs wastes (max - mean)/mean of its lane-iterations on
# already-converged lanes.  The classic fix: run the loop in fixed chunks of
# iterations, and between chunks compact the unconverged lanes into a
# smaller (power-of-two-bucketed, so compile count stays logarithmic) batch.
# Warm-starting makes chunking free — a resumed solve continues from
# (alpha, G) exactly, only the O(1) planning history is reset.


def _bucket(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


@partial(jax.jit, static_argnames=("cfg",))
def _chunk_solve(Ks, gidx, ys, C, a0, g0, cfg: SolverConfig) -> SolveResult:
    """One chunk of vmapped solves over lanes indexing the shared Gram bank.

    ``Ks`` is the un-mapped (n_gamma, l, l) stack; ``gidx`` maps each lane
    to its gamma — a :class:`~repro.core.qp.StackedKernel` gather per row
    access instead of a per-lane Gram copy (``jnp.repeat`` would cost
    k-fold memory on multiclass grids).
    """
    return jax.vmap(
        lambda g, y, a, gr: solve(qp_mod.StackedKernel(Ks, g), y, C, cfg,
                                  alpha0=a, G0=gr))(gidx, ys, a0, g0)


# step-type counters a chunked solve CAN resume across chunks (they are
# plain per-step sums, so summing the per-chunk values matches solve_grid)
_CHUNK_COUNTERS = ("iterations", "n_planning", "n_free", "n_clipped",
                   "n_reverted")


def _compacted_fused_flat(X, Y, Cs_np, gammas_np,
                          cfg: SolverConfig, chunk: int, impl: str,
                          block_l: int, precompute,
                          shrinking: bool, mesh=None,
                          diagnostics=None) -> SolveResult:
    """Chunked driver over the fused engine, FLAT lane layout.

    Like :func:`_solve_grid_fused` every (gamma, class, C) grid point is
    its own cold-started lane — there is no C chain to scan.  The whole
    lane/row compaction loop lives in
    :func:`~repro.core.solver_fused.solve_fused_chunked_qp`: between
    chunks the host drops converged lanes and (with ``shrinking=True``)
    physically gathers the surviving base rows, so later chunks launch
    their kernels over the live prefix only.  Compaction stacks with the
    in-kernel freeze: frozen lanes cost masked no-op work only until the
    next chunk boundary, after which they cost nothing.
    """
    k, l = Y.shape
    nG, nC = len(gammas_np), len(Cs_np)
    dtype = X.dtype
    Yf = np.repeat(np.tile(np.asarray(Y, np.float64), (nG, 1)), nC, axis=0)
    gam_lane = np.repeat(gammas_np, k * nC)
    C_lane = np.tile(Cs_np, nG * k)
    YC = Yf * C_lane[:, None]
    bank_kw = {}
    if _use_bank(impl, precompute):
        bank_kw = dict(
            gram=jnp.exp(-jnp.asarray(gammas_np, dtype)[:, None, None]
                         * sqdist(X)),
            gram_idx=np.repeat(np.arange(nG, dtype=np.int32), k * nC))
    fr = solve_fused_chunked_qp(
        X, Yf, np.minimum(0.0, YC), np.maximum(0.0, YC), gam_lane, cfg,
        impl=impl, block_l=block_l, chunk=chunk, shrinking=shrinking,
        mesh=mesh, diagnostics=diagnostics, **bank_kw)
    ring = None
    if diagnostics is not None and diagnostics.ring_config is not None:
        fr, ring = fr
    n_free_sv = _free_sv_count(fr.alpha,
                               jnp.asarray(np.minimum(0.0, YC), dtype),
                               jnp.asarray(np.maximum(0.0, YC), dtype))

    def shape(leaf):
        return leaf.reshape((nG, k, nC) + leaf.shape[1:])

    ring_g = None if ring is None else jax.tree.map(shape, ring)
    untracked = jnp.full((nG, k, nC), UNTRACKED, jnp.int32)
    res = SolveResult(
        alpha=shape(fr.alpha), b=shape(fr.b), G=shape(fr.G),
        iterations=shape(fr.iterations),
        objective=shape(fr.objective), kkt_gap=shape(fr.kkt_gap),
        converged=shape(fr.converged),
        n_planning=shape(fr.n_planning), n_free=untracked,
        n_clipped=untracked, n_reverted=untracked,
        n_free_sv=shape(n_free_sv),
        **_trace_fields((nG, k, nC), dtype, ring_g))
    if ring_g is not None:
        # flat lane order == caller axis order here (no C sort on the
        # fused path), so the meta enumerates the result axes directly
        meta = [{"gamma": float(g), "label": int(c), "C": float(Cv)}
                for g in gammas_np for c in range(k) for Cv in Cs_np]
        _drain_grid_ring(diagnostics, ring_g, meta, SimpleNamespace(
            iterations=res.iterations, kkt_gap=res.kkt_gap,
            converged=res.converged, n_planning=res.n_planning,
            n_unshrink=shape(fr.n_unshrink)))
    return res


def solve_grid_compacted(X, Y, Cs, gammas,
                         cfg: SolverConfig = SolverConfig(), *,
                         chunk: int = 96, impl: str | None = None,
                         block_l: int = 1024,
                         precompute: bool | None = None,
                         shrinking: bool = False, mesh=None,
                         devices=None, diagnostics=None) -> SolveResult:
    """Host-driven variant of :func:`solve_grid`: same (gamma, class, C)
    result axes, but the batch is re-compacted every ``chunk`` iterations so
    converged lanes stop consuming wall time.  This is the CPU throughput
    mode; the single fused call is the accelerator mode.

    ``impl`` selects the chunk engine exactly as in :func:`solve_grid`.
    ``None`` runs the vmapped standard solver over the shared per-gamma
    Gram bank (lanes *index* the (n_gamma, l, l) stack — no per-lane Gram
    copies), scanning the C axis with scaled warm starts; the per-step
    counters ``n_free``/``n_clipped``/``n_reverted`` are accumulated
    across chunks, matching :func:`solve_grid` semantics.  A kernel
    backend name routes chunks through
    :func:`~repro.core.solver_fused.solve_fused_batched` in the FLAT lane
    layout (every (gamma, class, C) point is a lane; compaction stacks
    with the in-kernel freeze; ``precompute`` picks the row source as in
    :func:`solve_grid`); there the per-step counters
    ``n_free``/``n_clipped``/``n_reverted`` carry the ``UNTRACKED`` (-1)
    sentinel — the fused iteration never materializes the step type, and
    a zero would be indistinguishable from "never happened".  Every mode
    reports the free-support-vector count from the final
    ``alpha``/bounds in ``n_free_sv``.  The trace/step recording buffers
    are placeholders in both modes (chunk resumes reset the O(1)
    recording state).

    ``shrinking=True`` adds active-set shrinking.  On the fused path the
    chunked driver (:func:`~repro.core.solver_fused.solve_fused_chunked_qp`)
    turns it into HARD row compaction: between chunks the bound-pinned
    base rows no live lane can still move are physically gathered out,
    so the kernels run at the shrunken width — real FLOP reduction, with
    LIBSVM-style gradient reconstruction + full-KKT re-check before any
    lane retires (unshrink events are counted per lane).  On the vmapped
    path it enables the classic engine's ``cfg.shrink_every`` cycle.

    ``mesh``/``devices`` (fused path only) lane-shard every chunk as in
    :func:`solve_grid`; host-side lane compaction between chunks stacks
    with the device split.

    ``diagnostics`` (fused path only) turns on the flight recorder: the
    chunked driver emits per-chunk ``chunk_solve`` phase events and EWMA
    ``straggler_warning`` events, the per-chunk device rings are merged
    into run-global per-lane trajectories, and ``trace``/``n_trace``
    carry the Fig. 3 planning-ratio channel as in :func:`solve_grid`.
    """
    X = jnp.asarray(X)
    Y = jnp.asarray(Y)
    if Y.ndim == 1:
        Y = Y[None, :]
    k, l = Y.shape
    Cs_np = np.asarray(Cs, np.float64).reshape(-1)
    gammas_np = np.asarray(gammas, np.float64).reshape(-1)
    if mesh is not None or devices is not None:
        if impl is None:
            raise ValueError("lane sharding runs on the fused engine — "
                             "set impl (e.g. impl='jnp') with mesh/devices")
        mesh = resolve_lane_mesh(mesh, devices)
    if impl is not None:
        return _compacted_fused_flat(X, Y, Cs_np, gammas_np, cfg, chunk,
                                     impl, block_l, precompute, shrinking,
                                     mesh, diagnostics)
    if diagnostics is not None:
        raise ValueError("diagnostics rides the fused engine — set impl "
                         "(e.g. impl='jnp') with diagnostics")
    if shrinking:
        cfg = resolve_shrink_cfg(cfg, True)
    order = np.argsort(Cs_np, kind="stable")
    nG, nC = len(gammas_np), len(Cs_np)
    B = nG * k

    Yf = jnp.tile(Y, (nG, 1))                           # (B, l)
    g_of_lane = np.repeat(np.arange(nG, dtype=np.int32), k)
    D2 = sqdist(X)
    Ks = jnp.exp(-jnp.asarray(gammas_np, X.dtype)[:, None, None] * D2)
    # never exceed the caller's budget: the last chunk may be partial
    ccfg = dataclasses.replace(cfg, max_iter=min(chunk, cfg.max_iter))

    alpha = np.zeros((B, l))
    G = np.asarray(Yf, np.float64).copy()
    C_prev = float(Cs_np[order][0])
    out = {f: np.zeros((B, nC) + s) for f, s in
           [("alpha", (l,)), ("G", (l,)), ("b", ()), ("objective", ()),
            ("kkt_gap", ()), ("converged", ()),
            *[(f, ()) for f in _CHUNK_COUNTERS]]}

    max_chunks = max(1, -(-cfg.max_iter // chunk))
    for ci in order:
        C = float(Cs_np[ci])
        r = C / C_prev
        a_c = alpha * r                                  # scaled warm start
        g_c = (1.0 - r) * np.asarray(Yf) + r * G
        active = np.arange(B)
        counts = {f: np.zeros(B) for f in _CHUNK_COUNTERS}
        for _ in range(max_chunks):
            bsz = _bucket(len(active))
            idx = np.concatenate([active, np.repeat(active[:1],
                                                    bsz - len(active))])
            res = _chunk_solve(Ks, jnp.asarray(g_of_lane[idx]),
                               jnp.take(Yf, idx, axis=0), C,
                               jnp.asarray(a_c[idx], X.dtype),
                               jnp.asarray(g_c[idx], X.dtype), ccfg)
            n = len(active)
            a_c[active] = np.asarray(res.alpha)[:n]
            g_c[active] = np.asarray(res.G)[:n]
            for f in _CHUNK_COUNTERS:
                counts[f][active] += np.asarray(getattr(res, f))[:n]
            done = np.asarray(res.converged)[:n]
            for f in ("b", "objective", "kkt_gap"):
                out[f][active, ci] = np.asarray(getattr(res, f))[:n]
            out["converged"][active, ci] = done
            active = active[~done]
            if len(active) == 0:
                break
        out["alpha"][:, ci] = a_c
        out["G"][:, ci] = g_c
        for f in _CHUNK_COUNTERS:
            out[f][:, ci] = counts[f]
        alpha, G, C_prev = a_c, g_c, C

    YC = np.asarray(Yf)[:, None, :] * Cs_np[None, :, None]   # (B, nC, l)
    out["n_free_sv"] = np.asarray(_free_sv_count(
        out["alpha"], np.minimum(0.0, YC), np.maximum(0.0, YC)))

    def shape(f, dtype=X.dtype):
        arr = out[f].reshape((nG, k, nC) + out[f].shape[2:])
        return jnp.asarray(arr, dtype)

    return SolveResult(
        alpha=shape("alpha"), b=shape("b"), G=shape("G"),
        iterations=shape("iterations", jnp.int32),
        objective=shape("objective"), kkt_gap=shape("kkt_gap"),
        converged=shape("converged", bool),
        n_planning=shape("n_planning", jnp.int32),
        n_free=shape("n_free", jnp.int32),
        n_clipped=shape("n_clipped", jnp.int32),
        n_reverted=shape("n_reverted", jnp.int32),
        n_free_sv=shape("n_free_sv", jnp.int32),
        **_trace_fields((nG, k, nC), X.dtype))


# ---------------------------------------------------------------------------
# Generalized-dual grids: ε-SVR and one-class lanes on the same fused engine
# ---------------------------------------------------------------------------
#
# The fused engine is dual-generic (per-lane P/L/U), so a regression or
# novelty-detection hyper-parameter grid flattens into the SAME flat
# cold-start lane batch as the SVC grid: one while_loop, two batched kernel
# passes per iteration, in-kernel lane freezing.  The ε-SVR lanes run the
# doubled 2l-variable operator over the base X (rows tiled — no 2l x 2l
# Gram anywhere); on the jnp backend both grids share the per-gamma *base*
# Gram bank exactly like the SVC grid.


def solve_grid_svr(X, y, Cs, epsilons, gammas,
                   cfg: SolverConfig = SolverConfig(), *,
                   impl: str = "auto", block_l: int = 1024,
                   precompute: bool | None = None,
                   shrinking: bool = False, mesh=None,
                   devices=None, diagnostics=None) -> FusedResult:
    """Solve the full ε-SVR (gamma, epsilon, C) grid as one fused lane batch.

    ``X``: (l, d); ``y``: (l,) real targets; ``Cs``: (n_C,); ``epsilons``:
    (n_eps,) tube widths; ``gammas``: (n_gamma,) (scalars are promoted).
    Every lane runs the doubled 2l-variable operator over the *base* X —
    rows stay l-wide on every backend (in-kernel half reads on
    pallas/interpret, tiled base rows on jnp); ``precompute`` picks the
    per-gamma *base* Gram bank exactly as in :func:`solve_grid`.
    Returns a :class:`~repro.core.solver_fused.FusedResult` whose leaves
    have leading axes ``(n_gamma, n_eps, n_C)``; ``alpha`` is the doubled
    (..., 2l) dual — fold with :func:`repro.core.qp.svr_fold` to (..., l)
    coefficients, after which :func:`grid_decision` evaluates the whole
    grid (pass the eps axis in the class slot).  ``shrinking=True``
    enables in-loop soft shrinking over the doubled coordinates (the
    per-lane active mask rides through the ``dup`` kernels like any
    other lane state; see :func:`solve_fused_batched_qp`).
    ``mesh``/``devices`` shard the lane batch over devices exactly as in
    :func:`solve_grid` (doubled lanes promise objective parity vs the
    single-device engine, not bitwise iteration counts — see
    :mod:`repro.core.sharded_lanes`).  ``diagnostics`` turns on the
    flight recorder as in :func:`solve_grid`, with per-lane events keyed
    by (gamma, epsilon, C).
    """
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    dtype = X.dtype
    l = y.shape[0]
    Cs_j = jnp.asarray(np.asarray(Cs, np.float64).reshape(-1), dtype)
    eps_j = jnp.asarray(np.asarray(epsilons, np.float64).reshape(-1), dtype)
    gam_j = jnp.asarray(np.asarray(gammas, np.float64).reshape(-1), dtype)
    nG, nE, nC = gam_j.shape[0], eps_j.shape[0], Cs_j.shape[0]
    zl = jnp.zeros((nC, l), dtype)
    # lane order (gamma, eps, C) row-major; P varies along eps, box along C
    P_e = jnp.concatenate([y[None, :] - eps_j[:, None],
                           y[None, :] + eps_j[:, None]], axis=1)  # (nE, 2l)
    Pf = jnp.tile(jnp.repeat(P_e, nC, axis=0), (nG, 1))           # (B, 2l)
    L_c = jnp.concatenate([zl, -Cs_j[:, None] + zl], axis=1)      # (nC, 2l)
    U_c = jnp.concatenate([Cs_j[:, None] + zl, zl], axis=1)
    Lf = jnp.tile(L_c, (nG * nE, 1))
    Uf = jnp.tile(U_c, (nG * nE, 1))
    gf = jnp.repeat(gam_j, nE * nC)
    bank_kw = {}
    if _use_bank(impl, precompute):
        bank_kw = dict(
            gram=jnp.exp(-gam_j[:, None, None] * sqdist(X)),
            gram_idx=jnp.repeat(jnp.arange(nG, dtype=jnp.int32), nE * nC))
    tel = None if diagnostics is None else diagnostics.ring_config
    cm = (nullcontext() if diagnostics is None
          else diagnostics.scope("solve_grid_svr", lanes=nG * nE * nC))
    with cm:
        if mesh is not None or devices is not None:
            out = solve_fused_sharded_qp(
                X, Pf, Lf, Uf, gf, cfg, mesh=mesh, devices=devices,
                impl=impl, block_l=block_l, doubled=True,
                shrinking=shrinking, telemetry=tel, **bank_kw)
        else:
            out = solve_fused_batched_qp(X, Pf, Lf, Uf, gf, cfg, impl=impl,
                                         block_l=block_l, doubled=True,
                                         shrinking=shrinking, telemetry=tel,
                                         **bank_kw)
        ring = None
        if tel is not None:
            out, ring = out
        if diagnostics is not None:
            jax.block_until_ready(out.alpha)
    if ring is not None:
        # flat lane order (gamma, eps, C) row-major == the result axes
        meta = [{"gamma": float(g), "epsilon": float(e), "C": float(Cv)}
                for g in np.asarray(gam_j) for e in np.asarray(eps_j)
                for Cv in np.asarray(Cs_j)]
        diagnostics.drain_ring(ring, meta, out)
    return jax.tree.map(
        lambda leaf: leaf.reshape((nG, nE, nC) + leaf.shape[1:]), out)


def solve_grid_oneclass(X, nus, gammas, cfg: SolverConfig = SolverConfig(),
                        *, impl: str = "auto", block_l: int = 1024,
                        precompute: bool | None = None,
                        shrinking: bool = False, mesh=None,
                        devices=None, diagnostics=None) -> FusedResult:
    """Solve the one-class (gamma, nu) grid as one fused lane batch.

    Every lane is the ν dual (``p = 0``, box ``[0, 1/(nu l)]``, ``sum(a) =
    1``) started from the LIBSVM feasible point with its closed-position
    gradient ``G0 = -K alpha0`` (one matvec per lane, paid once before the
    loop).  ``precompute`` picks the per-gamma Gram-bank row source as in
    :func:`solve_grid`.  Returns a
    :class:`~repro.core.solver_fused.FusedResult` with
    leading axes ``(n_gamma, n_nu)``; the decision offset is ``rho = -b``
    (``decision(x) = k(x, SVs) @ alpha + b``).  ``mesh``/``devices`` shard
    the lane batch over devices exactly as in :func:`solve_grid` (the lane
    cost proxy is the box width ``1/(nu l)``: small-nu lanes are the
    stragglers and spread round-robin across shards).  ``diagnostics``
    turns on the flight recorder as in :func:`solve_grid`, with per-lane
    events keyed by (gamma, nu).
    """
    X = jnp.asarray(X)
    dtype = X.dtype
    l = X.shape[0]
    nus_np = np.asarray(nus, np.float64).reshape(-1)
    gam_j = jnp.asarray(np.asarray(gammas, np.float64).reshape(-1), dtype)
    nG, nN = gam_j.shape[0], len(nus_np)
    A0 = jnp.stack([qp_mod.oneclass_alpha0(l, nu, dtype) for nu in nus_np])
    U_n = jnp.stack([qp_mod.oneclass_qp(l, nu, dtype).bounds.upper
                     for nu in nus_np])                           # (nN, l)
    Pf = jnp.zeros((nG * nN, l), dtype)
    Lf = jnp.zeros((nG * nN, l), dtype)
    Uf = jnp.tile(U_n, (nG, 1))
    gf = jnp.repeat(gam_j, nN)
    alpha0 = jnp.tile(A0, (nG, 1))
    bank_kw = {}
    if _use_bank(impl, precompute):
        bank = jnp.exp(-gam_j[:, None, None] * sqdist(X))
        G0 = -jnp.einsum("gij,nj->gni", bank, A0).reshape(nG * nN, l)
        bank_kw = dict(
            gram=bank,
            gram_idx=jnp.repeat(jnp.arange(nG, dtype=jnp.int32), nN))
    else:
        # Gram-free init: one blocked RBF matvec per (gamma, nu) lane
        G0 = -jax.vmap(lambda g: jax.vmap(
            lambda a: qp_mod.make_rbf(X, g).matvec(a))(A0))(gam_j)
        G0 = G0.reshape(nG * nN, l)
    tel = None if diagnostics is None else diagnostics.ring_config
    cm = (nullcontext() if diagnostics is None
          else diagnostics.scope("solve_grid_oneclass", lanes=nG * nN))
    with cm:
        if mesh is not None or devices is not None:
            out = solve_fused_sharded_qp(
                X, Pf, Lf, Uf, gf, cfg, mesh=mesh, devices=devices,
                impl=impl, block_l=block_l, alpha0=alpha0, G0=G0,
                shrinking=shrinking, telemetry=tel, **bank_kw)
        else:
            out = solve_fused_batched_qp(X, Pf, Lf, Uf, gf, cfg, impl=impl,
                                         block_l=block_l, alpha0=alpha0,
                                         G0=G0, shrinking=shrinking,
                                         telemetry=tel, **bank_kw)
        ring = None
        if tel is not None:
            out, ring = out
        if diagnostics is not None:
            jax.block_until_ready(out.alpha)
    if ring is not None:
        meta = [{"gamma": float(g), "nu": float(nu)}
                for g in np.asarray(gam_j) for nu in nus_np]
        diagnostics.drain_ring(ring, meta, out)
    return jax.tree.map(
        lambda leaf: leaf.reshape((nG, nN) + leaf.shape[1:]), out)


def grid_decision(Xq, X, gammas, alpha: jax.Array,
                  b: jax.Array) -> jax.Array:
    """Decision values of every grid point on query inputs.

    ``alpha``: (n_gamma, k, n_C, l) signed duals from :func:`solve_grid`;
    ``b``: (n_gamma, k, n_C).  Returns (n_gamma, k, n_C, m) — the query
    cross-Gram is computed once per gamma and shared by all (class, C)
    heads.
    """
    Xq = jnp.asarray(Xq)
    X = jnp.asarray(X)
    gammas = jnp.atleast_1d(jnp.asarray(gammas, X.dtype))
    sq_q = jnp.sum(Xq * Xq, axis=-1)
    sq_x = jnp.sum(X * X, axis=-1)
    d2 = jnp.maximum(sq_q[:, None] + sq_x[None, :] - 2.0 * (Xq @ X.T), 0.0)

    def per_gamma(gamma, a_g, b_g):
        Kq = jnp.exp(-gamma * d2)                      # (m, l) once per gamma
        return jnp.einsum("ml,kcl->kcm", Kq, a_g) + b_g[..., None]

    return jax.vmap(per_gamma)(gammas, alpha, b)
