"""C/gamma model-selection grids as one jit-compiled, vmapped solve.

A hyper-parameter grid over an RBF-SVM is ``n_gamma * n_class * n_C``
independent QPs that share one dataset.  Three structural facts make the
whole grid a single compiled call instead of a Python loop:

* The O(l^2 d) part of the Gram work — the squared-distance matrix — is
  *gamma-independent*: ``K_gamma = exp(-gamma * D2)`` is one elementwise
  exp per gamma on a shared ``D2``.
* (C, gamma, labels) are traced arguments of :func:`repro.core.solver.solve`
  (the config is static, the problem is data), so every grid point shares
  one compilation and batches under ``vmap``.
* The C-axis is solved by ``lax.scan`` in ascending order with *scaled
  warm starts*: ``alpha * (C_t/C_{t-1})`` is exactly feasible for the grown
  box (signs and the sum-to-zero constraint are scale-invariant, and bound
  support vectors land exactly on the new bound), and the matching gradient
  is closed-form — ``G' = (1-r) y + r G`` since ``G = y - K alpha`` — so
  the restart costs O(l), no kernel evaluations (cf. the paper's cold-start
  property in §2).

Axis convention for all stacked results: ``(n_gamma, n_class, n_C, ...)``.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qp as qp_mod
from repro.core.solver import SolveResult, SolverConfig, solve


def sqdist(X: jax.Array) -> jax.Array:
    """Pairwise squared distances (l, l) — the shared, gamma-free Gram work."""
    sq = jnp.sum(X * X, axis=-1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (X @ X.T)
    return jnp.maximum(d2, 0.0)


@partial(jax.jit, static_argnames=("cfg", "warm_start"))
def _solve_grid(X, Y, Cs, gammas, cfg: SolverConfig,
                warm_start: bool) -> SolveResult:
    D2 = sqdist(X)

    def per_gamma(gamma):
        kern = qp_mod.PrecomputedKernel(jnp.exp(-gamma * D2))

        def per_class(y):
            def step(carry, C):
                alpha, G, C_prev = carry
                r = C / C_prev
                a0 = alpha * r                   # exactly feasible at C
                g0 = (1.0 - r) * y + r * G       # y - K(r alpha), O(l)
                res = solve(kern, y, C, cfg, alpha0=a0, G0=g0)
                nxt = (res.alpha, res.G, C) if warm_start else carry
                return nxt, res

            # alpha=0, G=y is the C-free cold start: the scaled carry maps
            # it to itself, so the first scan step is exact for any C_prev.
            cold = (jnp.zeros_like(y), y, Cs[0])
            _, out = jax.lax.scan(step, cold, Cs)
            return out

        return jax.vmap(per_class)(Y)

    return jax.vmap(per_gamma)(gammas)


def solve_grid(X, Y, Cs, gammas, cfg: SolverConfig = SolverConfig(), *,
               warm_start: bool = True) -> SolveResult:
    """Solve the full (gamma, class, C) grid in ONE compiled vmapped call.

    ``X``: (l, d) shared inputs; ``Y``: (k, l) signed label vectors (a 1-D
    ``y`` is promoted to one class head); ``Cs``: (n_C,); ``gammas``:
    (n_gamma,) (scalars are promoted).  Returns a :class:`SolveResult` whose
    leaves have leading axes ``(n_gamma, n_class, n_C)`` aligned with the
    *input* order of ``Cs``/``gammas``.

    With ``warm_start=True`` the C-axis is internally solved in ascending
    order (results are scattered back to input order), chaining each solve
    from the previous optimum; ``warm_start=False`` gives independent
    cold starts — same optima, more iterations (used by the parity tests).
    """
    X = jnp.asarray(X)
    Y = jnp.asarray(Y)
    if Y.ndim == 1:
        Y = Y[None, :]
    Cs_np = np.asarray(Cs, dtype=np.float64).reshape(-1)
    gammas_np = np.asarray(gammas, dtype=np.float64).reshape(-1)
    order = np.argsort(Cs_np, kind="stable")
    res = _solve_grid(X, Y, jnp.asarray(Cs_np[order], X.dtype),
                      jnp.asarray(gammas_np, X.dtype), cfg, warm_start)
    if np.any(order != np.arange(len(Cs_np))):
        inv = np.argsort(order, kind="stable")
        res = jax.tree.map(lambda leaf: jnp.take(leaf, inv, axis=2), res)
    return res


# ---------------------------------------------------------------------------
# Chunked/compacted grid driver (CPU throughput mode)
# ---------------------------------------------------------------------------
#
# A vmapped while_loop runs until the SLOWEST lane converges, so a batch of
# heterogeneous QPs wastes (max - mean)/mean of its lane-iterations on
# already-converged lanes.  The classic fix: run the loop in fixed chunks of
# iterations, and between chunks compact the unconverged lanes into a
# smaller (power-of-two-bucketed, so compile count stays logarithmic) batch.
# Warm-starting makes chunking free — a resumed solve continues from
# (alpha, G) exactly, only the O(1) planning history is reset.


def _bucket(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


@partial(jax.jit, static_argnames=("cfg",))
def _chunk_solve(Ks, ys, C, a0, g0, cfg: SolverConfig) -> SolveResult:
    return jax.vmap(
        lambda K, y, a, g: solve(qp_mod.PrecomputedKernel(K), y, C, cfg,
                                 alpha0=a, G0=g))(Ks, ys, a0, g0)


def solve_grid_compacted(X, Y, Cs, gammas,
                         cfg: SolverConfig = SolverConfig(), *,
                         chunk: int = 96) -> SolveResult:
    """Host-driven variant of :func:`solve_grid`: same (gamma, class, C)
    result axes, but the batch is re-compacted every ``chunk`` iterations so
    converged lanes stop consuming wall time.  This is the CPU throughput
    mode; the single fused call is the accelerator mode.
    """
    X = jnp.asarray(X)
    Y = jnp.asarray(Y)
    if Y.ndim == 1:
        Y = Y[None, :]
    k, l = Y.shape
    Cs_np = np.asarray(Cs, np.float64).reshape(-1)
    gammas_np = np.asarray(gammas, np.float64).reshape(-1)
    order = np.argsort(Cs_np, kind="stable")
    nG, nC = len(gammas_np), len(Cs_np)
    B = nG * k

    D2 = sqdist(X)
    Ks = jnp.exp(-jnp.asarray(gammas_np, X.dtype)[:, None, None] * D2)
    Kf = jnp.repeat(Ks, k, axis=0)                      # (B, l, l) lane Grams
    Yf = jnp.tile(Y, (nG, 1))                           # (B, l)
    ccfg = dataclasses.replace(cfg, max_iter=chunk)

    alpha = np.zeros((B, l))
    G = np.asarray(Yf, np.float64).copy()
    C_prev = float(Cs_np[order][0])
    out = {f: np.zeros((B, nC) + s) for f, s in
           [("alpha", (l,)), ("G", (l,)), ("b", ()), ("objective", ()),
            ("kkt_gap", ()), ("iterations", ()), ("converged", ()),
            ("n_planning", ())]}

    max_chunks = max(1, -(-cfg.max_iter // chunk))
    for ci in order:
        C = float(Cs_np[ci])
        r = C / C_prev
        a_c = alpha * r                                  # scaled warm start
        g_c = (1.0 - r) * np.asarray(Yf) + r * G
        active = np.arange(B)
        iters = np.zeros(B)
        plans = np.zeros(B)
        for _ in range(max_chunks):
            bsz = _bucket(len(active))
            idx = np.concatenate([active, np.repeat(active[:1],
                                                    bsz - len(active))])
            res = _chunk_solve(jnp.take(Kf, idx, axis=0),
                               jnp.take(Yf, idx, axis=0), C,
                               jnp.asarray(a_c[idx], X.dtype),
                               jnp.asarray(g_c[idx], X.dtype), ccfg)
            n = len(active)
            a_c[active] = np.asarray(res.alpha)[:n]
            g_c[active] = np.asarray(res.G)[:n]
            iters[active] += np.asarray(res.iterations)[:n]
            plans[active] += np.asarray(res.n_planning)[:n]
            done = np.asarray(res.converged)[:n]
            for f in ("b", "objective", "kkt_gap"):
                out[f][active, ci] = np.asarray(getattr(res, f))[:n]
            out["converged"][active, ci] = done
            active = active[~done]
            if len(active) == 0:
                break
        out["alpha"][:, ci] = a_c
        out["G"][:, ci] = g_c
        out["iterations"][:, ci] = iters
        out["n_planning"][:, ci] = plans
        alpha, G, C_prev = a_c, g_c, C

    def shape(f, dtype=X.dtype):
        arr = out[f].reshape((nG, k, nC) + out[f].shape[2:])
        return jnp.asarray(arr, dtype)

    zero = jnp.zeros((nG, k, nC), jnp.int32)
    return SolveResult(
        alpha=shape("alpha"), b=shape("b"), G=shape("G"),
        iterations=shape("iterations", jnp.int32),
        objective=shape("objective"), kkt_gap=shape("kkt_gap"),
        converged=shape("converged", bool),
        n_planning=shape("n_planning", jnp.int32),
        n_free=zero, n_clipped=zero, n_reverted=zero,
        trace=jnp.zeros((nG, k, nC, 1), X.dtype), n_trace=zero,
        steps_i=jnp.zeros((nG, k, nC, 1), jnp.int32),
        steps_j=jnp.zeros((nG, k, nC, 1), jnp.int32),
        steps_mu=jnp.zeros((nG, k, nC, 1), X.dtype))


def grid_decision(Xq, X, gammas, alpha: jax.Array,
                  b: jax.Array) -> jax.Array:
    """Decision values of every grid point on query inputs.

    ``alpha``: (n_gamma, k, n_C, l) signed duals from :func:`solve_grid`;
    ``b``: (n_gamma, k, n_C).  Returns (n_gamma, k, n_C, m) — the query
    cross-Gram is computed once per gamma and shared by all (class, C)
    heads.
    """
    Xq = jnp.asarray(Xq)
    X = jnp.asarray(X)
    gammas = jnp.atleast_1d(jnp.asarray(gammas, X.dtype))
    sq_q = jnp.sum(Xq * Xq, axis=-1)
    sq_x = jnp.sum(X * X, axis=-1)
    d2 = jnp.maximum(sq_q[:, None] + sq_x[None, :] - 2.0 * (Xq @ X.T), 0.0)

    def per_gamma(gamma, a_g, b_g):
        Kq = jnp.exp(-gamma * d2)                      # (m, l) once per gamma
        return jnp.einsum("ml,kcl->kcm", Kq, a_g) + b_g[..., None]

    return jax.vmap(per_gamma)(gammas, alpha, b)
