"""JAX PA-SMO / SMO solver: ``jax.lax.while_loop`` driver, jit/vmap friendly.

Implements, selectable via :class:`SolverConfig.algorithm`:

* ``"smo"``          — Algorithm 1 with WSS2 (eq. 3), the LIBSVM baseline.
* ``"pasmo"``        — Algorithm 5 (Alg. 3 selection + Alg. 4 update), the
                       paper's contribution.  ``plan_candidates=N>1`` gives
                       the §7.4 multiple planning-ahead variant.
* ``"pasmo_simple"`` — Algorithm 2 (plan after *any* SMO step, standard
                       WSS2 selection; no convergence guarantee) — ablation.
* ``"overshoot"``    — §7.3 heuristic (clipped ``1.1 mu*``).
* ``wss="mvp"``      — first-order selection ablation (§ state of the art).

The solver state is a flat pytree, so the whole solve is one
``lax.while_loop`` under ``jit`` and batches with ``vmap`` (many QPs at
once: one-vs-rest heads, C/gamma grids).  Kernel rows come from an oracle
(:mod:`repro.core.qp`) so the same loop runs from a precomputed Gram matrix
or from on-the-fly (Pallas-backed) row computation.

The loop body operates on the *general* dual (:class:`repro.core.qp.DualQP`
— linear term ``p``, arbitrary box): :func:`solve_qp` is the general entry
point (ε-SVR via :class:`~repro.core.qp.DoubledKernel`, one-class via a
feasible ``alpha0``), :func:`solve` the classification instance.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import qp as qp_mod
from repro.core import step as step_mod
from repro.core import wss as wss_mod
from repro.core.qp import TAU, Bounds


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """Static solver configuration (hashable; closed over by jit)."""

    algorithm: str = "pasmo"       # smo | pasmo | pasmo_simple | overshoot
    wss: str = "wss2"              # wss2 | mvp
    eps: float = 1e-3              # KKT stopping accuracy (paper default)
    eta: float = 0.9               # Alg. 3 ratio window (paper fixes 0.9)
    overshoot: float = 1.1         # §7.3 factor (only algorithm="overshoot")
    max_iter: int = 1_000_000
    plan_candidates: int = 1       # N of §7.4; 1 = plain PA-SMO
    record_trace: bool = False     # record mu/mu* of planning steps (Fig. 3)
    trace_cap: int = 16384
    shrink_every: int = 0          # 0 = off; else re-evaluate mask every k its
    record_steps: bool = False     # record (i, j, mu) per iteration (debug /
    step_cap: int = 4096           # trajectory-parity tests)
    step: str = "plain"            # plain | conjugate (Conjugate-SMO 2-dir)

    def __post_init__(self):
        assert self.algorithm in ("smo", "pasmo", "pasmo_simple", "overshoot")
        assert self.wss in ("wss2", "mvp")
        assert self.plan_candidates >= 1
        assert self.step in ("plain", "conjugate")
        # The conjugate step *replaces* the planning-ahead machinery (both
        # re-use the previous working set as the second direction), so it
        # only composes with the plain SMO base algorithm.
        assert self.step == "plain" or self.algorithm == "smo", \
            "step='conjugate' requires algorithm='smo'"


class SolverState(NamedTuple):
    alpha: jax.Array          # (l,)
    G: jax.Array              # (l,) gradient  y - K alpha
    t: jax.Array              # int32 iteration counter
    done: jax.Array           # bool
    gap: jax.Array            # last KKT gap
    hist_i: jax.Array         # (N+1,) int32 recent working sets, newest first
    hist_j: jax.Array         # (N+1,)
    n_hist: jax.Array         # int32 number of valid history entries
    p_smo: jax.Array          # bool: previous iteration performed a SMO step
    prev_free: jax.Array      # bool: ... and it was free
    prev_ratio_ok: jax.Array  # bool: last planning ratio in [1-eta, 1+eta]
    dir_u: jax.Array          # (l,) Q (e_pi - e_pj) of the previous step
    conj_ok: jax.Array        # bool: prev direction usable as conjugate
    active: jax.Array         # (l,) bool soft-shrinking mask
    n_planning: jax.Array     # int32 counters
    n_free: jax.Array
    n_clipped: jax.Array
    n_reverted: jax.Array
    trace: jax.Array          # (cap,) float ratios (cap=1 when disabled)
    n_trace: jax.Array        # int32
    steps_i: jax.Array        # (step_cap,) int32 (cap=1 when disabled)
    steps_j: jax.Array        # (step_cap,) int32
    steps_mu: jax.Array       # (step_cap,) float


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SolveResult:
    """Solver output.  Counter semantics are UNIFORM across engines:

    ``n_free``/``n_clipped``/``n_reverted`` are *per-step* counters (how
    many iterations took a free / clipped / reverted-to-SMO step); engines
    that do not materialize the step type (the fused two-pass engine) fill
    them with the ``repro.core.grid.UNTRACKED`` (-1) sentinel — never with
    zeros.  ``n_free_sv`` is the *state* counter every engine can report:
    the number of strictly-interior (free) support vectors at the returned
    ``alpha``.
    """

    alpha: jax.Array
    b: jax.Array              # bias term for prediction
    G: jax.Array
    iterations: jax.Array
    objective: jax.Array
    kkt_gap: jax.Array
    converged: jax.Array
    n_planning: jax.Array
    n_free: jax.Array
    n_clipped: jax.Array
    n_reverted: jax.Array
    n_free_sv: jax.Array
    trace: jax.Array
    n_trace: jax.Array
    steps_i: jax.Array
    steps_j: jax.Array
    steps_mu: jax.Array


# Refresh cadence used when a caller asks for ``shrinking=True`` without
# setting ``SolverConfig.shrink_every`` (LIBSVM refreshes every min(l, 1000)
# iterations; our conservative rule is cheap enough to run more often).
DEFAULT_SHRINK_EVERY = 64


def resolve_shrink_cfg(cfg: SolverConfig, shrinking) -> SolverConfig:
    """Fold a ``shrinking=True|False|None`` knob into ``cfg.shrink_every``.

    ``None`` defers to the config; ``True`` enables it with
    :data:`DEFAULT_SHRINK_EVERY` when the config has no cadence of its own;
    ``False`` forces it off.
    """
    if shrinking is None:
        return cfg
    every = (cfg.shrink_every or DEFAULT_SHRINK_EVERY) if shrinking else 0
    if every == cfg.shrink_every:
        return cfg
    return dataclasses.replace(cfg, shrink_every=every)


def _shrink_mask(G, alpha, bounds: Bounds):
    """Conservative adaptive shrinking: drop bound variables that cannot be
    part of any violating pair under the current gap endpoints (the shared
    rule in :func:`repro.core.qp.shrink_mask`).  Masked variables still
    receive exact gradient updates, so reactivation is free (cf. DESIGN.md
    §3: shrinking is a mask on TPU, not a problem resize).
    """
    return qp_mod.shrink_mask(G, alpha, bounds.lower, bounds.upper)


def _make_body(kernel, p, bounds: Bounds, diag, cfg: SolverConfig):
    n = p.shape[0]
    N = cfg.plan_candidates
    dtype = p.dtype
    eps = jnp.asarray(cfg.eps, dtype)
    eta = cfg.eta
    planning_enabled = cfg.algorithm in ("pasmo", "pasmo_simple")
    conjugate = cfg.step == "conjugate"

    def body(s: SolverState) -> SolverState:
        alpha, G = s.alpha, s.G
        up = qp_mod.up_mask(alpha, bounds) & s.active
        dn = qp_mod.down_mask(alpha, bounds) & s.active

        # ------------------------------------------------------------------
        # Working set selection (Alg. 3 for pasmo, plain WSS2/MVP otherwise)
        # ------------------------------------------------------------------
        i0, g_i0 = wss_mod.select_i(G, up)
        row_i0 = kernel.row(i0)

        if cfg.wss == "mvp":
            sel = wss_mod.select_mvp(G, up, dn)
            sel = wss_mod.Selection(sel.i, sel.j,
                                    gain=jnp.asarray(0.0, dtype),
                                    violation=sel.violation)
            use_exact = jnp.asarray(False)
        elif cfg.algorithm == "pasmo":
            use_exact = (~s.p_smo) & (~s.prev_ratio_ok)
            sel = jax.lax.cond(
                use_exact,
                lambda: wss_mod.select_wss2_exact(G, row_i0, diag, alpha,
                                                  bounds, up, dn, i0, g_i0),
                lambda: wss_mod.select_wss2(G, row_i0, diag, up, dn, i0, g_i0))
        else:
            use_exact = jnp.asarray(False)
            sel = wss_mod.select_wss2(G, row_i0, diag, up, dn, i0, g_i0)

        bi, bj, best_gain = sel.i, sel.j, sel.gain
        if cfg.algorithm == "pasmo":
            # Extra candidates: the working sets used for planning, i.e.
            # history entries 1..N (entry 0 is B^(t-1), the planning target).
            consider = ~s.p_smo
            for h in range(1, N + 1):
                ci, cj = s.hist_i[h], s.hist_j[h]
                valid = s.n_hist > h
                kcc = kernel.entry(ci, cj)
                kci, kcj = jnp.take(diag, ci), jnp.take(diag, cj)
                cg = jax.lax.cond(
                    use_exact,
                    lambda ci=ci, cj=cj, kci=kci, kcc=kcc, kcj=kcj:
                        wss_mod.candidate_exact_gain(ci, cj, G, kci, kcc, kcj,
                                                     alpha, bounds, up, dn),
                    lambda ci=ci, cj=cj, kci=kci, kcc=kcc, kcj=kcj:
                        wss_mod.candidate_newton_gain(ci, cj, G, kci, kcc,
                                                      kcj, up, dn))
                take = consider & valid & (cg > best_gain)
                bi = jnp.where(take, ci, bi)
                bj = jnp.where(take, cj, bj)
                best_gain = jnp.where(take, cg, best_gain)

        i, j = bi, bj
        row_i = jax.lax.cond(i == i0, lambda: row_i0,
                             lambda: kernel.row(i))
        row_j = kernel.row(j)

        # ------------------------------------------------------------------
        # Step computation (Alg. 4 / eq. 2 / §7.3)
        # ------------------------------------------------------------------
        l = jnp.take(G, i) - jnp.take(G, j)
        Kij = jnp.take(row_i, j)
        q11 = jnp.maximum(jnp.take(diag, i) - 2.0 * Kij + jnp.take(diag, j),
                          TAU)
        sb = step_mod.step_bounds(
            jnp.take(alpha, i), jnp.take(alpha, j),
            jnp.take(bounds.lower, i), jnp.take(bounds.upper, i),
            jnp.take(bounds.lower, j), jnp.take(bounds.upper, j))
        mu_star = l / q11

        if cfg.algorithm == "overshoot":
            mu_smo, free_smo = step_mod.overshoot_step(l, q11, sb,
                                                       cfg.overshoot)
        else:
            mu_smo, free_smo = step_mod.smo_step(l, q11, sb)

        do_plan = jnp.asarray(False)
        mu_plan = mu_smo
        any_feasible = jnp.asarray(False)
        if planning_enabled:
            allow = s.prev_free if cfg.algorithm == "pasmo" else s.p_smo
            best_g2 = jnp.asarray(-jnp.inf, dtype)
            for h in range(N):
                pi, pj = s.hist_i[h], s.hist_j[h]
                valid = s.n_hist > h
                w2 = jnp.take(G, pi) - jnp.take(G, pj)
                q22 = (jnp.take(diag, pi) - 2.0 * kernel.entry(pi, pj)
                       + jnp.take(diag, pj))
                q12 = (jnp.take(row_i, pi) - jnp.take(row_i, pj)
                       - jnp.take(row_j, pi) + jnp.take(row_j, pj))
                terms = step_mod.PlanningTerms(w1=l, w2=w2, Q11=q11,
                                               Q22=q22, Q12=q12)
                mu1, okdet = step_mod.planning_step(terms)
                mu2 = step_mod.planned_second_step(mu1, terms)
                interior1 = (sb.lo < mu1) & (mu1 < sb.hi)
                d_pi = ((pi == i).astype(dtype) - (pi == j).astype(dtype))
                d_pj = ((pj == i).astype(dtype) - (pj == j).astype(dtype))
                sb2 = step_mod.step_bounds(
                    jnp.take(alpha, pi) + mu1 * d_pi,
                    jnp.take(alpha, pj) + mu1 * d_pj,
                    jnp.take(bounds.lower, pi), jnp.take(bounds.upper, pi),
                    jnp.take(bounds.lower, pj), jnp.take(bounds.upper, pj))
                interior2 = (sb2.lo < mu2) & (mu2 < sb2.hi)
                g2 = step_mod.double_step_gain(mu1, terms)
                feasible = okdet & interior1 & interior2 & valid
                better = feasible & (g2 > best_g2)
                best_g2 = jnp.where(better, g2, best_g2)
                mu_plan = jnp.where(better, mu1, mu_plan)
                any_feasible = any_feasible | feasible
            do_plan = allow & any_feasible

        mu2v = jnp.asarray(0.0, dtype)
        if conjugate:
            # Conjugate-SMO step: solve the exact 2x2 subproblem on the
            # current WSS direction v1 = e_i - e_j and the previous update
            # direction v2 = e_pi - e_pj.  Q v2 is carried in ``dir_u`` from
            # the previous iteration, so all five restriction terms are O(1)
            # gathers — no extra kernel rows.
            cpi, cpj = s.hist_i[0], s.hist_j[0]
            w2 = jnp.take(G, cpi) - jnp.take(G, cpj)
            q22 = jnp.take(s.dir_u, cpi) - jnp.take(s.dir_u, cpj)
            q12 = jnp.take(s.dir_u, i) - jnp.take(s.dir_u, j)
            terms = step_mod.PlanningTerms(w1=l, w2=w2, Q11=q11, Q22=q22,
                                           Q12=q12)
            mu1c, mu2c, okdet = step_mod.conjugate_step(terms)

            def moved(c):
                # net displacement of coordinate c under (mu1c v1 + mu2c v2);
                # indicator arithmetic handles overlapping pairs exactly
                return (mu1c * ((c == i).astype(dtype)
                                - (c == j).astype(dtype))
                        + mu2c * ((c == cpi).astype(dtype)
                                  - (c == cpj).astype(dtype)))

            def interior(c):
                a_c = jnp.take(alpha, c) + moved(c)
                return ((jnp.take(bounds.lower, c) < a_c)
                        & (a_c < jnp.take(bounds.upper, c)))

            inter = interior(i) & interior(j) & interior(cpi) & interior(cpj)
            # exact gain of the unconstrained 2-direction step; must dominate
            # the 1-D Newton gain along v1 (true for a PD 2x2 system — the
            # check guards near-degenerate numerics only)
            g2 = 0.5 * (l * mu1c + w2 * mu2c)
            g1 = step_mod.gain_newton(l, q11)
            accept = (s.conj_ok & (s.n_hist >= 1) & okdet & inter
                      & (g2 + TAU >= g1))
            do_plan = accept
            mu_plan = mu1c
            mu2v = jnp.where(accept, mu2c, jnp.asarray(0.0, dtype))

        mu = jnp.where(do_plan, mu_plan, mu_smo)
        reverted = (s.prev_free if cfg.algorithm == "pasmo" else s.p_smo)
        reverted = reverted & ~do_plan & jnp.asarray(planning_enabled)

        # ------------------------------------------------------------------
        # Update (steps 2-3 of Alg. 1)
        # ------------------------------------------------------------------
        alpha_new = alpha.at[i].add(mu).at[j].add(-mu)
        G_new = G - mu * (row_i - row_j)
        if conjugate:
            # rejected conjugate steps have mu2v == 0, so the extra scatter /
            # axpy are exact no-ops and G stays bitwise on the SMO trajectory
            alpha_new = alpha_new.at[cpi].add(mu2v).at[cpj].add(-mu2v)
            G_new = G_new - mu2v * s.dir_u

        # ------------------------------------------------------------------
        # Bookkeeping, shrinking, stopping
        # ------------------------------------------------------------------
        ratio = mu_plan / jnp.where(jnp.abs(mu_star) > 0, mu_star, 1.0)
        ratio_ok = (ratio >= 1.0 - eta) & (ratio <= 1.0 + eta)
        # slice+concat roll: jnp.roll would mint an int64 gather-index
        # vector under x64, leaking off the int32 index channel
        hist_i = jnp.concatenate([i[None], s.hist_i[:-1]])
        hist_j = jnp.concatenate([j[None], s.hist_j[:-1]])

        if cfg.record_trace:
            slot = jnp.minimum(s.n_trace, cfg.trace_cap - 1)
            traced = jnp.where(do_plan, ratio, jnp.take(s.trace, slot))
            trace = s.trace.at[slot].set(traced)
            n_trace = s.n_trace + do_plan.astype(jnp.int32)
        else:
            trace, n_trace = s.trace, s.n_trace

        if cfg.record_steps:
            slot = jnp.minimum(s.t, cfg.step_cap - 1)
            steps_i = s.steps_i.at[slot].set(i)
            steps_j = s.steps_j.at[slot].set(j)
            steps_mu = s.steps_mu.at[slot].set(mu)
        else:
            steps_i, steps_j, steps_mu = s.steps_i, s.steps_j, s.steps_mu

        active = s.active
        refresh = unshrunk = jnp.asarray(False)
        if cfg.shrink_every > 0:
            refresh = (s.t % cfg.shrink_every) == (cfg.shrink_every - 1)
            active = jnp.where(refresh, _shrink_mask(G_new, alpha_new, bounds),
                               active)
            gap_masked = qp_mod.finite_gap(
                qp_mod.kkt_gap(G_new, alpha_new, bounds, active))
            # unshrink when the masked problem looks solved
            unshrunk = gap_masked <= eps
            active = jnp.where(unshrunk, jnp.ones_like(active), active)

        if conjugate:
            # Q v of this step's WSS direction, for the next iteration's 2x2
            # restriction.  Reset-on-clip convention (arXiv 2003.08719): the
            # direction survives only through free steps — a clipped fallback
            # or any shrink-mask refresh / unshrink event clears it.
            dir_u = row_i - row_j
            conj_ok = do_plan | free_smo
            if cfg.shrink_every > 0:
                conj_ok = conj_ok & ~refresh & ~unshrunk
        else:
            dir_u, conj_ok = s.dir_u, s.conj_ok

        gap = qp_mod.finite_gap(qp_mod.kkt_gap(G_new, alpha_new, bounds))
        done = gap <= eps

        return SolverState(
            alpha=alpha_new, G=G_new, t=s.t + 1, done=done, gap=gap,
            hist_i=hist_i, hist_j=hist_j,
            n_hist=jnp.minimum(s.n_hist + 1, N + 1),
            p_smo=~do_plan,
            prev_free=(~do_plan) & free_smo,
            prev_ratio_ok=jnp.where(do_plan, ratio_ok, s.prev_ratio_ok),
            dir_u=dir_u, conj_ok=conj_ok,
            active=active,
            n_planning=s.n_planning + do_plan.astype(jnp.int32),
            n_free=s.n_free + ((~do_plan) & free_smo).astype(jnp.int32),
            n_clipped=s.n_clipped + ((~do_plan) & ~free_smo).astype(jnp.int32),
            n_reverted=s.n_reverted + reverted.astype(jnp.int32),
            trace=trace, n_trace=n_trace,
            steps_i=steps_i, steps_j=steps_j, steps_mu=steps_mu)

    return body


def init_state(kernel, p, bounds: Bounds, cfg: SolverConfig,
               alpha0: Optional[jax.Array] = None,
               G0: Optional[jax.Array] = None) -> SolverState:
    n = p.shape[0]
    dtype = p.dtype
    if alpha0 is None:
        # grad f(0) = p: no kernel evaluations (paper §2).  NOTE: alpha = 0
        # must be feasible for this default (true for classification/SVR;
        # the one-class equality sum(a) = 1 needs an explicit alpha0).
        alpha0 = jnp.zeros_like(p)
        G0 = p
    elif G0 is None:
        # Reconstruct grad f(a0) = p - Q a0 through the oracle (one matvec).
        # Warm starts across a C-grid reuse the previous G instead (free).
        G0 = p - kernel.matvec(alpha0)
    N = cfg.plan_candidates
    cap = cfg.trace_cap if cfg.record_trace else 1
    scap = cfg.step_cap if cfg.record_steps else 1
    gap = qp_mod.finite_gap(qp_mod.kkt_gap(G0, alpha0, bounds))
    return SolverState(
        alpha=alpha0, G=G0, t=jnp.asarray(0, jnp.int32),
        done=gap <= cfg.eps, gap=gap,
        hist_i=jnp.zeros((N + 1,), jnp.int32),
        hist_j=jnp.zeros((N + 1,), jnp.int32),
        n_hist=jnp.asarray(0, jnp.int32),
        p_smo=jnp.asarray(True), prev_free=jnp.asarray(False),
        prev_ratio_ok=jnp.asarray(True),
        # (1,) placeholder when the conjugate step is off (trace-cap trick)
        dir_u=jnp.zeros((n if cfg.step == "conjugate" else 1,), dtype),
        conj_ok=jnp.asarray(False),
        active=jnp.ones((n,), bool),
        n_planning=jnp.asarray(0, jnp.int32),
        n_free=jnp.asarray(0, jnp.int32),
        n_clipped=jnp.asarray(0, jnp.int32),
        n_reverted=jnp.asarray(0, jnp.int32),
        trace=jnp.zeros((cap,), dtype), n_trace=jnp.asarray(0, jnp.int32),
        steps_i=jnp.zeros((scap,), jnp.int32),
        steps_j=jnp.zeros((scap,), jnp.int32),
        steps_mu=jnp.zeros((scap,), dtype))


def _finalize(s: SolverState, p, bounds: Bounds) -> SolveResult:
    up = qp_mod.up_mask(s.alpha, bounds)
    dn = qp_mod.down_mask(s.alpha, bounds)
    g_up = jnp.max(jnp.where(up, s.G, -jnp.inf))
    g_dn = jnp.min(jnp.where(dn, s.G, jnp.inf))
    b = qp_mod.safe_bias(g_up, g_dn)
    # f(a) = p.a - 1/2 a.Q a = 1/2 (p.a + G.a)  since G = p - Q a
    objective = 0.5 * (jnp.dot(p, s.alpha) + jnp.dot(s.G, s.alpha))
    n_free_sv = jnp.sum((s.alpha > bounds.lower)
                        & (s.alpha < bounds.upper), dtype=jnp.int32)
    return SolveResult(
        alpha=s.alpha, b=b, G=s.G, iterations=s.t, objective=objective,
        kkt_gap=s.gap, converged=s.done,
        n_planning=s.n_planning, n_free=s.n_free, n_clipped=s.n_clipped,
        n_reverted=s.n_reverted, n_free_sv=n_free_sv,
        trace=s.trace, n_trace=s.n_trace,
        steps_i=s.steps_i, steps_j=s.steps_j, steps_mu=s.steps_mu)


@partial(jax.jit, static_argnames=("cfg", "shrinking"))
def solve_qp(kernel, qp: qp_mod.DualQP, cfg: SolverConfig = SolverConfig(),
             alpha0: Optional[jax.Array] = None,
             G0: Optional[jax.Array] = None, *,
             shrinking: Optional[bool] = None) -> SolveResult:
    """Solve a general :class:`~repro.core.qp.DualQP` (``max p.a - 1/2
    a.Q a`` over a box with one equality constraint).

    ``kernel`` is any oracle from :mod:`repro.core.qp` (pytree) — for
    ε-SVR wrap the base oracle in :class:`~repro.core.qp.DoubledKernel`.
    Problems whose feasible set does not contain 0 (one-class) must pass a
    feasible ``alpha0`` (``G0`` is reconstructed by one matvec if
    omitted).  jit-compiled; ``qp`` is traced data, so heterogeneous
    batches vmap over one compilation.  ``shrinking`` overrides
    ``cfg.shrink_every`` (see :func:`resolve_shrink_cfg`): ``True`` enables
    the soft active-set mask, ``False`` disables it, ``None`` (default)
    respects the config.
    """
    cfg = resolve_shrink_cfg(cfg, shrinking)
    p = jnp.asarray(qp.p)
    bounds = qp.bounds
    diag = kernel.diag().astype(p.dtype)
    body = _make_body(kernel, p, bounds, diag, cfg)
    s0 = init_state(kernel, p, bounds, cfg, alpha0, G0)

    def cond(s: SolverState):
        return (~s.done) & (s.t < cfg.max_iter)

    s = jax.lax.while_loop(cond, body, s0)
    return _finalize(s, p, bounds)


def solve(kernel, y: jax.Array, C, cfg: SolverConfig = SolverConfig(),
          alpha0: Optional[jax.Array] = None,
          G0: Optional[jax.Array] = None, *,
          shrinking: Optional[bool] = None) -> SolveResult:
    """Solve the dual SVM classification QP (eq. 1): the ``p = y`` instance
    of :func:`solve_qp`.

    ``C`` is a scalar budget or an (l,) per-sample vector (class-weighted
    SVC).  ``shrinking=True|False`` overrides ``cfg.shrink_every`` (see
    :func:`resolve_shrink_cfg`).  Returns a :class:`SolveResult`.
    jit-compiled; vmap over a batch of QPs with e.g.
    ``jax.vmap(lambda K, y: solve(PrecomputedKernel(K), y, C, cfg))``.
    """
    y = jnp.asarray(y)
    qp = qp_mod.classification_qp(y, jnp.asarray(C, y.dtype))
    return solve_qp(kernel, qp, cfg, alpha0, G0, shrinking=shrinking)


def solve_batched(Ks: jax.Array, ys: jax.Array, C,
                  cfg: SolverConfig = SolverConfig(), *,
                  shrinking: Optional[bool] = None) -> SolveResult:
    """vmap-batched solve over a stack of precomputed-kernel QPs.

    ``Ks``: (B, l, l); ``ys``: (B, l); ``C``: scalar or (B,) per-problem
    budgets (C is a traced argument, so heterogeneous batches share one
    compilation).  One-vs-rest multiclass and C-grid sweeps are batched QPs
    with a shared or stacked Gram matrix — the TPU throughput mode of the
    solver (DESIGN.md §3); see :mod:`repro.core.multiclass` and
    :mod:`repro.core.grid` for the shared-Gram front-ends.
    """
    ys = jnp.asarray(ys)
    Cs = jnp.broadcast_to(jnp.asarray(C, ys.dtype), ys.shape[:1])

    def one(K, y, c):
        return solve(qp_mod.PrecomputedKernel(K), y, c, cfg,
                     shrinking=shrinking)

    return jax.vmap(one)(jnp.asarray(Ks), ys, Cs)
