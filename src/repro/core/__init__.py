# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
#
# Layout: qp (problem + kernel oracles), step/wss (per-iteration algebra),
# solver (the while_loop driver), multiclass/grid (batched multi-QP layers),
# solver_fused/sharded (fused and distributed variants), reference (numpy
# oracle).
