"""Fault tolerance runtime: straggler detection, failure injection, and the
resilient step loop (checkpoint / restore / replay).

On a real pod this wraps the per-host training process: the step-time EWMA
flags stragglers (a slow host shows up as a slow collective everywhere, so
every host sees it), the deadline triggers a checkpoint-and-abort so the
scheduler can replace the bad host, and ``run_resilient`` restarts from the
last committed checkpoint replaying the data pipeline by step index (the
pipeline is stateless/indexable — DESIGN.md §7).  In tests, failures are
injected deterministically and the loop must produce bit-identical final
state vs an uninterrupted run.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint)


@dataclasses.dataclass
class StepMonitor:
    """EWMA step-time tracker with straggler deadline."""

    alpha: float = 0.1
    deadline_factor: float = 3.0
    warmup_steps: int = 3
    ewma: Optional[float] = None
    count: int = 0
    slow_steps: int = 0

    def record(self, dt: float) -> bool:
        """Record one step duration; returns True when the step breached the
        straggler deadline (caller decides: log, re-shard, or abort)."""
        self.count += 1
        if self.count <= self.warmup_steps:
            # compilation / warmup steps don't contaminate the EWMA
            return False
        if self.ewma is None:
            self.ewma = dt
            return False
        breached = dt > self.deadline_factor * self.ewma
        if breached:
            self.slow_steps += 1
        # clamp outliers so one straggler doesn't poison the baseline
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * min(
            dt, 2 * self.ewma)
        return breached

    @property
    def deadline(self) -> Optional[float]:
        return None if self.ewma is None \
            else self.deadline_factor * self.ewma


class FailureInjector:
    """Deterministic failure schedule for tests: raises at given steps."""

    def __init__(self, fail_at=()):
        self.fail_at = set(fail_at)
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected failure at step {step}")


def run_resilient(step_fn: Callable, init_state: Any, batch_at: Callable,
                  n_steps: int, ckpt_dir: str, save_every: int = 10,
                  injector: Optional[FailureInjector] = None,
                  max_restarts: int = 10,
                  monitor: Optional[StepMonitor] = None) -> Any:
    """Checkpointed training loop with restart-on-failure.

    ``step_fn(state, batch) -> (state, metrics)``; ``batch_at(step)`` is a
    pure function (replayable).  On failure: restore the last committed
    checkpoint and replay from there.  Returns the final state.
    """
    restarts = 0
    while True:
        ckpt = AsyncCheckpointer(ckpt_dir)
        try:
            start = latest_step(ckpt_dir)
            if start is None:
                state, step0 = init_state, 0
            else:
                state = restore_checkpoint(ckpt_dir, start, init_state)
                step0 = start
            for step in range(step0, n_steps):
                if injector is not None:
                    injector.maybe_fail(step)
                t0 = time.monotonic()
                state, _ = step_fn(state, batch_at(step))
                if monitor is not None:
                    jax.block_until_ready(jax.tree.leaves(state)[0])
                    monitor.record(time.monotonic() - t0)
                nxt = step + 1
                if nxt % save_every == 0 or nxt == n_steps:
                    ckpt.save(nxt, state)
            ckpt.close()
            return state
        except RuntimeError:
            ckpt.close()
            restarts += 1
            if restarts > max_restarts:
                raise
