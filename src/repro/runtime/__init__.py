from repro.runtime.fault import StepMonitor, FailureInjector, run_resilient
