"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8 experts top-2, sliding-window attention (4096).
[arXiv:2401.04088; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, n_experts=8, top_k=2,
    sliding_window=4096,
)


def smoke() -> ModelConfig:
    # capacity_factor 8 => no token drops at smoke sizes, so teacher-forced
    # and incremental decode agree exactly (capacity-drop MoE is otherwise
    # inconsistent between the two — DESIGN.md §5)
    return ModelConfig(
        name="mixtral-8x7b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, n_experts=4, top_k=2, sliding_window=64,
        capacity_factor=8.0,
    )
