"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (GQA kv=1, MQA)
d_ff=7680 — RG-LRU + local attention, (rec, rec, attn) 1:2 pattern,
window 2048, vocab=256000.  [arXiv:2402.19427; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab=256000,
    rglru_pattern=3, local_window=2048, rglru_width=2560,
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b-smoke", family="hybrid",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=128, vocab=256,
        rglru_pattern=3, local_window=32, rglru_width=64,
        tie_embeddings=True,
    )
