"""internvl2-1b [vlm]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 — InternViT (stub patch embeddings) + InternLM2/qwen2-class LM
backbone.  [arXiv:2404.16821; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab=151655, qkv_bias=True,
    vision_tokens=256, frontend="vision_stub", tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b-smoke", family="vlm",
        n_layers=2, d_model=56, n_heads=7, n_kv_heads=1,
        d_ff=128, vocab=256, qkv_bias=True,
        vision_tokens=8, frontend="vision_stub", tie_embeddings=True,
    )
