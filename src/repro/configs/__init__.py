"""Architecture config registry: ``get_config(arch)`` / ``get_smoke(arch)``.

Arch ids follow the assignment table; ``pasmo_svm`` is the paper's own
experiment configuration (solver + dataset grid, see repro.svm).
"""

from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (ModelConfig, ServeConfig, ShapeConfig,
                                SHAPES, TrainConfig, get_shape)

_MODULES: Dict[str, str] = {
    "stablelm-12b": "repro.configs.stablelm_12b",
    "qwen2.5-3b": "repro.configs.qwen2_5_3b",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "qwen2-0.5b": "repro.configs.qwen2_0_5b",
    "grok-1-314b": "repro.configs.grok_1_314b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "mamba2-370m": "repro.configs.mamba2_370m",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "internvl2-1b": "repro.configs.internvl2_1b",
}

ARCHS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch]).smoke()
