"""whisper-tiny [audio]: 4L d_model=384 6H d_ff=1536 vocab=51865 —
enc-dec, conv frontend (stub: precomputed frame embeddings).
[arXiv:2212.04356; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865,
    encoder_layers=4, encoder_seq=1500, frontend="audio_stub",
    tie_embeddings=True, use_rope=False,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny-smoke", family="encdec",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256,
        encoder_layers=2, encoder_seq=64, frontend="audio_stub",
        tie_embeddings=True, use_rope=False,
    )
