"""Config dataclasses: model architecture, training and serving shapes.

One ``ModelConfig`` per assigned architecture lives in
``repro/configs/<arch>.py`` with the exact public-literature dimensions;
each also exposes ``smoke()`` — a reduced same-family config for CPU tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None       # default d_model // n_heads
    qkv_bias: bool = False               # qwen-style attention bias
    use_rope: bool = True                # whisper uses absolute sinusoidal
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25

    # attention locality
    sliding_window: int = 0              # 0 = full causal attention

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4
    ssm_chunk: int = 256

    # hybrid (recurrentgemma): (rec, rec, attn) repeating pattern
    rglru_pattern: int = 0               # 3 => 1 attention per 3 layers
    local_window: int = 2048
    rglru_width: Optional[int] = None    # recurrence width (default d_model)

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 1500              # precomputed frame embeddings (stub)

    # VLM
    vision_tokens: int = 0               # stub patch embeddings prepended

    # modality frontend stub
    frontend: str = "none"               # none | audio_stub | vision_stub

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (harness rule)."""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window > 0)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for rooflines."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.head_dim
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads \
            + self.n_heads * hd * d
        mlp = 3 * d * f
        if self.family == "moe":
            mlp = mlp * self.n_experts + d * self.n_experts  # + router
        ssm = 0
        if self.family == "ssm":
            di = self.ssm_expand * d
            ssm = (d * 2 * di                # in_proj (x, z)
                   + di * 2 * self.ssm_state  # B, C proj
                   + di * self.conv_kernel + di  # conv + dt
                   + di * d)                 # out_proj
            attn = 0
            mlp = 0
        blocks = self.n_layers * (attn + mlp + ssm + 2 * d)
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "encdec":
            blocks += self.encoder_layers * (attn + mlp + 2 * d)
            blocks += self.n_layers * (attn + 2 * d)  # cross-attn
        return blocks + emb


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One harness input-shape cell."""

    name: str                        # train_4k | prefill_32k | ...
    kind: str                        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4_096, 256),
    ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    ShapeConfig("decode_32k", "decode", 32_768, 128),
    ShapeConfig("long_500k", "decode", 524_288, 1),
)


def get_shape(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Training-step configuration (the hillclimb knobs live here)."""

    seq_len: int = 4_096
    global_batch: int = 256
    microbatches: int = 1            # gradient accumulation steps
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    accum_dtype: str = "bfloat16"    # gradient accumulation buffer
    remat: str = "full"              # none | full | selective
    # "inside_grad": scan microbatches inside the differentiated loss, so
    # cross-data gradient reductions defer to one per step (§Perf grok
    # hillclimb); "outside": per-microbatch value_and_grad + manual
    # accumulation (baseline; reduces grads every microbatch).
    accum_mode: str = "inside_grad"
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    optimizer: str = "adamw"         # adamw | adafactor | sgdm
    compress_grads: bool = False     # int8 + error feedback all-reduce


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    seq_len: int = 32_768            # KV cache / state horizon
    batch: int = 128
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    kv_dtype: str = "bfloat16"
