"""Static-analysis subsystem: jaxpr auditor, recompile guard, repo linter.

The stack carries load-bearing invariants that exist only as convention:

* feature-off engine configs (``telemetry=None``, ``step="plain"``,
  ``act``/``masked`` off) must trace jaxprs structurally identical to the
  bare engine — the hot path pays nothing for features it does not use;
* integer indices ride an int32 channel and floats never weak-promote to
  f64 inside traced device code;
* per-problem ``(C, gamma)`` stay *traced* while ``SolverConfig`` stays
  static — a discipline regression shows up as one recompile per grid
  lane and silently erases the planning-ahead paper's cheap-iteration
  premium;
* solver return types (``SolveResult``/``FusedResult``) only widen
  through the telemetry seam.

``python -m repro.analysis`` checks all of them as three passes:

* :mod:`repro.analysis.jaxpr_audit` — traces the classic/fused/sharded
  engines across a config matrix and walks the jaxprs programmatically
  (structural equivalence vs ``tests/golden/structural.json``, dtype
  audit, host-callback scan, primitive/dtype census artifact);
* :mod:`repro.analysis.recompile_guard` — a tracing-cache probe that
  sweeps ``(C, gamma, B, l)`` and asserts the exact expected compile
  count per jit call site;
* :mod:`repro.analysis.lint_rules` — AST rules over the repo source
  (f64 literals in device code, Python ``if`` on traced carry state,
  widened result signatures, nondeterministic tests).

Every pass returns a list of :class:`Finding`; the CLI exits non-zero
when any pass finds one.  See ``README.md`` ("Static analysis") for the
rule table and the CI wiring.
"""

from repro.analysis.report import Finding

__all__ = ["Finding"]
