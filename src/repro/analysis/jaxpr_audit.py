"""Jaxpr auditor: walk engine traces programmatically instead of
byte-diffing pretty-printed goldens.

Three audits over a matrix of engine configurations (:data:`MATRIX`):

* **structural equivalence** — for the feature-off configs (telemetry
  off, ``step="plain"``, shrinking mask off) the traced jaxpr's
  *structural signature* — the equation-primitive multiset plus the
  ``while_loop`` carry pytree structure (leaf shapes/dtypes) — must match
  the signature pinned in ``tests/golden/structural.json``.  A widened
  carry (a feature leaking state into the hot loop) or a changed
  primitive census fails the audit with a named diff instead of a
  1461-line golden byte-diff.  Carry structure is stable across jax
  versions and is always compared; the primitive multiset depends on jax
  lowering details, so it is compared strictly only when the running jax
  version matches the one recorded in the golden.

* **dtype audit** — every matrix entry is re-traced with *float32*
  inputs under ``jax_enable_x64``.  In that regime any ``float64``
  equation output is a weak-type promotion leak (an unadorned np scalar
  or dtype-less constructor) and any ``int64`` output is a leak out of
  the int32 index channel (PR 5's contract; exactness past l = 2^24 and
  on-device index width both depend on it).  ``convert_element_type``
  equations targeting f64/int64 are reported individually — they are the
  usual smoking gun.

* **host-callback scan** — no callback primitives
  (``pure_callback``/``io_callback``/``debug_callback``/debug prints)
  may appear inside a ``while_loop`` body: a callback in the hot loop
  syncs the host every iteration.

:func:`emit_census` writes the per-entry primitive/dtype census as JSON
artifacts (uploaded by the CI ``static-analysis`` job) so trace drift is
observable over time even when no invariant fires.
"""

from __future__ import annotations

import json
import os
from collections import Counter

from repro.analysis.report import Finding

# Small, fixed trace problem: big enough to exercise every code path
# (selection, planning history, doubled halves), small enough that every
# trace is milliseconds.  The pinned entries reuse the byte-golden recipe
# (tests/golden/regen.py): l=16, d=4, B=3, C=2.0, seed 0.
AUDIT_L, AUDIT_D, AUDIT_B = 16, 4, 3

# Primitives that sync the host; forbidden inside while_loop bodies.
CALLBACK_PRIMS = ("pure_callback", "io_callback", "debug_callback",
                  "callback", "outside_call", "debug_print")


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------


def _sub_jaxprs(eqn):
    """Yield every (Closed)Jaxpr referenced by ``eqn``'s params.

    Covers pjit (``jaxpr``), while (``body_jaxpr``/``cond_jaxpr``), cond
    (``branches``), scan, custom_* wrappers and pallas_call — anything
    that stores a jaxpr or a list of them in its params.
    """
    for val in eqn.params.values():
        items = val if isinstance(val, (list, tuple)) else (val,)
        for item in items:
            if hasattr(item, "jaxpr") and hasattr(item.jaxpr, "eqns"):
                yield item.jaxpr          # ClosedJaxpr
            elif hasattr(item, "eqns"):
                yield item                # raw Jaxpr


def iter_eqns(jaxpr, path=()):
    """Depth-first (path, eqn) over ``jaxpr`` and every sub-jaxpr.

    ``path`` is the tuple of enclosing primitive names — e.g.
    ``("pjit", "while")`` for an equation inside the solve loop.
    """
    for eqn in jaxpr.eqns:
        yield path, eqn
        sub_path = path + (eqn.primitive.name,)
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub, sub_path)


def _closed_inner(closed):
    return getattr(closed, "jaxpr", closed)


def primitive_census(closed) -> dict[str, int]:
    """Multiset of equation primitives over the whole trace."""
    c = Counter()
    for _, eqn in iter_eqns(_closed_inner(closed)):
        c[eqn.primitive.name] += 1
    return dict(sorted(c.items()))


def dtype_census(closed) -> dict[str, int]:
    """Multiset of equation-output dtypes over the whole trace."""
    c = Counter()
    for _, eqn in iter_eqns(_closed_inner(closed)):
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "dtype"):
                c[str(aval.dtype)] += 1
    return dict(sorted(c.items()))


def while_carry_specs(closed) -> list[list[list]]:
    """Carry pytree structure of every ``while`` equation in the trace.

    Returns one entry per while_loop (document order): a list of
    ``[shape, dtype]`` pairs, one per carry leaf (the body jaxpr's
    non-constant invars).  This is the "did a feature widen the hot-loop
    carry" detector — it is independent of jaxpr pretty-printing and
    stable across jax versions.
    """
    out = []
    for _, eqn in iter_eqns(_closed_inner(closed)):
        if eqn.primitive.name != "while":
            continue
        body = eqn.params["body_jaxpr"].jaxpr
        nconsts = eqn.params["body_nconsts"]
        carry = body.invars[nconsts:]
        out.append([[list(v.aval.shape), str(v.aval.dtype)] for v in carry])
    return out


def signature(closed) -> dict:
    """Structural signature: primitive multiset + while-carry structure."""
    return {"primitives": primitive_census(closed),
            "carries": while_carry_specs(closed)}


# ---------------------------------------------------------------------------
# trace matrix
# ---------------------------------------------------------------------------


def _problem(dtype_name: str):
    import jax.numpy as jnp
    import numpy as np

    dtype = jnp.dtype(dtype_name)
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(AUDIT_L, AUDIT_D)), dtype)
    Y = jnp.asarray(np.sign(rng.normal(size=(AUDIT_B, AUDIT_L))), dtype)
    YC = Y * jnp.asarray(2.0, dtype)
    L, U = jnp.minimum(0.0, YC), jnp.maximum(0.0, YC)
    gam = jnp.asarray(rng.uniform(0.3, 1.0, AUDIT_B), dtype)
    return X, Y, L, U, gam


def _cfg(name: str):
    from repro.core.solver import SolverConfig

    return {
        "plain": lambda: SolverConfig(eps=1e-3, max_iter=500),
        "conjugate": lambda: SolverConfig(algorithm="smo", step="conjugate",
                                          eps=1e-3, max_iter=500),
        "pasmo": lambda: SolverConfig(algorithm="pasmo", eps=1e-3,
                                      max_iter=500),
    }[name]()


def _trace_fused(dtype_name, cfg_name, **kw):
    import jax

    from repro.core.solver_fused import solve_fused_batched_qp

    X, Y, L, U, gam = _problem(dtype_name)
    cfg = _cfg(cfg_name)
    return jax.make_jaxpr(
        lambda X, P, L, U, g: solve_fused_batched_qp(
            X, P, L, U, g, cfg, **kw))(X, Y, L, U, gam)


def _trace_fused_doubled(dtype_name, **kw):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import qp as qp_mod
    from repro.core.solver_fused import solve_fused_batched_qp

    X, _, _, _, gam = _problem(dtype_name)
    dtype = X.dtype
    rng = np.random.default_rng(1)
    y = jnp.asarray(rng.normal(size=(AUDIT_L,)), dtype)
    qp = qp_mod.svr_qp(y, 2.0, 0.1)
    P = jnp.broadcast_to(qp.p, (AUDIT_B, 2 * AUDIT_L))
    L = jnp.broadcast_to(qp.bounds.lower, (AUDIT_B, 2 * AUDIT_L))
    U = jnp.broadcast_to(qp.bounds.upper, (AUDIT_B, 2 * AUDIT_L))
    cfg = _cfg("plain")
    return jax.make_jaxpr(
        lambda X, P, L, U, g: solve_fused_batched_qp(
            X, P, L, U, g, cfg, doubled=True, **kw))(X, P, L, U, gam)


def _trace_fused_bank(dtype_name, **kw):
    import jax
    import jax.numpy as jnp

    from repro.core.solver_fused import solve_fused_batched_qp
    from repro.kernels import ops

    X, Y, L, U, gam = _problem(dtype_name)
    gram = ops.gram(X, X, gam[0])[None]
    gidx = jnp.zeros((AUDIT_B,), jnp.int32)
    cfg = _cfg("plain")
    return jax.make_jaxpr(
        lambda X, P, L, U, g, gram, gidx: solve_fused_batched_qp(
            X, P, L, U, g, cfg, gram=gram, gram_idx=gidx, **kw))(
        X, Y, L, U, gam, gram, gidx)


def _trace_classic(dtype_name, cfg_name):
    import jax

    from repro.core import qp as qp_mod
    from repro.core.solver import solve
    from repro.kernels import ops

    X, Y, _, _, gam = _problem(dtype_name)
    K = ops.gram(X, X, gam[0])
    y = Y[0]
    cfg = _cfg(cfg_name)
    return jax.make_jaxpr(
        lambda K, y: solve(qp_mod.PrecomputedKernel(K), y, 2.0, cfg))(K, y)


def _trace_sharded(dtype_name):
    import jax

    from repro.core.sharded_lanes import (resolve_lane_mesh,
                                          solve_fused_sharded_qp)

    X, Y, L, U, gam = _problem(dtype_name)
    mesh = resolve_lane_mesh(None, jax.devices()[:1])
    cfg = _cfg("plain")
    return jax.make_jaxpr(
        lambda X, P, L, U, g: solve_fused_sharded_qp(
            X, P, L, U, g, cfg, mesh=mesh, impl="jnp"))(X, Y, L, U, gam)


def _trace_telemetry(dtype_name, **kw):
    from repro.telemetry import RingConfig

    return _trace_fused(dtype_name, "plain",
                        telemetry=RingConfig(sample_every=8), **kw)


# name -> (tracer, pinned).  Pinned entries have their structural
# signature recorded in tests/golden/structural.json — they are the
# feature-off configurations whose trace must never drift when a new
# Python-gated feature lands (the byte-golden recipe, structurally).
MATRIX = {
    "plain_jnp": (lambda d: _trace_fused(d, "plain", impl="jnp"), True),
    "plain_shrink_jnp": (lambda d: _trace_fused(
        d, "plain", impl="jnp", shrinking=True), True),
    "plain_interpret": (lambda d: _trace_fused(
        d, "plain", impl="interpret", block_l=8), True),
    "conjugate_jnp": (lambda d: _trace_fused(
        d, "conjugate", impl="jnp"), True),
    "conjugate_interpret": (lambda d: _trace_fused(
        d, "conjugate", impl="interpret", block_l=8), True),
    "pasmo_jnp": (lambda d: _trace_fused(d, "pasmo", impl="jnp"), False),
    "telemetry_jnp": (lambda d: _trace_telemetry(d, impl="jnp"), False),
    "doubled_jnp": (lambda d: _trace_fused_doubled(d, impl="jnp"), False),
    "doubled_interpret": (lambda d: _trace_fused_doubled(
        d, impl="interpret", block_l=8), False),
    "bank_jnp": (lambda d: _trace_fused_bank(d, impl="jnp"), False),
    "classic_smo": (lambda d: _trace_classic(d, "plain"), False),
    "classic_pasmo": (lambda d: _trace_classic(d, "pasmo"), False),
    "sharded_plain": (lambda d: _trace_sharded(d), False),
}

PINNED = tuple(k for k, (_, pinned) in MATRIX.items() if pinned)


def trace_entry(name: str, dtype_name: str = "float64"):
    tracer, _ = MATRIX[name]
    return tracer(dtype_name)


# ---------------------------------------------------------------------------
# audits
# ---------------------------------------------------------------------------


def audit_dtypes(closed, entry: str,
                 expect_float: str = "float32") -> list[Finding]:
    """Flag f64 weak-type promotion and int64 index leaks in one trace.

    The trace must have been built from ``expect_float`` inputs with
    ``jax_enable_x64`` on — then every float64 output is a promotion the
    input dtype did not ask for, and every int64 output left the int32
    index channel.
    """
    findings = []
    assert expect_float == "float32", "the probe traces f32 inputs"
    for path, eqn in iter_eqns(_closed_inner(closed)):
        loc = "/".join(path) or "<top>"
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is None or not hasattr(aval, "dtype"):
                continue
            dt = str(aval.dtype)
            if dt == "float64":
                findings.append(Finding(
                    "dtype-f64", entry,
                    f"{eqn.primitive.name} at {loc} produces float64 from "
                    f"float32 inputs (weak-type promotion leak)"))
            elif dt == "int64":
                findings.append(Finding(
                    "dtype-int64", entry,
                    f"{eqn.primitive.name} at {loc} produces int64 "
                    f"(index left the int32 channel)"))
        if eqn.primitive.name == "convert_element_type":
            new = str(eqn.params.get("new_dtype", ""))
            if new in ("float64", "int64"):
                findings.append(Finding(
                    "dtype-convert", entry,
                    f"convert_element_type -> {new} at {loc}"))
    return findings


def audit_callbacks(closed, entry: str) -> list[Finding]:
    """No host-callback primitives inside while_loop bodies."""
    findings = []
    for path, eqn in iter_eqns(_closed_inner(closed)):
        name = eqn.primitive.name
        if "while" not in path:
            continue
        if name in CALLBACK_PRIMS or "callback" in name:
            findings.append(Finding(
                "host-callback", entry,
                f"{name} inside while_loop body at {'/'.join(path)} — "
                f"host sync every iteration"))
    return findings


def compare_signature(got: dict, want: dict, entry: str,
                      strict_primitives: bool = True) -> list[Finding]:
    """Structural diff of two signatures, rendered as findings."""
    findings = []
    gc, wc = got["carries"], want["carries"]
    if len(gc) != len(wc):
        findings.append(Finding(
            "struct-carry", entry,
            f"{len(gc)} while_loop(s) traced, golden has {len(wc)}"))
    else:
        for k, (g, w) in enumerate(zip(gc, wc)):
            if g == w:
                continue
            if len(g) != len(w):
                findings.append(Finding(
                    "struct-carry", entry,
                    f"while_loop #{k} carry widened: {len(g)} leaves vs "
                    f"{len(w)} in the golden (a feature leaked state "
                    f"into the feature-off hot loop)"))
            else:
                diffs = [f"leaf {n}: {tuple(a[0])}/{a[1]} vs "
                         f"{tuple(b[0])}/{b[1]}"
                         for n, (a, b) in enumerate(zip(g, w)) if a != b]
                findings.append(Finding(
                    "struct-carry", entry,
                    f"while_loop #{k} carry leaf specs drifted: "
                    + "; ".join(diffs[:4])))
    if strict_primitives and got["primitives"] != want["primitives"]:
        gp, wp = got["primitives"], want["primitives"]
        delta = []
        for prim in sorted(set(gp) | set(wp)):
            a, b = gp.get(prim, 0), wp.get(prim, 0)
            if a != b:
                delta.append(f"{prim}: {b} -> {a}")
        findings.append(Finding(
            "struct-prims", entry,
            "primitive census drifted vs golden: " + ", ".join(delta)))
    return findings


def default_golden_path(root: str | None = None) -> str:
    root = root or repo_root()
    return os.path.join(root, "tests", "golden", "structural.json")


def repo_root() -> str:
    """Best-effort repo root: the checkout this package was imported
    from, else the current directory (installed-package fallback)."""
    here = os.path.dirname(os.path.abspath(__file__))
    cand = os.path.abspath(os.path.join(here, "..", "..", ".."))
    for marker in ("pyproject.toml", "pytest.ini"):
        if os.path.exists(os.path.join(cand, marker)):
            return cand
    return os.getcwd()


def emit_golden(path: str) -> None:
    """(Re)write the pinned structural signatures.

    Run after an INTENTIONAL trace change to the feature-off engine, and
    review the JSON diff — it is the structural counterpart of
    ``tests/golden/regen.py`` for the byte fixtures.
    """
    import jax

    assert jax.config.jax_enable_x64, "capture requires jax_enable_x64"
    entries = {name: signature(trace_entry(name)) for name in PINNED}
    payload = {"jax": jax.__version__, "entries": entries}
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
        fh.write("\n")


def audit_structure(golden_path: str | None = None) -> list[Finding]:
    """Feature-off structural equivalence vs the pinned golden.

    Also re-traces the plain config after tracing the feature-on configs
    and asserts the signature is unchanged (no tracing-cache bleed) and
    that the telemetry ring really widens the carry when ON (the audit
    itself would be vacuous if both traces looked alike).
    """
    import jax

    golden_path = golden_path or default_golden_path()
    if not os.path.exists(golden_path):
        return [Finding(
            "struct-golden", golden_path,
            "structural golden missing — regenerate with "
            "`python -m repro.analysis --emit-golden`")]
    with open(golden_path) as fh:
        golden = json.load(fh)
    strict = golden.get("jax") == jax.__version__
    if not strict:
        print(f"jaxpr_audit: golden captured on jax {golden.get('jax')}, "
              f"running {jax.__version__} — primitive census compared "
              f"report-only, carry structure still strict")
    findings = []
    sigs = {}
    for name in PINNED:
        want = golden["entries"].get(name)
        if want is None:
            findings.append(Finding(
                "struct-golden", name,
                "pinned entry missing from the structural golden — "
                "regenerate it"))
            continue
        sigs[name] = signature(trace_entry(name))
        findings.extend(compare_signature(
            sigs[name], want, name, strict_primitives=strict))

    # feature-on sanity: the ring must widen the carry (otherwise the
    # equivalence audit above proves nothing) ...
    on = signature(trace_entry("telemetry_jnp"))
    base = sigs.get("plain_jnp")
    if base is not None:
        if on["carries"] == base["carries"]:
            findings.append(Finding(
                "struct-feature", "telemetry_jnp",
                "telemetry=RingConfig() did not widen the while carry — "
                "the ring is not riding the loop"))
        # ... and re-tracing plain afterwards must reproduce the same
        # structure (no tracing-cache bleed between configs).
        again = signature(trace_entry("plain_jnp"))
        if again != base:
            findings.append(Finding(
                "struct-invariance", "plain_jnp",
                "plain signature changed after tracing feature-on "
                "configs in-process"))
    return findings


def audit_all_dtypes(names=None) -> list[Finding]:
    """Dtype + callback audit across the matrix (f32 probe inputs)."""
    findings = []
    for name in names or MATRIX:
        closed = trace_entry(name, "float32")
        findings.extend(audit_dtypes(closed, name))
        findings.extend(audit_callbacks(closed, name))
    return findings


def emit_census(out_dir: str, names=None, dtype_name: str = "float64"):
    """Write one census JSON per matrix entry; returns the paths."""
    import jax

    os.makedirs(out_dir, exist_ok=True)
    paths = []
    for name in names or MATRIX:
        closed = trace_entry(name, dtype_name)
        payload = {
            "entry": name,
            "jax": jax.__version__,
            "input_dtype": dtype_name,
            "primitives": primitive_census(closed),
            "dtypes": dtype_census(closed),
            "carries": while_carry_specs(closed),
        }
        path = os.path.join(out_dir, f"census_{name}.json")
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=1, sort_keys=True)
            fh.write("\n")
        paths.append(path)
    return paths


# ---------------------------------------------------------------------------
# planted violations (negative controls for the CLI / tests)
# ---------------------------------------------------------------------------


def plant_f64() -> list:
    """Trace the plain engine with a deliberate f64 round-trip on the
    linear term; the dtype audit MUST flag it (requires x64 enabled,
    otherwise the planted cast is a no-op)."""
    import jax
    import jax.numpy as jnp

    from repro.core.solver_fused import solve_fused_batched_qp

    assert jax.config.jax_enable_x64, "plant_f64 needs JAX_ENABLE_X64"
    X, Y, L, U, gam = _problem("float32")
    cfg = _cfg("plain")
    closed = jax.make_jaxpr(
        lambda X, P, L, U, g: solve_fused_batched_qp(
            X, P.astype(jnp.float64).astype(P.dtype), L, U, g, cfg,
            impl="jnp"))(X, Y, L, U, gam)
    return audit_dtypes(closed, "plant:f64")


def plant_widened_carry() -> list:
    """Compare the telemetry-ON trace against the plain signature: the
    ring widens the while carry, so the structural check MUST flag it."""
    got = signature(trace_entry("telemetry_jnp"))
    want = signature(trace_entry("plain_jnp"))
    return compare_signature(got, want, "plant:carry",
                             strict_primitives=False)


def assert_structural(name: str, golden_path: str | None = None) -> None:
    """pytest helper: assert matrix entry ``name`` matches the structural
    golden (carry pytree always; primitive multiset only on the pinned
    jax version, mirroring the retired byte-golden skip)."""
    import jax

    with open(golden_path or default_golden_path()) as fh:
        golden = json.load(fh)
    strict = golden["jax"] == jax.__version__
    got = signature(trace_entry(name))
    finds = compare_signature(got, golden["entries"][name], name,
                              strict_primitives=strict)
    assert not finds, "\n".join(f.render() for f in finds)
