"""Repo-invariant linter: AST rules the generic linters cannot express.

Runs without jax (pure ``ast``), so it works on boxes with no working
accelerator install.  Rules:

``RA001`` *f64 in device code* — no ``jnp.float64`` in
    ``src/repro/{core,kernels}`` outside function-signature defaults
    (caller-facing dtype defaults are API, not traced code), and no
    ``np.float64`` in ``kernels/`` at all.  In ``core/`` the np form is
    allowed only in the documented host-side drivers
    (``reference.py``, ``grid.py``, ``solver_fused.py`` — numpy
    accumulators never enter a trace).  Suppress a deliberate use with a
    ``# static-ok: f64`` line comment.

``RA002`` *Python branch on traced carry* — inside a solver-loop
    ``body``/``cond`` function, a Python ``if``/``while`` whose test
    reads the carry parameter is a tracer leak (it burns the trace into
    one branch or crashes under jit).  Data branches belong in
    ``jnp.where``/``lax.cond``.

``RA003`` *widened result signature* — ``SolveResult`` /
    ``FusedResult`` field lists are pinned.  New per-iteration outputs
    route through the telemetry ring seam (PR 8), not through the result
    structs every caller unpacks.

``RA004`` *nondeterministic tests* — tests draw randomness from seeded
    ``np.random.default_rng(seed)`` generators only; bare legacy-global
    draws, unseeded generators, stdlib ``random`` without a seed, and
    wall-clock reads (``time.time``, ``datetime.now``) are flagged.
"""

from __future__ import annotations

import ast
import pathlib
from typing import List, Optional

from repro.analysis.report import Finding

SUPPRESS_F64 = "static-ok: f64"

DEVICE_PREFIXES = ("src/repro/core/", "src/repro/kernels/")
HOST_F64_CORE = (
    "src/repro/core/reference.py",
    "src/repro/core/grid.py",
    "src/repro/core/solver_fused.py",
)
# The telemetry-seam convention (PR 8): these are the ONLY result fields.
RESULT_PINS = {
    "SolveResult": (
        "alpha", "b", "G", "iterations", "objective", "kkt_gap",
        "converged", "n_planning", "n_free", "n_clipped", "n_reverted",
        "n_free_sv", "trace", "n_trace", "steps_i", "steps_j", "steps_mu"),
    "FusedResult": (
        "alpha", "b", "G", "iterations", "objective", "kkt_gap",
        "converged", "n_planning", "n_unshrink"),
}

WALLCLOCK_CALLS = {("time", "time"), ("datetime", "now"),
                   ("date", "today")}


def repo_root() -> pathlib.Path:
    p = pathlib.Path(__file__).resolve()
    for parent in p.parents:
        if (parent / "pyproject.toml").exists():
            return parent
    raise RuntimeError("pyproject.toml not found above " + str(p))


def _attr_chain(node: ast.AST) -> Optional[str]:
    """Dotted name of an attribute chain (``jnp.float64``), else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _signature_default_nodes(tree: ast.AST) -> set:
    """ids of every node inside a function-signature default expression."""
    out = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defaults = list(node.args.defaults)
            defaults += [d for d in node.args.kw_defaults if d is not None]
            for d in defaults:
                for sub in ast.walk(d):
                    out.add(id(sub))
    return out


def _suppressed(lines: List[str], lineno: int) -> bool:
    return (0 < lineno <= len(lines)
            and SUPPRESS_F64 in lines[lineno - 1])


def _rule_f64(tree, rel: str, lines, findings: List[Finding]) -> None:
    if not rel.startswith(DEVICE_PREFIXES):
        return
    in_kernels = rel.startswith("src/repro/kernels/")
    defaults = _signature_default_nodes(tree)
    for node in ast.walk(tree):
        chain = _attr_chain(node)
        if chain not in ("jnp.float64", "np.float64", "numpy.float64"):
            continue
        if _suppressed(lines, node.lineno):
            continue
        if chain == "jnp.float64":
            if id(node) in defaults:
                continue                     # caller-facing dtype default
        else:
            if not in_kernels and rel in HOST_F64_CORE:
                continue                     # documented host-side driver
        findings.append(Finding(
            "RA001", f"{rel}:{node.lineno}",
            f"{chain} in device code (traced math is f32/f64-agnostic "
            "via input dtype; host drivers are allowlisted; suppress a "
            f"deliberate use with '# {SUPPRESS_F64}')"))


def _references(node: ast.AST, name: str) -> bool:
    return any(isinstance(sub, ast.Name) and sub.id == name
               for sub in ast.walk(node))


def _rule_carry_branch(tree, rel: str, findings: List[Finding]) -> None:
    if not rel.startswith("src/repro/core/"):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.FunctionDef):
            continue
        if node.name not in ("body", "cond"):
            continue
        if not node.args.args:
            continue
        carry = node.args.args[0].arg
        for stmt in ast.walk(node):
            if isinstance(stmt, (ast.If, ast.While)) \
                    and _references(stmt.test, carry):
                findings.append(Finding(
                    "RA002", f"{rel}:{stmt.lineno}",
                    f"Python branch on traced carry '{carry}' inside "
                    f"{node.name}() — use jnp.where / lax.cond"))


def _rule_result_pin(tree, rel: str, findings: List[Finding]) -> None:
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        pin = RESULT_PINS.get(node.name)
        if pin is None:
            continue
        fields = tuple(t.target.id for t in node.body
                       if isinstance(t, ast.AnnAssign)
                       and isinstance(t.target, ast.Name))
        if fields != pin:
            extra = sorted(set(fields) - set(pin))
            missing = sorted(set(pin) - set(fields))
            findings.append(Finding(
                "RA003", f"{rel}:{node.lineno}",
                f"{node.name} fields changed (added {extra or '[]'}, "
                f"removed {missing or '[]'}): new per-iteration outputs "
                "route through the telemetry ring seam, not the result "
                "struct"))


def _rule_test_determinism(tree, rel: str, findings: List[Finding]) -> None:
    if not rel.startswith("tests/") or rel.startswith("tests/fixtures/"):
        return
    seeded_stdlib = any(
        isinstance(n, ast.Call) and _attr_chain(n.func) == "random.seed"
        for n in ast.walk(tree))
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if chain is None:
            continue
        if chain.endswith("np.random.default_rng") and not node.args:
            findings.append(Finding(
                "RA004", f"{rel}:{node.lineno}",
                "unseeded default_rng() in a test"))
        elif chain.startswith("np.random.") \
                and chain != "np.random.default_rng":
            findings.append(Finding(
                "RA004", f"{rel}:{node.lineno}",
                f"legacy global RNG draw {chain} (use a seeded "
                "default_rng)"))
        elif chain.startswith("random.") and chain != "random.seed" \
                and not seeded_stdlib:
            findings.append(Finding(
                "RA004", f"{rel}:{node.lineno}",
                f"stdlib {chain} without random.seed in this file"))
        elif any(chain.endswith(f"{m}.{f}") for m, f in WALLCLOCK_CALLS):
            findings.append(Finding(
                "RA004", f"{rel}:{node.lineno}",
                f"wall-clock read {chain} in a test (nondeterministic)"))


RULES = (_rule_f64, _rule_carry_branch, _rule_result_pin,
         _rule_test_determinism)


def lint_source(source: str, rel: str) -> List[Finding]:
    """Run every rule over one file's text; ``rel`` is the repo-relative
    posix path that decides which rules apply (fixture tests map planted
    files onto device-code paths this way)."""
    findings: List[Finding] = []
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding("RA000", f"{rel}:{e.lineno}", "syntax error")]
    lines = source.splitlines()
    _rule_f64(tree, rel, lines, findings)
    _rule_carry_branch(tree, rel, findings)
    _rule_result_pin(tree, rel, findings)
    _rule_test_determinism(tree, rel, findings)
    return findings


# Planted-violation fixtures: filename -> the repo-relative path the file
# is linted AS (rules are path-scoped).  Each must trigger its rule once.
FIXTURES = {
    "ra001_f64_device.py": "src/repro/core/__planted__.py",
    "ra002_carry_branch.py": "src/repro/core/__planted__.py",
    "ra003_widened_result.py": "src/repro/core/__planted__.py",
    "ra004_unseeded_test.py": "tests/test___planted__.py",
}


def run_fixtures(fixture_dir: Optional[pathlib.Path] = None
                 ) -> List[Finding]:
    """Lint the planted fixtures (negative control: MUST find one
    violation per fixture)."""
    d = fixture_dir or repo_root() / "tests" / "fixtures" / "lint"
    findings: List[Finding] = []
    for fname, rel in FIXTURES.items():
        findings.extend(lint_source((d / fname).read_text(), rel))
    return findings


def run_lint(root: Optional[pathlib.Path] = None) -> List[Finding]:
    root = root or repo_root()
    findings: List[Finding] = []
    for sub in ("src/repro", "tests"):
        base = root / sub
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            rel = path.relative_to(root).as_posix()
            if rel.startswith("tests/fixtures/"):
                continue
            findings.extend(lint_source(path.read_text(), rel))
    return findings
