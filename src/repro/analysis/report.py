"""Shared finding container + tiny text rendering for the analysis CLI.

Kept free of jax imports on purpose: the linter pass (and the ``--lint``
CLI path) must run on a box without a working jax install.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violation reported by an analysis pass.

    ``check`` is the stable rule identifier (e.g. ``dtype-int64``,
    ``struct-carry``, ``recompile-count``, ``RA001``); ``where`` locates
    it (a matrix entry name, a probe name, or ``file:line``); ``message``
    is the human sentence.
    """

    check: str
    where: str
    message: str

    def render(self) -> str:
        return f"[{self.check}] {self.where}: {self.message}"


def print_findings(pass_name: str, findings: list[Finding]) -> None:
    if not findings:
        print(f"{pass_name}: OK")
        return
    print(f"{pass_name}: {len(findings)} finding(s)")
    for f in findings:
        print("  " + f.render())
