"""Tracing-cache probes: assert exact compile counts per jit call site.

The grid premium rests on a static/traced split (PR 1): per-problem
``(C, gamma)`` ride in as *traced* data (C through the box ``L``/``U``,
gamma as an array) while ``SolverConfig`` and the backend knobs are
*static*.  A regression — say a Python-float gamma threaded into a
static argument, or a config field demoted to traced — does not fail any
numeric test; it shows up as one silent retrace per grid lane and erases
the cheap-iteration premium the planning-ahead paper is about.

Each probe below clears the global tracing caches, drives a real jit
call site through a small ``(C, gamma, B, l)`` sweep, and asserts the
**exact** expected entry count via the jitted function's
``_cache_size()``.  Counts are exact, not bounds: a probe that expects 2
and sees 1 is as wrong as one that sees 3 (it means the sweep no longer
exercises what it claims to).
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.report import Finding

PROBE_L, PROBE_D, PROBE_B = 16, 4, 3


def _problem(l: int = PROBE_L, B: int = PROBE_B, seed: int = 0):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(l, PROBE_D)))
    Y = jnp.asarray(np.sign(rng.normal(size=(B, l))))
    return X, Y


def _fused_args(C: float, gamma: float, l: int = PROBE_L, B: int = PROBE_B):
    X, Y = _problem(l, B)
    YC = Y * C
    L, U = jnp.minimum(0.0, YC), jnp.maximum(0.0, YC)
    gam = jnp.full((B,), gamma, X.dtype)
    return X, Y, L, U, gam


def _count(probe_name: str, jitted, expected: int,
           findings: List[Finding]) -> None:
    got = jitted._cache_size()
    if got != expected:
        findings.append(Finding(
            "recompile-count", probe_name,
            f"expected exactly {expected} cache entrie(s), got {got} "
            "(static/traced discipline regression)"))


def probe_fused_c_gamma(findings: List[Finding]) -> None:
    """(C, gamma) sweep over the fused engine: ONE compile for 4 values."""
    from repro.core.solver_fused import solve_fused_batched_qp

    from repro.core.solver import SolverConfig
    cfg = SolverConfig(eps=1e-3, max_iter=200)
    jax.clear_caches()
    for C in (0.5, 2.0):
        for gamma in (0.4, 0.9):
            X, Y, L, U, gam = _fused_args(C, gamma)
            solve_fused_batched_qp(X, Y, L, U, gam, cfg, impl="jnp")
    _count("fused:c-gamma-sweep", solve_fused_batched_qp, 1, findings)


def probe_fused_shapes(findings: List[Finding]) -> None:
    """Distinct (B, l) shapes legitimately compile once each."""
    from repro.core.solver_fused import solve_fused_batched_qp

    from repro.core.solver import SolverConfig
    cfg = SolverConfig(eps=1e-3, max_iter=200)
    jax.clear_caches()
    for B, l in ((2, 16), (3, 16), (3, 32)):
        X, Y, L, U, gam = _fused_args(1.0, 0.5, l=l, B=B)
        solve_fused_batched_qp(X, Y, L, U, gam, cfg, impl="jnp")
    _count("fused:shape-sweep", solve_fused_batched_qp, 3, findings)


def probe_fused_static_cfg(findings: List[Finding]) -> None:
    """Distinct SolverConfigs are distinct compilations (static by design)."""
    from repro.core.solver_fused import solve_fused_batched_qp

    from repro.core.solver import SolverConfig
    jax.clear_caches()
    for eps in (1e-3, 1e-4):
        X, Y, L, U, gam = _fused_args(1.0, 0.5)
        solve_fused_batched_qp(X, Y, L, U, gam,
                               SolverConfig(eps=eps, max_iter=200),
                               impl="jnp")
    _count("fused:static-cfg", solve_fused_batched_qp, 2, findings)


def probe_classic_c_gamma(findings: List[Finding]) -> None:
    """(C, gamma) sweep over the classic engine: ONE compile for 4 values.

    C enters through the traced box bounds, gamma through the traced Gram
    values — same aval, same compilation.
    """
    from repro.core import qp as qp_mod
    from repro.core.solver import SolverConfig, solve_qp

    X, Y = _problem()
    y = Y[0]
    cfg = SolverConfig(eps=1e-3, max_iter=200)
    d2 = jnp.sum((X[:, None, :] - X[None, :, :]) ** 2, axis=-1)
    jax.clear_caches()
    for C in (0.5, 2.0):
        for gamma in (0.4, 0.9):
            kernel = qp_mod.PrecomputedKernel(jnp.exp(-gamma * d2))
            solve_qp(kernel, qp_mod.classification_qp(y, C), cfg)
    _count("classic:c-gamma-sweep", solve_qp, 1, findings)


def probe_grid_values(findings: List[Finding]) -> None:
    """Whole-grid call site: value sweeps share ONE compile, a new grid
    shape adds exactly one."""
    from repro.core import grid as grid_mod
    from repro.core.solver import SolverConfig

    X, Y = _problem()
    cfg = SolverConfig(eps=1e-3, max_iter=200)
    jax.clear_caches()
    for Cs, gammas in (((0.5, 1.0), (0.4, 0.8)), ((0.7, 2.0), (0.3, 0.9))):
        grid_mod.solve_grid(X, Y, jnp.asarray(Cs), jnp.asarray(gammas),
                            cfg, impl="jnp")
    _count("grid:value-sweep", grid_mod._solve_grid_fused, 1, findings)
    grid_mod.solve_grid(X, Y, jnp.asarray([0.5, 1.0, 2.0]),
                        jnp.asarray([0.4, 0.8]), cfg, impl="jnp")
    _count("grid:new-shape", grid_mod._solve_grid_fused, 2, findings)


PROBES: tuple = (
    probe_fused_c_gamma,
    probe_fused_shapes,
    probe_fused_static_cfg,
    probe_classic_c_gamma,
    probe_grid_values,
)


def run_probes(probes=PROBES) -> List[Finding]:
    findings: List[Finding] = []
    for probe in probes:
        probe(findings)
    jax.clear_caches()
    return findings


def plant_excess_recompile() -> List[Finding]:
    """Negative control: a call site that bakes gamma in as a *static*
    argument retraces per value — the guard must flag it."""
    from repro.core.solver_fused import solve_fused_batched_qp

    from repro.core.solver import SolverConfig
    cfg = SolverConfig(eps=1e-3, max_iter=200)

    from functools import partial

    @partial(jax.jit, static_argnames=("gamma",))
    def leaky(X, P, L, U, *, gamma: float):
        g = jnp.full((P.shape[0],), gamma, X.dtype)
        return solve_fused_batched_qp(X, P, L, U, g, cfg, impl="jnp")

    findings: List[Finding] = []
    jax.clear_caches()
    for gamma in (0.4, 0.9):
        X, Y, L, U, _ = _fused_args(1.0, gamma)
        leaky(X, Y, L, U, gamma=gamma)
    _count("plant:static-gamma", leaky, 1, findings)
    jax.clear_caches()
    return findings
