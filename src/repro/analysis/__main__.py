"""CLI for the static-analysis subsystem.

Default run (no flags) executes all three passes and exits non-zero on
any finding::

    PYTHONPATH=src python -m repro.analysis

Pass selection: ``--audit`` (jaxpr structural + dtype + callback),
``--recompile`` (tracing-cache probes), ``--lint`` (AST rules; works
without jax).  ``--census DIR`` writes the per-engine primitive/dtype
census JSONs (CI uploads them as an artifact).  ``--write-golden``
refreshes ``tests/golden/structural.json`` after an INTENDED trace
change.  ``--plant {f64,carry,recompile,lint}`` runs one planted
violation instead of the real passes — the negative control MUST exit
non-zero, which is what ``tests/test_analysis.py`` asserts.
"""

from __future__ import annotations

import argparse
import sys


def _enable_x64() -> None:
    import jax

    jax.config.update("jax_enable_x64", True)


def _run_plant(kind: str) -> list:
    if kind == "lint":
        from repro.analysis import lint_rules

        return lint_rules.run_fixtures()
    _enable_x64()
    if kind == "f64":
        from repro.analysis import jaxpr_audit

        return jaxpr_audit.plant_f64()
    if kind == "carry":
        from repro.analysis import jaxpr_audit

        return jaxpr_audit.plant_widened_carry()
    from repro.analysis import recompile_guard

    return recompile_guard.plant_excess_recompile()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jaxpr auditor, recompile guard and repo linter")
    ap.add_argument("--audit", action="store_true",
                    help="jaxpr structural/dtype/callback audit only")
    ap.add_argument("--recompile", action="store_true",
                    help="tracing-cache probes only")
    ap.add_argument("--lint", action="store_true",
                    help="AST rules only (no jax needed)")
    ap.add_argument("--census", metavar="DIR",
                    help="also write per-engine census JSONs to DIR")
    ap.add_argument("--golden", metavar="PATH",
                    help="structural golden path (default "
                         "tests/golden/structural.json)")
    ap.add_argument("--write-golden", action="store_true",
                    help="refresh the structural golden and exit")
    ap.add_argument("--plant", choices=("f64", "carry", "recompile",
                                        "lint"),
                    help="run one planted violation (negative control; "
                         "exits non-zero when detection works)")
    args = ap.parse_args(argv)

    from repro.analysis.report import print_findings

    if args.plant:
        findings = _run_plant(args.plant)
        print_findings(f"plant:{args.plant}", findings)
        if not findings:
            print(f"plant:{args.plant}: NOT DETECTED "
                  "(the planted violation slipped through)",
                  file=sys.stderr)
            return 2
        return 1

    if args.write_golden:
        _enable_x64()
        from repro.analysis import jaxpr_audit

        path = args.golden or jaxpr_audit.default_golden_path()
        jaxpr_audit.emit_golden(path)
        print(f"wrote {path}")
        return 0

    run_all = not (args.audit or args.recompile or args.lint)
    failed = False

    if run_all or args.lint:
        from repro.analysis import lint_rules

        findings = lint_rules.run_lint()
        print_findings("lint", findings)
        failed |= bool(findings)

    if run_all or args.audit:
        _enable_x64()
        from repro.analysis import jaxpr_audit

        golden = args.golden or jaxpr_audit.default_golden_path()
        findings = jaxpr_audit.audit_structure(golden)
        findings += jaxpr_audit.audit_all_dtypes()
        print_findings("jaxpr-audit", findings)
        failed |= bool(findings)
        if args.census:
            paths = jaxpr_audit.emit_census(args.census)
            print(f"census: wrote {len(paths)} file(s) to {args.census}")

    if run_all or args.recompile:
        _enable_x64()
        from repro.analysis import recompile_guard

        findings = recompile_guard.run_probes()
        print_findings("recompile-guard", findings)
        failed |= bool(findings)

    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
