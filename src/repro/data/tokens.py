"""Deterministic, indexable token pipeline.

Fault-tolerance contract: ``batch_at(step)`` is a pure function of
(seed, step, shape), so any step can be replayed after a restore without
pipeline state — the checkpoint only needs the step counter (DESIGN.md §7).
The synthetic stream is a counter-mode PRNG (threefry via jax.random on
CPU-resident numpy fallback), giving markov-ish token streams with a
configurable vocabulary; a memory-mapped corpus loader hooks in through
the same interface.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticTokens:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # markov chain parameters give non-uniform, learnable structure
    branching: int = 64

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        B, S = self.global_batch, self.seq_len
        # per-batch random "grammar": next token depends on current token
        # through a seeded hash; gives low-entropy targets a model can learn
        base = rng.integers(0, self.vocab, size=(B, 1), dtype=np.int64)
        mults = rng.integers(1, self.branching, size=(B, S), dtype=np.int64)
        toks = np.zeros((B, S), np.int64)
        toks[:, 0] = base[:, 0]
        for t in range(1, S):
            toks[:, t] = (toks[:, t - 1] * 6364136223846793005
                          + mults[:, t]) % self.vocab
        tokens = toks.astype(np.int32)
        labels = np.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
        return {"tokens": tokens, "labels": labels}


def shard_batch(batch: Dict[str, np.ndarray], mesh, rules=None):
    """Place a host batch onto the mesh with the batch-axis sharding."""
    import jax
    from repro.sharding import DEFAULT_RULES, spec_for
    from jax.sharding import NamedSharding

    rules = rules or DEFAULT_RULES
    out = {}
    for k, v in batch.items():
        names = ("batch",) + (None,) * (v.ndim - 1)
        sh = NamedSharding(mesh, spec_for(v.shape, names, mesh, rules))
        out[k] = jax.device_put(v, sh)
    return out
