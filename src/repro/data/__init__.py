from repro.data.tokens import SyntheticTokens, shard_batch
