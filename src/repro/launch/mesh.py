"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (smoke tests keep their single CPU device).
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: make_mesh has no axis_types kwarg
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) data x model single pod (256 chips, v5e), or
    (2, 16, 16) pod x data x model for the 512-chip two-pod dry-run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many (virtual) devices exist — tests."""
    return _make_mesh((n_data, n_model), ("data", "model"))


def make_lane_mesh(devices=None, *, axis: str = "data"):
    """1-D mesh over ``devices`` (default: every attached device) for the
    lane-sharded fused engine (:mod:`repro.core.sharded_lanes`).

    Built from an explicit device list — unlike :func:`jax.make_mesh` this
    lets tests and benchmarks pin a subset (e.g. half the forced host
    devices) without touching global state."""
    import numpy as np
    from jax.sharding import Mesh
    devs = list(jax.devices()) if devices is None else list(devices)
    return Mesh(np.array(devs), (axis,))
