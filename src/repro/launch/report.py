"""Render EXPERIMENTS.md tables from dry-run artifacts.

    python -m repro.launch.report [--dir artifacts/dryrun] [--mesh single]
"""

import argparse
import glob
import json
import os


def load(d, mesh=None):
    rows = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        r = json.load(open(f))
        if mesh and r.get("mesh") != mesh:
            continue
        rows.append(r)
    return rows


def roofline_table(rows):
    out = ["| arch | shape | compute_s | memory_s | collective_s | "
           "dominant | MODEL/HLO | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skipped | — | ({r['skip_reason']}) |")
            continue
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | FAILED | | | | | |")
            continue
        ro = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.3e} "
            f"| {ro['memory_s']:.3e} | {ro['collective_s']:.3e} "
            f"| {ro['dominant']} | {ro['useful_flops_ratio']:.3f} "
            f"| {ro['roofline_fraction']:.3f} |")
    return "\n".join(out)


def dryrun_table(rows):
    out = ["| arch | shape | mesh | status | compile s | HLO GFLOP/dev | "
           "HLO GB/dev | coll GB/dev | input GB/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                       f"| SKIP ({r['skip_reason'][:40]}) | | | | | |")
            continue
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                       f"| **FAIL** | | | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK "
            f"| {r['time_compile_s']:.1f} "
            f"| {r['hlo_flops'] / 1e9:.1f} | {r['hlo_bytes'] / 1e9:.1f} "
            f"| {r['collectives']['total'] / 1e9:.1f} "
            f"| {r['input_bytes_per_device'] / 1e9:.2f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--kind", default="roofline",
                    choices=["roofline", "dryrun"])
    args = ap.parse_args()
    rows = load(args.dir, args.mesh)
    print(roofline_table(rows) if args.kind == "roofline"
          else dryrun_table(rows))


if __name__ == "__main__":
    main()
