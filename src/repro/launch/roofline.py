"""Roofline derivation from compiled dry-run artifacts.

Terms (v5e hardware constants, per the brief):

    compute term    = HLO_FLOPs / (chips x 197e12 bf16 FLOP/s)
    memory term     = HLO_bytes / (chips x 819e9 B/s HBM)
    collective term = collective_bytes / (chips x 50e9 B/s ICI link)

``compiled.cost_analysis()`` supplies per-device FLOPs/bytes (the SPMD
module is a per-device program).  collective bytes are parsed from the
post-optimization HLO text: we sum the *operand* sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
instruction (per-device shapes), which is the brief's convention.
MODEL_FLOPS uses the 6ND / 2ND convention (attention flops excluded), so
MODEL_FLOPS / HLO_FLOPs exposes remat recompute and dispatch overheads.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# shapes like f32[8,128]{1,0} or bf16[4096]
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum operand bytes of collective ops in (post-SPMD) HLO text."""
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*[^=]*?\b([a-z0-9-]+)\(", stripped)
        if not m:
            continue
        op = m.group(1)
        # normalize e.g. all-reduce-start / all-gather-done
        base = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-start"):
                base = c
                break
        if base is None:
            continue
        # operand shapes: everything inside the call parens
        args = stripped[stripped.index("(") + 1:]
        total = sum(_shape_bytes(d, dims)
                    for d, dims in _SHAPE_RE.findall(args))
        if total == 0:
            # fall back to the output shape (lhs)
            lhs = stripped[:stripped.index("=")]
            rhs_head = stripped[stripped.index("="):stripped.index("(")]
            total = sum(_shape_bytes(d, dims)
                        for d, dims in _SHAPE_RE.findall(rhs_head))
        out[base] += total
        counts[base] += 1
    out_all = dict(out)
    out_all["total"] = sum(out.values())
    out_all["counts"] = counts
    return out_all


def model_flops(n_params: int, n_active_params: int, tokens: int,
                kind: str) -> float:
    """6ND (train) / 2ND (inference) with active params for MoE."""
    n = n_active_params or n_params
    return (6.0 if kind == "train" else 2.0) * n * tokens


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    collective_bytes_per_device: float
    model_flops_global: float
    bytes_per_device_peak: Optional[float]  # memory_analysis, if available

    @property
    def compute_s(self) -> float:
        return self.hlo_flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.hlo_flops_per_device * self.chips
        return self.model_flops_global / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak compute achievable at the modeled bottleneck:
        (useful compute time) / (dominant term time)."""
        useful_s = (self.model_flops_global / self.chips) / PEAK_FLOPS
        bound = max(self.compute_s, self.memory_s, self.collective_s)
        return useful_s / bound if bound else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 collective_s=self.collective_s, dominant=self.dominant,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def render_table(rows) -> str:
    hdr = ("| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "dominant | MODEL/HLO | roofline_frac |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant']} "
            f"| {r['useful_flops_ratio']:.3f} "
            f"| {r['roofline_fraction']:.3f} |")
    return "\n".join(lines)
