"""Render the fused-engine flight-recorder JSONL as a human report.

    python -m repro.launch.telemetry_report run.jsonl [--top-k 5]
                                            [--trace-lane 3] [--hist]

Input is the artifact written by :class:`repro.telemetry.Diagnostics`
(``fingerprint`` / ``phase`` / ``lane`` / ``straggler_warning`` /
``summary`` events, one JSON object per line).  Output sections:

* environment fingerprint (what machine/backend produced the run);
* host phase table (wall-clock per named scope);
* per-lane convergence table — iterations, KKT gap, planning-step and
  unshrink totals, keyed by the lane's hyper-parameters;
* straggler diagnosis: which (gamma, C) cells dominate the wall-clock
  (iteration share), plus any chunk-deadline warnings from the
  EWMA monitor;
* optionally (``--trace-lane``) the lane's Fig. 3 planning trace — the
  mu/mu* ratio per accepted planning step — and its sampled KKT-gap
  trajectory.

Pure stdlib on purpose: the report must render anywhere the JSONL can
be copied to, with no JAX (or even numpy) in sight.
"""

from __future__ import annotations

import argparse
import json
import sys

FINGERPRINT_ORDER = ("jax_version", "backend", "device_kind",
                     "device_count", "cpu_count", "host", "python",
                     "machine")


def load_events(path: str) -> list[dict]:
    with open(path) as fh:
        return [json.loads(line) for line in fh if line.strip()]


def split_events(events):
    """Bucket a raw event stream by type (unknown types are ignored)."""
    by = {"fingerprint": [], "phase": [], "lane": [],
          "straggler_warning": [], "summary": []}
    for e in events:
        by.get(e.get("event"), []).append(e)
    return by


def _lane_key(rec: dict) -> str:
    """Human label for a lane from whichever hyper-params it carries."""
    parts = []
    for key, fmt in (("gamma", "g={:g}"), ("label", "y={}"),
                     ("C", "C={:g}"), ("epsilon", "eps={:g}"),
                     ("nu", "nu={:g}")):
        if key in rec:
            parts.append(fmt.format(rec[key]))
    return " ".join(parts) if parts else f"lane {rec.get('lane', '?')}"


def _table(header: list[str], rows: list[list[str]]) -> str:
    out = ["| " + " | ".join(header) + " |",
           "|" + "|".join("---" for _ in header) + "|"]
    out += ["| " + " | ".join(row) + " |" for row in rows]
    return "\n".join(out)


def fingerprint_section(fps: list[dict]) -> str:
    if not fps:
        return "(no fingerprint event in stream)"
    fp = fps[0]
    rows = [[k, str(fp[k])] for k in FINGERPRINT_ORDER if k in fp]
    return _table(["field", "value"], rows)


def phase_section(phases: list[dict]) -> str:
    if not phases:
        return "(no phase events)"
    agg: dict[str, list[float]] = {}
    for e in phases:
        agg.setdefault(e.get("name", "?"), []).append(
            float(e.get("seconds", 0.0)))
    rows = [[name, str(len(ts)), f"{sum(ts):.4f}",
             f"{sum(ts) / len(ts):.4f}", f"{max(ts):.4f}"]
            for name, ts in sorted(agg.items(),
                                   key=lambda kv: -sum(kv[1]))]
    return _table(["phase", "calls", "total s", "mean s", "max s"], rows)


def convergence_section(lanes: list[dict]) -> str:
    if not lanes:
        return "(no lane events — device-tier telemetry was off)"
    rows = []
    for rec in lanes:
        gap = rec.get("kkt_gap")
        rows.append([
            str(rec.get("lane", "?")), _lane_key(rec),
            str(rec.get("iterations", "?")),
            {True: "yes", False: "NO"}.get(rec.get("converged"), "?"),
            "?" if gap is None else f"{gap:.2e}",
            str(rec.get("n_planning", "?")),
            str(rec.get("total_unshrink", "?")),
            str(rec.get("n_samples", 0)),
        ])
    return _table(["lane", "cell", "iters", "conv", "kkt gap",
                   "plan", "unshrink", "samples"], rows)


def straggler_section(lanes: list[dict], warnings: list[dict],
                      top_k: int = 5) -> str:
    if not lanes:
        return "(no lane events)"
    iters = [int(rec.get("iterations", 0)) for rec in lanes]
    total = max(1, sum(iters))
    order = sorted(range(len(lanes)), key=lambda i: -iters[i])[:top_k]
    rows = [[str(lanes[i].get("lane", i)), _lane_key(lanes[i]),
             str(iters[i]), f"{100.0 * iters[i] / total:.1f}%"]
            for i in order]
    out = [_table(["lane", "cell", "iters", "iter share"], rows)]
    share = sum(iters[i] for i in order) / total
    out.append(f"\ntop {len(order)} of {len(lanes)} lanes carry "
               f"{100.0 * share:.1f}% of all iterations.")
    for w in warnings:
        out.append(f"chunk deadline breached: round {w.get('round')} took "
                   f"{w.get('seconds', 0.0):.3f}s "
                   f"(EWMA deadline {w.get('deadline', 0.0):.3f}s, "
                   f"{len(w.get('lanes', []))} live lanes)")
    return "\n".join(out)


def iteration_histogram(lanes: list[dict], width: int = 40) -> str:
    iters = [int(rec.get("iterations", 0)) for rec in lanes]
    if not iters:
        return "(no lane events)"
    lo, hi = min(iters), max(iters)
    nbins = min(8, max(1, len(set(iters))))
    span = max(1e-12, float(hi - lo))
    counts = [0] * nbins
    for v in iters:
        counts[min(nbins - 1, int((v - lo) / span * nbins))] += 1
    peak = max(counts)
    out = []
    for b, c in enumerate(counts):
        a = lo + span * b / nbins
        z = lo + span * (b + 1) / nbins
        bar = "#" * max(0, round(width * c / peak))
        out.append(f"  [{a:8.1f}, {z:8.1f})  {c:4d}  {bar}")
    return "\n".join(out)


def trace_section(lanes: list[dict], lane: int, width: int = 52) -> str:
    """The classic Fig. 3 rendering: mu/mu* per accepted planning step."""
    rec = next((r for r in lanes if r.get("lane") == lane), None)
    if rec is None:
        return f"(lane {lane} not found)"
    tr = rec.get("ratio", {})
    ts, vals = tr.get("t", []), tr.get("value", [])
    out = [f"lane {lane} ({_lane_key(rec)}): {rec.get('n_ratio', 0)} "
           f"accepted planning steps"]
    if vals:
        lo, hi = min(vals), max(vals)
        span = max(1e-12, hi - lo)
        for t, v in zip(ts, vals):
            pos = round((v - lo) / span * (width - 1))
            out.append(f"  t={t:6d}  mu/mu*={v:10.4f}  "
                       + "." * pos + "*")
    samples = rec.get("samples", {})
    st, sg = samples.get("t", []), samples.get("gap", [])
    if st:
        out.append("sampled KKT-gap trajectory:")
        out.append("  " + "  ".join(f"t={t}:{g:.2e}"
                                    for t, g in zip(st, sg)))
    return "\n".join(out)


def render_report(events: list[dict], *, top_k: int = 5,
                  trace_lane: int | None = None,
                  hist: bool = False) -> str:
    by = split_events(events)
    sections = [
        ("environment", fingerprint_section(by["fingerprint"])),
        ("host phases", phase_section(by["phase"])),
        ("convergence", convergence_section(by["lane"])),
        ("stragglers", straggler_section(by["lane"],
                                         by["straggler_warning"], top_k)),
    ]
    if hist:
        sections.append(("iteration histogram",
                         iteration_histogram(by["lane"])))
    if trace_lane is not None:
        sections.append((f"planning trace (Fig. 3), lane {trace_lane}",
                         trace_section(by["lane"], trace_lane)))
    if by["summary"]:
        s = by["summary"][-1]
        keys = ("n_lanes", "n_converged", "total_iterations",
                "max_iterations", "total_planning", "total_unshrink")
        sections.append(("summary", ", ".join(
            f"{k}={s[k]}" for k in keys if k in s)))
    return "\n\n".join(f"## {title}\n\n{body}" for title, body in sections)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.telemetry_report",
        description="Render a Diagnostics JSONL artifact as a report.")
    ap.add_argument("path", help="telemetry JSONL file")
    ap.add_argument("--top-k", type=int, default=5,
                    help="straggler table size")
    ap.add_argument("--trace-lane", type=int, default=None,
                    help="render this lane's Fig. 3 planning trace")
    ap.add_argument("--hist", action="store_true",
                    help="include the iteration histogram")
    args = ap.parse_args(argv)
    events = load_events(args.path)
    if not events:
        print(f"no events in {args.path}", file=sys.stderr)
        return 1
    print(render_report(events, top_k=args.top_k,
                        trace_lane=args.trace_lane, hist=args.hist))
    return 0


if __name__ == "__main__":
    sys.exit(main())
