import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DRYRUN_DEVICES", "512"))

# The two lines above MUST run before any jax import (jax locks the device
# count on first init).  Everything below is normal code.

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import math              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Optional, Tuple  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS, get_config, get_shape       # noqa: E402
from repro.configs.base import (SHAPES, ModelConfig, ServeConfig,  # noqa: E402
                                ShapeConfig, TrainConfig)
from repro.launch.mesh import make_production_mesh            # noqa: E402
from repro.launch import hlo_analysis                         # noqa: E402
from repro.launch import roofline as rf                      # noqa: E402
from repro.models import registry                             # noqa: E402
from repro.sharding import (DEFAULT_RULES, Rules, axis_rules,  # noqa: E402
                            tree_shardings)
from repro.train import optimizer as opt_mod                  # noqa: E402
from repro.train.train_step import TrainState, make_train_step  # noqa: E402
from repro.train.serve_step import make_prefill, make_serve_step  # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces a JSON artifact with:
  * compile wall-time, per-device HLO flops/bytes (cost_analysis),
  * collective operand bytes parsed from the optimized HLO,
  * memory_analysis (or an analytic params+opt+cache estimate when the CPU
    backend doesn't implement it),
  * the derived roofline terms (launch/roofline.py).

Usage:
  python -m repro.launch.dryrun --mesh single --arch all --shape all
  python -m repro.launch.dryrun --mesh multi --arch grok-1-314b \
      --shape train_4k --out artifacts/dryrun
"""


def _sds_with(sh_tree, sds_tree):
    return jax.tree.map(
        lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                             sharding=sh),
        sds_tree, sh_tree)


def choose_microbatches(shape: ShapeConfig, cfg: ModelConfig,
                        dp: int) -> int:
    """Keep per-device microbatch activation footprints sane: target ~4k
    tokens per device per microbatch for d_model >= 4096, 16k below."""
    b_dev = max(1, shape.global_batch // dp)
    target_tokens = 4096 if cfg.d_model >= 4096 else 16384
    mb_rows = max(1, target_tokens // shape.seq_len)
    m = max(1, math.ceil(b_dev / mb_rows))
    while b_dev % m != 0:
        m += 1
    return min(m, b_dev)


def dp_size(mesh) -> int:
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    return dp


def build_train(cfg: ModelConfig, shape: ShapeConfig, mesh, rules: Rules):
    tc = TrainConfig(seq_len=shape.seq_len, global_batch=shape.global_batch,
                     microbatches=choose_microbatches(shape, cfg,
                                                      dp_size(mesh)),
                     remat="full")
    pdt = jnp.bfloat16
    p_sds = jax.eval_shape(
        lambda: registry.init_params(jax.random.PRNGKey(0), cfg, pdt))
    p_log = registry.param_logical(cfg)
    p_sh = tree_shardings(p_log, p_sds, mesh, rules)

    o_sds = jax.eval_shape(lambda p: opt_mod.init(p, tc), p_sds)
    rep = NamedSharding(mesh, P())
    o_sh = opt_mod.AdamWState(step=rep, m=p_sh, v=p_sh)

    state_sds = TrainState(params=_sds_with(p_sh, p_sds),
                           opt=opt_mod.AdamWState(
                               step=jax.ShapeDtypeStruct((), jnp.int32,
                                                         sharding=rep),
                               m=_sds_with(p_sh, o_sds.m),
                               v=_sds_with(p_sh, o_sds.v)),
                           ef=None,
                           step=jax.ShapeDtypeStruct((), jnp.int32,
                                                     sharding=rep))
    b_sds = registry.train_input_specs(cfg, shape)
    b_log = registry.train_input_logical(cfg)
    b_sh = tree_shardings(b_log, b_sds, mesh, rules)
    batch_sds = _sds_with(b_sh, b_sds)

    state_sh = TrainState(params=p_sh, opt=o_sh, ef=None, step=rep)
    metrics_sh = {"loss": rep, "grad_norm": rep, "lr": rep}
    fn = jax.jit(make_train_step(cfg, tc),
                 out_shardings=(state_sh, metrics_sh))
    return fn, (state_sds, batch_sds), dataclasses.asdict(tc)


def build_prefill(cfg: ModelConfig, shape: ShapeConfig, mesh, rules: Rules):
    sc = ServeConfig(seq_len=shape.seq_len, batch=shape.global_batch)
    pdt = jnp.bfloat16
    p_sds = jax.eval_shape(
        lambda: registry.init_params(jax.random.PRNGKey(0), cfg, pdt))
    p_sh = tree_shardings(registry.param_logical(cfg), p_sds, mesh, rules)
    b_sds = registry.train_input_specs(cfg, shape)
    b_sds.pop("labels")
    b_log = registry.train_input_logical(cfg)
    b_log.pop("labels")
    b_sh = tree_shardings(b_log, b_sds, mesh, rules)
    fn = jax.jit(make_prefill(cfg, sc))
    return fn, (_sds_with(p_sh, p_sds), _sds_with(b_sh, b_sds)), \
        dataclasses.asdict(sc)


def build_decode(cfg: ModelConfig, shape: ShapeConfig, mesh, rules: Rules):
    sc = ServeConfig(seq_len=shape.seq_len, batch=shape.global_batch)
    pdt = jnp.bfloat16
    p_sds = jax.eval_shape(
        lambda: registry.init_params(jax.random.PRNGKey(0), cfg, pdt))
    p_sh = tree_shardings(registry.param_logical(cfg), p_sds, mesh, rules)
    c_sds = registry.cache_specs(cfg, shape.global_batch, shape.seq_len)
    c_sh = tree_shardings(registry.cache_logical(cfg), c_sds, mesh, rules)
    tok_sds = jax.ShapeDtypeStruct(
        (shape.global_batch, 1), jnp.int32,
        sharding=NamedSharding(mesh, rf_spec_batch(shape, mesh, rules)))
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32,
                                   sharding=NamedSharding(mesh, P()))
    # donate the cache: the ring update aliases in place on device
    fn = jax.jit(make_serve_step(cfg, sc), donate_argnums=(1,))
    return fn, (_sds_with(p_sh, p_sds), _sds_with(c_sh, c_sds), tok_sds,
                pos_sds), dataclasses.asdict(sc)


def rf_spec_batch(shape: ShapeConfig, mesh, rules: Rules):
    from repro.sharding import spec_for
    return spec_for((shape.global_batch, 1), ("batch", None), mesh, rules)


def analytic_bytes_per_device(args_sds) -> float:
    """Fallback memory estimate: per-device bytes of all inputs (params,
    opt state, cache, batch) under their shardings.  Activations excluded
    (reported separately by memory_analysis when available)."""
    total = 0
    for leaf in jax.tree.leaves(args_sds):
        if leaf.sharding is not None:
            shard_shape = leaf.sharding.shard_shape(leaf.shape)
        else:
            shard_shape = leaf.shape
        total += int(np.prod(shard_shape)) * leaf.dtype.itemsize
    return float(total)


def active_params(cfg: ModelConfig) -> int:
    if cfg.family == "moe":
        dense_like = dataclasses.replace(
            cfg, n_experts=cfg.top_k,
            name=cfg.name + "-active")
        return dense_like.param_count()
    return cfg.param_count()


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             rules: Rules = DEFAULT_RULES,
             out_dir: Optional[str] = None) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    chips = int(np.prod(list(mesh.shape.values())))
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "chips": chips, "kind": shape.kind, "ok": False}

    ok, reason = registry.supports_cell(cfg, shape)
    if not ok:
        record.update(skipped=True, skip_reason=reason, ok=True)
        _write(record, out_dir)
        return record

    try:
        build = {"train": build_train, "prefill": build_prefill,
                 "decode": build_decode}[shape.kind]
        with axis_rules(mesh, rules):
            fn, args, settings = build(cfg, shape, mesh, rules)
            t0 = time.monotonic()
            lowered = fn.lower(*args)
            t_lower = time.monotonic() - t0
            t0 = time.monotonic()
            compiled = lowered.compile()
            t_compile = time.monotonic() - t0

        # raw XLA numbers (recorded for reference; while bodies counted
        # once — see hlo_analysis docstring)
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            xla_flops = float(ca.get("flops", 0.0))
            xla_bytes = float(ca.get("bytes accessed", 0.0))
        except Exception as e:
            xla_flops, xla_bytes = 0.0, 0.0

        try:
            mem = compiled.memory_analysis()
            mem_str = str(mem) if mem is not None else None
        except Exception:
            mem_str = None

        # trip-count-corrected per-device costs from the optimized HLO
        t0 = time.monotonic()
        cost = hlo_analysis.analyze(compiled.as_text())
        t_analyze = time.monotonic() - t0

        tokens = shape.global_batch * (shape.seq_len
                                       if shape.kind != "decode" else 1)
        mf = rf.model_flops(cfg.param_count(), active_params(cfg), tokens,
                            shape.kind)
        roof = rf.Roofline(
            arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
            hlo_flops_per_device=cost.flops,
            hlo_bytes_per_device=cost.bytes,
            collective_bytes_per_device=cost.collective_bytes,
            model_flops_global=mf,
            bytes_per_device_peak=None)

        record.update(
            ok=True, skipped=False, settings=settings,
            time_lower_s=t_lower, time_compile_s=t_compile,
            time_analyze_s=t_analyze,
            hlo_flops=cost.flops, hlo_bytes=cost.bytes,
            xla_cost_analysis={"flops": xla_flops, "bytes": xla_bytes,
                               "caveat": "while bodies counted once"},
            collectives={**cost.collectives,
                         "total": cost.collective_bytes,
                         "counts": cost.collective_counts},
            loops=cost.loops,
            memory_analysis=mem_str,
            input_bytes_per_device=analytic_bytes_per_device(args),
            param_count=cfg.param_count(),
            active_param_count=active_params(cfg),
            roofline=roof.to_dict())
    except Exception as e:
        record.update(error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    _write(record, out_dir)
    return record


def _write(record: dict, out_dir: Optional[str]):
    if out_dir is None:
        return
    os.makedirs(out_dir, exist_ok=True)
    name = f"{record['mesh']}__{record['arch']}__{record['shape']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(record, f, indent=2, default=str)


def make_mesh_by_name(name: str):
    if name == "single":
        return make_production_mesh(multi_pod=False)
    if name == "multi":
        return make_production_mesh(multi_pod=True)
    # custom "NxM" or "PxNxM" (small test meshes)
    dims = tuple(int(x) for x in name.split("x"))
    axes = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
    from repro.launch.mesh import _make_mesh
    return _make_mesh(dims, axes)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=None)
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    mesh = make_mesh_by_name(args.mesh)
    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = [s.name for s in SHAPES] if args.shape == "all" \
        else args.shape.split(",")

    results = []
    for arch in archs:
        for shape in shapes:
            t0 = time.monotonic()
            rec = run_cell(arch, shape, mesh, args.mesh, DEFAULT_RULES,
                           args.out)
            status = ("SKIP" if rec.get("skipped")
                      else "OK" if rec.get("ok") else "FAIL")
            extra = ""
            if rec.get("ok") and not rec.get("skipped"):
                r = rec["roofline"]
                extra = (f" dominant={r['dominant']}"
                         f" frac={r['roofline_fraction']:.3f}"
                         f" compile={rec['time_compile_s']:.1f}s")
            if status == "FAIL":
                extra = " " + rec.get("error", "")[:200]
            print(f"[{status}] {arch} x {shape} x {args.mesh}"
                  f" ({time.monotonic() - t0:.1f}s){extra}", flush=True)
            results.append(rec)

    n_fail = sum(1 for r in results if not r.get("ok"))
    print(f"\n{len(results)} cells, {n_fail} failures", flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
