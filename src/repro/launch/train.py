"""Production training launcher.

Builds the mesh, shards state and data by the logical rules, and runs the
fault-tolerant step loop (checkpoint/resume/straggler monitor).  On the
CPU container this runs reduced configs end-to-end; on a pod the same
entrypoint runs the full configs (device count and mesh shape are the only
differences — `make_production_mesh`).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --smoke --steps 100 --ckpt /tmp/ck
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import AsyncCheckpointer, latest_step, \
    restore_checkpoint
from repro.configs import get_config, get_smoke
from repro.configs.base import TrainConfig
from repro.data import SyntheticTokens, shard_batch
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import registry
from repro.runtime import StepMonitor
from repro.sharding import DEFAULT_RULES, axis_rules, tree_shardings
from repro.train import optimizer as opt_mod
from repro.train.train_step import TrainState, init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default="/tmp/repro_launch_ckpt")
    ap.add_argument("--save-every", type=int, default=25)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the (16,16) mesh (needs >=256 devices)")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    fp32 = jax.default_backend() == "cpu"
    tc = TrainConfig(
        seq_len=args.seq, global_batch=args.batch,
        microbatches=args.microbatches,
        param_dtype="float32" if fp32 else "bfloat16",
        compute_dtype="float32" if fp32 else "bfloat16",
        accum_dtype="float32", remat="full")

    if args.production_mesh:
        mesh = make_production_mesh()
    else:
        n = len(jax.devices())
        mesh = make_host_mesh(n, 1)
    print(f"mesh: {dict(mesh.shape)}  arch: {cfg.name} "
          f"(~{cfg.param_count() / 1e6:.0f}M params)")

    with axis_rules(mesh, DEFAULT_RULES):
        state = init_state(jax.random.PRNGKey(0), cfg, tc)
        p_sh = tree_shardings(registry.param_logical(cfg), state.params,
                              mesh, DEFAULT_RULES)
        rep = NamedSharding(mesh, P())
        state = TrainState(
            params=jax.device_put(state.params, p_sh),
            opt=opt_mod.AdamWState(
                step=jax.device_put(state.opt.step, rep),
                m=jax.device_put(state.opt.m, p_sh),
                v=jax.device_put(state.opt.v, p_sh)),
            ef=None, step=jax.device_put(state.step, rep))
        step_fn = jax.jit(make_train_step(cfg, tc), donate_argnums=(0,))

        data = SyntheticTokens(vocab=cfg.vocab, seq_len=args.seq,
                               global_batch=args.batch)
        ckpt = AsyncCheckpointer(args.ckpt)
        monitor = StepMonitor()
        start = latest_step(args.ckpt) or 0
        if start:
            state = restore_checkpoint(args.ckpt, start, state)
            print(f"resumed from step {start}")

        for step in range(start, args.steps):
            batch = shard_batch(data.batch_at(step), mesh)
            t0 = time.monotonic()
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            slow = monitor.record(time.monotonic() - t0)
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:4d}  loss {float(metrics['loss']):.4f}"
                      f"  gnorm {float(metrics['grad_norm']):.3f}"
                      + ("  [straggler]" if slow else ""), flush=True)
            if (step + 1) % args.save_every == 0 or step + 1 == args.steps:
                ckpt.save(step + 1, state)
        ckpt.close()
        print("done")


if __name__ == "__main__":
    main()
