"""Trip-count-corrected cost analysis of post-optimization HLO text.

XLA's ``compiled.cost_analysis()`` counts every ``while`` body ONCE — with
scan-over-layers (and microbatch/chunk scans) that underestimates FLOPs,
bytes and collective payloads by the trip count (verified empirically: a
2-layer and a 4-layer scanned model report identical flops).  This module
re-derives costs from ``compiled.as_text()``:

  1. parse computations and instructions (result shape, op, operand refs,
     attributes), resolving operand shapes through a per-computation
     symbol table (operands are %refs in optimized HLO),
  2. recover while trip counts from ``backend_config known_trip_count``
     (fallback: the comparison constant in the condition computation),
  3. walk the call graph from ENTRY with a running multiplier
     (nested loops multiply),
  4. accumulate dot/conv FLOPs (2 x output x contraction), HBM traffic
     (operand+output bytes of top-level instructions, fusions counted at
     the fusion boundary) and collective operand bytes by kind.

Shapes in SPMD-partitioned modules are per-device, so all results are
per-device quantities.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1,
    "f8e5m2": 1,
}

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\](?:\{[^}]*\})?")
_INST_RE = re.compile(r"^\s*(ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"\b([a-z][a-z0-9\-]*)\(")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_REF_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONST_RE = re.compile(r"constant\((\d+)\)")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SKIP_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "call", "conditional", "after-all", "partition-id",
    "replica-id", "iota", "rng-bit-generator",
}


def _shape_list(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        out.append((dt, [int(x) for x in dims.split(",")] if dims else []))
    return out


def _bytes_of(shapes) -> float:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return float(total)


@dataclasses.dataclass
class Instruction:
    name: str
    op: str
    result_shapes: List[Tuple[str, List[int]]]
    operand_refs: List[str]
    operand_text: str
    attr_text: str
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]
    shapes: Dict[str, List[Tuple[str, List[int]]]]
    is_entry: bool = False


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for raw in text.splitlines():
        stripped = raw.strip()
        if not stripped or stripped.startswith(("//", "HloModule")):
            continue
        hdr = _COMP_HDR_RE.match(stripped)
        if hdr:
            current = Computation(name=hdr.group(2), instructions=[],
                                  shapes={}, is_entry=bool(hdr.group(1)))
            comps[current.name] = current
            continue
        if stripped == "}" or current is None:
            continue
        m = _INST_RE.match(stripped)
        if not m:
            continue
        root_flag, name, rhs = m.groups()
        opm = _OP_RE.search(rhs)
        if not opm:
            continue
        op = opm.group(1)
        result_shapes = _shape_list(rhs[:opm.start()])
        paren = rhs[opm.end():]
        depth, idx = 1, len(paren)
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    idx = i
                    break
        operand_text = paren[:idx]
        attr_text = paren[idx + 1:]
        inst = Instruction(name=name, op=op, result_shapes=result_shapes,
                           operand_refs=_REF_RE.findall(operand_text),
                           operand_text=operand_text, attr_text=attr_text,
                           is_root=bool(root_flag))
        current.instructions.append(inst)
        current.shapes[name] = result_shapes
    return comps


def _operand_bytes(inst: Instruction, comp: Computation) -> float:
    # optimized HLO references operands as %name; resolve via symbol table,
    # falling back to inline shapes (older formats)
    inline = _shape_list(inst.operand_text)
    if inline:
        return _bytes_of(inline)
    total = 0.0
    for ref in inst.operand_refs:
        total += _bytes_of(comp.shapes.get(ref, []))
    return total


def _attr(inst: Instruction, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", inst.attr_text)
    return m.group(1) if m else None


def _trip_count(inst: Instruction,
                comps: Dict[str, Computation]) -> int:
    m = _TRIP_RE.search(inst.attr_text)
    if m:
        return int(m.group(1))
    cond = _attr(inst, "condition")
    best = 1
    if cond and cond in comps:
        for ci in comps[cond].instructions:
            mm = _CONST_RE.search(f"{ci.op}({ci.operand_text})")
            if mm:
                best = max(best, int(mm.group(1)))
    return best


def _contraction_size(inst: Instruction, comp: Computation) -> int:
    if not inst.operand_refs:
        return 1
    lhs_shapes = comp.shapes.get(inst.operand_refs[0], [])
    if not lhs_shapes:
        return 1
    _, lhs_dims = lhs_shapes[0]
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.attr_text)
    contract = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return contract


def _out_elems(inst: Instruction) -> int:
    n = 1
    for _, dims in inst.result_shapes:
        for d in dims:
            n *= d
    return n


def _conv_kernel_elems(inst: Instruction, comp: Computation) -> int:
    if len(inst.operand_refs) < 2:
        return 1
    ker = comp.shapes.get(inst.operand_refs[1], [])
    n = 1
    for _, dims in ker:
        for d in dims:
            n *= d
    return max(n, 1)


def _param_index(inst: Instruction) -> Optional[int]:
    m = re.match(r"\s*(\d+)\s*$", inst.operand_text)
    return int(m.group(1)) if m else None


_MOVEMENT_OPS = {"parameter", "constant", "convert", "bitcast", "copy",
                 "reshape", "transpose", "broadcast"}


def _movement_fusion_bytes(fused: Computation,
                           inst: Instruction) -> Optional[float]:
    """TPU-semantic traffic for pure data-movement fusions.

    The CPU backend widens bf16 dots to f32 and then carries/round-trips
    whole buffers through convert chains, and functional scan-ys cache
    updates appear as full-buffer DUS fusions; on TPU (native bf16 MXU +
    donated-buffer aliasing) these are (a) nonexistent or (b) in-place
    writes of just the update region.  Returns None when the fusion is not
    pure data movement (normal accounting applies).

      * fusion of {convert/copy/bitcast/reshape/...} + dynamic-update-slice
        -> 2x the update-region bytes (read+write, aliased buffer);
      * fusion of only converts/copies/bitcasts -> 2x the narrower of
        input/output (one pass at storage width).
    """
    ops = {fi.op for fi in fused.instructions}
    if not ops <= (_MOVEMENT_OPS | {"dynamic-update-slice"}):
        return None
    dus = [fi for fi in fused.instructions
           if fi.op == "dynamic-update-slice"]
    if dus:
        total = 0.0
        for d in dus:
            if len(d.operand_refs) >= 2:
                upd = fused.shapes.get(d.operand_refs[1], [])
                total += 2.0 * _bytes_of(upd)
        return total if total else 2.0 * _bytes_of(inst.result_shapes)
    # convert/copy-only fusion: one read + one write at the narrow width
    out_b = _bytes_of(inst.result_shapes)
    in_b = sum(_bytes_of(fused.shapes.get(fi.name, []))
               for fi in fused.instructions if fi.op == "parameter")
    return 2.0 * min(out_b, in_b) if in_b else 2.0 * out_b


def _fusion_operand_bytes(fused: Computation, inst: Instruction,
                          comp: Computation) -> float:
    """Reads of a fusion call, slice-aware: a parameter consumed (only)
    through dynamic-slice/gather inside the fusion reads ~the slice, not
    the whole buffer (the scan-over-layers param gather, cache reads)."""
    # param index -> param instruction name inside the fused computation
    param_names: Dict[int, str] = {}
    for fi in fused.instructions:
        if fi.op == "parameter":
            idx = _param_index(fi)
            if idx is not None:
                param_names[idx] = fi.name
    # per-param sliced read sizes
    sliced: Dict[str, float] = {}
    consumers: Dict[str, List[Instruction]] = {}
    for fi in fused.instructions:
        for ref in fi.operand_refs:
            consumers.setdefault(ref, []).append(fi)
    for idx, pname in param_names.items():
        uses = consumers.get(pname, [])
        if uses and all(u.op in ("dynamic-slice", "gather")
                        and u.operand_refs
                        and u.operand_refs[0] == pname for u in uses):
            sliced[pname] = sum(_bytes_of(u.result_shapes) for u in uses)
    total = 0.0
    for i, ref in enumerate(inst.operand_refs):
        full = _bytes_of(comp.shapes.get(ref, []))
        pname = param_names.get(i)
        if pname is not None and pname in sliced:
            total += min(full, sliced[pname])
        else:
            total += full
    return total


def _fusion_output_bytes(fused: Computation, inst: Instruction) -> float:
    """Writes of a fusion call: a root dynamic-update-slice writes only the
    update (the buffer aliases in place on TPU)."""
    root = next((fi for fi in fused.instructions if fi.is_root),
                fused.instructions[-1] if fused.instructions else None)
    if root is not None and root.op == "dynamic-update-slice" \
            and len(root.operand_refs) >= 2:
        upd = fused.shapes.get(root.operand_refs[1], [])
        if upd:
            return _bytes_of(upd)
    return _bytes_of(inst.result_shapes)


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    loops: List[Tuple[str, int]] = dataclasses.field(default_factory=list)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def analyze(text: str) -> HloCost:
    comps = parse_hlo(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return HloCost()
    fusion_bodies = set()
    for c in comps.values():
        for inst in c.instructions:
            if inst.op == "fusion":
                called = _attr(inst, "calls")
                if called:
                    fusion_bodies.add(called)

    cost = HloCost()
    seen_fused: Dict[str, float] = {}

    def fused_flops(comp_name: str, mult: float):
        comp = comps.get(comp_name)
        if comp is None:
            return
        for inst in comp.instructions:
            if inst.op == "dot":
                cost.flops += (2.0 * _out_elems(inst)
                               * _contraction_size(inst, comp)) * mult
            elif inst.op == "convolution":
                cost.flops += (2.0 * _out_elems(inst)
                               * _conv_kernel_elems(inst, comp)) * mult
            sub = _attr(inst, "calls")
            if sub:
                fused_flops(sub, mult)

    def visit(comp_name: str, mult: float):
        comp = comps.get(comp_name)
        if comp is None:
            return
        for inst in comp.instructions:
            if inst.op.endswith("-done"):
                continue
            base = None
            for c in _COLLECTIVES:
                if inst.op == c or inst.op == c + "-start":
                    base = c
                    break
            if base is not None:
                b = _operand_bytes(inst, comp)
                if b == 0:
                    b = _bytes_of(inst.result_shapes)
                cost.collectives[base] += b * mult
                cost.collective_counts[base] += mult
                cost.collective_bytes += b * mult
                cost.bytes += (_bytes_of(inst.result_shapes)
                               + _operand_bytes(inst, comp)) * mult
                continue
            if inst.op == "while":
                trips = _trip_count(inst, comps)
                body = _attr(inst, "body")
                cost.loops.append((body or "?", trips))
                if body in comps:
                    visit(body, mult * trips)
                continue
            if inst.op in ("call", "conditional"):
                for key in ("to_apply", "branch_computations", "calls"):
                    sub = _attr(inst, key)
                    if sub and sub in comps and sub not in fusion_bodies:
                        visit(sub, mult)
                continue
            if inst.op == "fusion":
                sub = _attr(inst, "calls")
                if sub and sub in comps:
                    mv = _movement_fusion_bytes(comps[sub], inst)
                    if mv is not None:
                        cost.bytes += mv * mult
                    else:
                        cost.bytes += (
                            _fusion_output_bytes(comps[sub], inst)
                            + _fusion_operand_bytes(comps[sub], inst,
                                                    comp)) * mult
                        fused_flops(sub, mult)
                else:
                    cost.bytes += (_bytes_of(inst.result_shapes)
                                   + _operand_bytes(inst, comp)) * mult
                continue
            if inst.op == "dot":
                cost.flops += (2.0 * _out_elems(inst)
                               * _contraction_size(inst, comp)) * mult
                cost.bytes += (_bytes_of(inst.result_shapes)
                               + _operand_bytes(inst, comp)) * mult
                continue
            if inst.op == "convolution":
                cost.flops += (2.0 * _out_elems(inst)
                               * _conv_kernel_elems(inst, comp)) * mult
                cost.bytes += (_bytes_of(inst.result_shapes)
                               + _operand_bytes(inst, comp)) * mult
                continue
            if inst.op in _SKIP_OPS:
                continue
            if inst.op in ("dynamic-slice", "gather"):
                # reads ~= slice/output size (+ small indices), not the
                # whole source buffer
                cost.bytes += 2.0 * _bytes_of(inst.result_shapes) * mult
                continue
            if inst.op in ("dynamic-update-slice", "scatter"):
                upd = comp.shapes.get(inst.operand_refs[1], []) \
                    if len(inst.operand_refs) >= 2 else []
                b = _bytes_of(upd) if upd else _bytes_of(inst.result_shapes)
                cost.bytes += 2.0 * b * mult
                continue
            cost.bytes += (_bytes_of(inst.result_shapes)
                           + _operand_bytes(inst, comp)) * mult

    visit(entry.name, 1.0)
    return cost
