import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DRYRUN_DEVICES", "512"))

"""Dry-run of the DISTRIBUTED PA-SMO solver on the production mesh — the
paper's own workload at pod scale (beyond the required 40 LM cells).

Lowers+compiles `core.sharded.solve_sharded` with the example dimension
l sharded over all 256 (or 512) chips, and derives the per-iteration
roofline: the brief's insight check — SMO's minimal working set makes the
per-iteration collective payload O(d), so at pod scale the solver is
bounded by the LOCAL kernel-row compute/bandwidth, not the network.

    python -m repro.launch.dryrun_solver --l 1048576 --d 256
"""

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402

from repro.core.sharded import solve_sharded          # noqa: E402
from repro.core.solver import SolverConfig            # noqa: E402
from repro.launch import hlo_analysis                 # noqa: E402
from repro.launch import roofline as rf               # noqa: E402
from repro.launch.dryrun import make_mesh_by_name     # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--l", type=int, default=1_048_576)
    ap.add_argument("--d", type=int, default=256)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--max-iter", type=int, default=1_000_000)
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    mesh = make_mesh_by_name(args.mesh)
    chips = int(np.prod(list(mesh.shape.values())))
    cfg = SolverConfig(algorithm="pasmo", eps=1e-3, max_iter=args.max_iter)

    # flatten (data, model[, pod]) into one solver axis by reusing 'data'
    # only — the solver shards l over data; model-axis devices replicate
    # (a 2D solver x hyperparameter grid layout is the batched extension).
    X = jax.ShapeDtypeStruct((args.l, args.d), jnp.float32)
    y = jax.ShapeDtypeStruct((args.l,), jnp.float32)

    def run(Xv, yv):
        return solve_sharded(Xv, yv, 10.0, 0.5, mesh, cfg)

    t0 = time.monotonic()
    lowered = jax.jit(run).lower(X, y)
    compiled = lowered.compile()
    t_compile = time.monotonic() - t0
    cost = hlo_analysis.analyze(compiled.as_text())

    # per-iteration costs: the while loop dominates; subtract one-time work
    # by reporting per-trip quantities of the main loop
    loop_trips = max((t for _, t in cost.loops), default=1)
    per_iter_flops = cost.flops / max(loop_trips, 1)
    per_iter_bytes = cost.bytes / max(loop_trips, 1)
    per_iter_coll = cost.collective_bytes / max(loop_trips, 1)

    rec = {
        "arch": "pasmo-solver", "shape": f"l{args.l}-d{args.d}",
        "mesh": args.mesh, "chips": chips, "ok": True,
        "time_compile_s": t_compile,
        "max_iter_used_as_trip_count": loop_trips,
        "per_iteration": {
            "flops_per_device": per_iter_flops,
            "bytes_per_device": per_iter_bytes,
            "collective_bytes_per_device": per_iter_coll,
            "compute_us": per_iter_flops / rf.PEAK_FLOPS * 1e6,
            "memory_us": per_iter_bytes / rf.HBM_BW * 1e6,
            "collective_us": per_iter_coll / rf.ICI_BW * 1e6,
        },
        "collectives": {**cost.collectives,
                        "counts": cost.collective_counts},
    }
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out,
                        f"{args.mesh}__pasmo-solver__l{args.l}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=2, default=str)
    p = rec["per_iteration"]
    print(f"[OK] pasmo-solver l={args.l} d={args.d} mesh={args.mesh} "
          f"({t_compile:.1f}s compile)")
    print(f"per-iteration/device: compute {p['compute_us']:.3f}us  "
          f"memory {p['memory_us']:.3f}us  "
          f"collective {p['collective_us']:.3f}us")
    dom = max(("compute", "memory", "collective"),
              key=lambda k: p[k + "_us"])
    print(f"dominant: {dom}; artifact: {path}")


if __name__ == "__main__":
    main()
