"""Production serving launcher: batched prefill + decode loop on a mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --smoke --batch 4 --prompt-len 32 --tokens 64
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.configs.base import ServeConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import registry
from repro.sharding import DEFAULT_RULES, axis_rules
from repro.train.serve_step import make_prefill, make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    fp32 = jax.default_backend() == "cpu"
    dt = "float32" if fp32 else "bfloat16"
    sc = ServeConfig(seq_len=args.prompt_len + args.tokens,
                     batch=args.batch, param_dtype=dt, compute_dtype=dt,
                     kv_dtype=dt)
    mesh = make_production_mesh() if args.production_mesh \
        else make_host_mesh(len(jax.devices()), 1)

    with axis_rules(mesh, DEFAULT_RULES):
        params = registry.init_params(
            jax.random.PRNGKey(0), cfg,
            jnp.float32 if fp32 else jnp.bfloat16)
        rng = np.random.default_rng(0)
        prompt = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)),
            jnp.int32)}
        if cfg.family == "vlm":
            prompt["patches"] = jnp.asarray(
                rng.normal(size=(args.batch, cfg.vision_tokens,
                                 cfg.d_model)) * 0.02,
                jnp.float32 if fp32 else jnp.bfloat16)
        if cfg.family == "encdec":
            prompt["frames"] = jnp.asarray(
                rng.normal(size=(args.batch, cfg.encoder_seq,
                                 cfg.d_model)) * 0.02,
                jnp.float32 if fp32 else jnp.bfloat16)

        prefill = jax.jit(make_prefill(cfg, sc))
        step = jax.jit(make_serve_step(cfg, sc), donate_argnums=(1,))

        t0 = time.time()
        logits, cache = prefill(params, prompt)
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out = [tok]
        t0 = time.time()
        for t in range(args.tokens - 1):
            logits, cache = step(params, cache, tok,
                                 jnp.asarray(args.prompt_len + t,
                                             jnp.int32))
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0
        gen = jnp.concatenate(out, axis=1)
        print(f"arch={cfg.name} batch={args.batch}")
        print(f"prefill {args.prompt_len} tok: {t_prefill:.2f}s; decode "
              f"{args.tokens} tok: {t_decode:.2f}s "
              f"({args.batch * args.tokens / max(t_decode, 1e-9):.1f} "
              f"tok/s)")
        print("first sequence:", np.asarray(gen[0])[:12], "...")


if __name__ == "__main__":
    main()
