"""Host-tier telemetry: JSONL event sink, phase scopes, env fingerprint.

The device tier (:mod:`repro.telemetry.ring`) samples iteration dynamics
inside the fused while_loop; this module is everything that happens on
the host around it:

* :func:`env_fingerprint` — the machine/runtime identity stamped into
  every benchmark record and telemetry artifact, so perf drift across
  runners (the ``doubled_row_parity`` 0.91 -> 0.66 -> 0.77 incident) is
  attributable;
* :class:`JsonlSink` — an append-only structured event stream (one JSON
  object per line) that also keeps the events in memory for in-process
  consumers (the report CLI reads either);
* :func:`phase_scope` — wall-clock timer + ``jax.profiler``
  ``TraceAnnotation`` named scope, so solver phases show up both in the
  JSONL stream and in profiler traces when one is being captured.
"""

from __future__ import annotations

import contextlib
import hashlib
import json
import os
import platform
import socket
import time

import jax
import numpy as np

FINGERPRINT_KEYS = ("jax_version", "backend", "device_kind",
                    "device_count", "cpu_count", "host")


def env_fingerprint() -> dict:
    """Runtime identity for benchmark records and telemetry artifacts.

    The hostname is hashed — records are committed to the repo and
    uploaded as CI artifacts, so the raw name stays out of them.
    """
    try:
        devs = jax.devices()
        backend = jax.default_backend()
        kind = devs[0].device_kind if devs else "unknown"
        count = len(devs)
    except Exception:  # pragma: no cover - backend init failure
        backend, kind, count = "unknown", "unknown", 0
    host = hashlib.sha256(socket.gethostname().encode()).hexdigest()[:12]
    return {
        "jax_version": jax.__version__,
        "backend": backend,
        "device_kind": kind,
        "device_count": count,
        "cpu_count": os.cpu_count() or 0,
        "host": host,
        "python": platform.python_version(),
        "machine": platform.machine(),
    }


def fingerprint_diff(stored: dict | None, current: dict | None) -> list:
    """Human-readable stored-vs-current mismatch lines (empty = match)."""
    stored = stored or {}
    current = current or {}
    lines = []
    for k in FINGERPRINT_KEYS:
        a, b = stored.get(k), current.get(k)
        if a != b:
            lines.append(f"{k}: recorded={a!r} current={b!r}")
    return lines


def _to_plain(v):
    """JSON-safe coercion for jax/numpy leaves (incl. arrays -> lists)."""
    if isinstance(v, (jax.Array, np.ndarray, np.generic)):
        return np.asarray(v).tolist()
    if isinstance(v, dict):
        return {k: _to_plain(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_to_plain(x) for x in v]
    return v


class JsonlSink:
    """Append-only JSONL event stream (+ in-memory mirror).

    ``path=None`` keeps events in memory only — the default for tests
    and for callers that just want :meth:`events` / the summary dict.
    """

    def __init__(self, path: str | os.PathLike | None = None):
        self.path = os.fspath(path) if path is not None else None
        self.events: list[dict] = []
        self._fh = open(self.path, "a") if self.path is not None else None

    def emit(self, event: str, **payload) -> dict:
        rec = {"event": event, "ts": time.time()}
        rec.update({k: _to_plain(v) for k, v in payload.items()})
        return self._append(rec)

    def emit_plain(self, event: str, payload: dict) -> dict:
        """:meth:`emit` minus the ``_to_plain`` walk.

        For hot callers (the per-lane ring drain) whose payload is
        already JSON-safe — ``tolist()`` output and python scalars; the
        recursive coercion walk over hundreds of already-plain floats
        per lane was the drain's dominant cost.
        """
        rec = {"event": event, "ts": time.time()}
        rec.update(payload)
        return self._append(rec)

    def _append(self, rec: dict) -> dict:
        self.events.append(rec)
        if self._fh is not None:
            self._fh.write(json.dumps(rec) + "\n")
            self._fh.flush()
        return rec

    def close(self):
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def _trace_annotation(name: str):
    """Profiler named scope, tolerant of jax versions/backends without it."""
    ann = getattr(jax.profiler, "TraceAnnotation", None)
    if ann is None:  # pragma: no cover - very old jax
        return contextlib.nullcontext()
    try:
        return ann(name)
    except Exception:  # pragma: no cover - profiler backend quirk
        return contextlib.nullcontext()


@contextlib.contextmanager
def phase_scope(name: str, sink: JsonlSink | None = None, **meta):
    """Wall-clock + profiler scope around a solver phase.

    Emits a ``phase`` event with the measured ``seconds`` on exit; the
    ``TraceAnnotation`` makes the same span visible in a profiler trace
    when one is active.  Usable with ``sink=None`` as a pure profiler
    scope.
    """
    t0 = time.perf_counter()
    with _trace_annotation(name):
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            if sink is not None:
                sink.emit("phase", name=name, seconds=dt, **meta)


def read_jsonl(path) -> list[dict]:
    """Load a JSONL artifact back into event dicts (blank lines skipped)."""
    out = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
