"""Solver observability: flight recorder for the fused PA-SMO engine.

Three tiers (see ISSUE 8 / the README "Observability" section):

* **device** — :class:`~repro.telemetry.ring.TelemetryRing`, a bounded
  per-lane ring-buffer pytree carried through the fused while_loop
  (:mod:`repro.telemetry.ring`);
* **host** — JSONL event sink, phase timers / profiler scopes, and the
  environment fingerprint (:mod:`repro.telemetry.sink`);
* **report** — ``python -m repro.launch.telemetry_report`` renders
  convergence tables and a straggler diagnosis from the JSONL artifact.

:class:`Diagnostics` is the user-facing knob threaded through the grid
drivers and the ``SVC``/``SVR``/``OneClassSVM`` facades
(``diagnostics=``).  It is a *host* object (sink handles aren't
hashable), so the engines themselves take the static
:class:`~repro.telemetry.ring.RingConfig` via ``telemetry=`` and the
drivers drain the returned rings into the ``Diagnostics`` sink.
"""

from __future__ import annotations

import numpy as np

from repro.telemetry.ring import (RingConfig, TelemetryRing, ring_init,
                                  ring_slice, ring_update)
from repro.telemetry.sink import (JsonlSink, _to_plain, env_fingerprint,
                                  fingerprint_diff, phase_scope, read_jsonl)

__all__ = [
    "Diagnostics", "RingConfig", "TelemetryRing", "ring_init",
    "ring_update", "ring_slice", "JsonlSink", "env_fingerprint",
    "fingerprint_diff", "phase_scope", "read_jsonl",
]


class Diagnostics:
    """Host-side flight-recorder handle for one or more solver runs.

    Parameters
    ----------
    path : optional JSONL output path (``None`` keeps events in memory;
        read them back via ``diag.sink.events``).
    ring : device-tier sampling geometry, or ``None`` to record host
        phases only (the engines then run their telemetry-free jaxpr).
    """

    def __init__(self, path=None, *, ring: RingConfig | None = RingConfig(),
                 sink: JsonlSink | None = None):
        self.ring_config = ring
        self.sink = sink if sink is not None else JsonlSink(path)
        self.lanes: list[dict] = []
        self.sink.emit("fingerprint", **env_fingerprint())

    # -- host tier ---------------------------------------------------------

    def scope(self, name: str, **meta):
        """Wall-clock + profiler scope; emits a ``phase`` event."""
        return phase_scope(name, self.sink, **meta)

    def event(self, event: str, **payload):
        return self.sink.emit(event, **payload)

    # -- device tier drain -------------------------------------------------

    def drain_ring(self, ring: TelemetryRing, meta=None, result=None):
        """Convert a returned ring into per-lane ``lane`` events.

        ``meta`` is an optional per-lane list of dicts (gamma/C/labels —
        what the straggler report keys on); ``result`` an optional
        :class:`~repro.core.solver_fused.FusedResult` view of the same
        lanes contributing final scalars.
        """
        if ring is None or self.ring_config is None:
            return []
        cfg = self.ring_config
        r = {k: np.asarray(getattr(ring, k)) for k in (
            "t", "gap", "n_active", "n_unshrink", "n_samples",
            "ratio", "ratio_t", "n_ratio")}
        B = r["n_samples"].shape[0]
        res = {}
        if result is not None:
            # tolerant: SolveResult-shaped objects lack n_unshrink (the
            # drain then falls back to the ring's last sample)
            for key in ("iterations", "kkt_gap", "converged",
                        "n_planning", "n_unshrink"):
                v = getattr(result, key, None)
                if v is not None:
                    res[key] = np.asarray(v)
        out = []
        for lane in range(B):
            ns = int(min(r["n_samples"][lane], cfg.cap))
            nr = int(min(r["n_ratio"][lane], cfg.ratio_cap))
            rec = {
                "lane": len(self.lanes),
                "n_samples": int(r["n_samples"][lane]),
                "n_ratio": int(r["n_ratio"][lane]),
                "samples": {
                    "t": r["t"][lane, :ns].tolist(),
                    "gap": r["gap"][lane, :ns].tolist(),
                    "n_active": r["n_active"][lane, :ns].tolist(),
                    "n_unshrink": r["n_unshrink"][lane, :ns].tolist(),
                },
                "ratio": {
                    "t": r["ratio_t"][lane, :nr].tolist(),
                    "value": r["ratio"][lane, :nr].tolist(),
                },
            }
            if meta is not None:
                rec.update({k: _to_plain(v) for k, v in meta[lane].items()})
            if "iterations" in res:
                rec["iterations"] = int(res["iterations"][lane])
            if "kkt_gap" in res:
                rec["kkt_gap"] = float(res["kkt_gap"][lane])
            if "converged" in res:
                rec["converged"] = bool(res["converged"][lane])
            if "n_planning" in res:
                rec["n_planning"] = int(res["n_planning"][lane])
                if rec.get("iterations"):
                    # share of iterations whose 2-direction step was
                    # accepted: the planning rate under algorithm="pasmo",
                    # the conjugate acceptance rate under step="conjugate"
                    # (same channel — the modes are mutually exclusive)
                    rec["accepted_step_share"] = (
                        rec["n_planning"] / rec["iterations"])
            if "n_unshrink" in res:
                rec["total_unshrink"] = int(res["n_unshrink"][lane])
            elif ns:
                rec["total_unshrink"] = int(r["n_unshrink"][lane, ns - 1])
            self.lanes.append(rec)
            # emit_plain: everything above is tolist() output / python
            # scalars — the per-element coercion walk dominated the drain
            out.append(self.sink.emit_plain("lane", rec))
        return out

    # -- summary -----------------------------------------------------------

    def summary(self, top_k: int = 5) -> dict:
        """Aggregate view: iteration histogram, straggler top-k, totals."""
        iters = np.asarray(
            [rec.get("iterations", 0) for rec in self.lanes], np.int64)
        s = {"n_lanes": len(self.lanes),
             "total_planning": int(sum(rec.get("n_planning", 0)
                                       for rec in self.lanes)),
             "total_unshrink": int(sum(rec.get("total_unshrink", 0)
                                       for rec in self.lanes)),
             "n_converged": int(sum(bool(rec.get("converged", False))
                                    for rec in self.lanes))}
        if len(iters):
            edges = np.histogram_bin_edges(iters, bins=min(8, max(
                1, len(iters))))
            hist, _ = np.histogram(iters, bins=edges)
            order = np.argsort(iters)[::-1][:top_k]
            total = max(1, int(iters.sum()))
            s["iteration_histogram"] = {
                "edges": [float(e) for e in edges],
                "counts": [int(c) for c in hist]}
            s["stragglers"] = [{
                "lane": int(k),
                "iterations": int(iters[k]),
                "iter_share": float(iters[k] / total),
                **{key: self.lanes[k][key] for key in ("gamma", "C", "label")
                   if key in self.lanes[k]},
            } for k in order]
            s["total_iterations"] = int(iters.sum())
            s["max_iterations"] = int(iters.max())
        return s

    def finalize(self, top_k: int = 5) -> dict:
        """Emit the ``summary`` event and close the sink file handle."""
        s = self.summary(top_k)
        self.sink.emit("summary", **s)
        self.sink.close()
        return s
