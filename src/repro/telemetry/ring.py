"""Device-tier telemetry: bounded per-lane ring buffers for the fused engine.

The fused batched solver (:mod:`repro.core.solver_fused`) runs a whole
(gamma, class, C) grid inside ONE ``lax.while_loop`` and, without help,
only final scalars escape.  :class:`TelemetryRing` is a small pytree of
bounded per-lane buffers carried through the loop state that samples the
iteration dynamics the paper actually argues about:

* every ``sample_every`` iterations (plus a forced sample on the
  iteration a lane freezes): KKT gap, active-set size under shrinking,
  and the running unshrink counter;
* on every *accepted* planning step: the mu/mu* ratio — the classic
  engine's Fig. 3 ``record_trace`` channel, generalized to B lanes.

Overflow follows the classic trace precedent (oldest-wins): the write
slot is ``min(count, cap - 1)``, so the first ``cap - 1`` samples are
kept verbatim and the last slot always holds the newest sample, while
the count keeps incrementing so overflow is detectable
(``n_samples > cap``).

:class:`RingConfig` is frozen/hashable so it can ride ``jit`` static
arguments; ``telemetry=None`` at the solver layer means "no ring in the
carry at all" — the traced jaxpr must stay byte-identical to the
telemetry-free engine (asserted in ``tests/test_telemetry.py``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class RingConfig:
    """Static (hashable) ring geometry.

    ``sample_every`` is the sampling period in loop iterations;
    ``cap`` bounds the sampled channels and ``ratio_cap`` the
    planning-ratio event channel (both per lane).
    """

    sample_every: int = 64
    cap: int = 128
    ratio_cap: int = 128

    def __post_init__(self):
        assert self.sample_every >= 1
        assert self.cap >= 1 and self.ratio_cap >= 1


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TelemetryRing:
    """Per-lane ring buffers (all leaves lane-leading, shard-safe).

    Sampled channels (written every ``sample_every`` iterations and on
    lane freeze): ``t`` (iteration stamp), ``gap`` (KKT gap),
    ``n_active`` (active-set size; the full width when shrinking is
    off), ``n_unshrink`` (running unshrink-event counter).  Event
    channel (written on accepted planning steps): ``ratio`` = mu/mu*
    with its ``ratio_t`` stamp.  ``n_samples``/``n_ratio`` count total
    writes and may exceed the caps (oldest-wins overflow).
    """

    t: jax.Array           # (B, cap) int32
    gap: jax.Array         # (B, cap)
    n_active: jax.Array    # (B, cap) int32
    n_unshrink: jax.Array  # (B, cap) int32
    n_samples: jax.Array   # (B,) int32
    ratio: jax.Array       # (B, ratio_cap)
    ratio_t: jax.Array     # (B, ratio_cap) int32
    n_ratio: jax.Array     # (B,) int32


def ring_init(cfg: RingConfig, B: int, dtype) -> TelemetryRing:
    zi = jnp.zeros((B, cfg.cap), jnp.int32)
    return TelemetryRing(
        t=zi, gap=jnp.zeros((B, cfg.cap), dtype), n_active=zi,
        n_unshrink=zi, n_samples=jnp.zeros((B,), jnp.int32),
        ratio=jnp.zeros((B, cfg.ratio_cap), dtype),
        ratio_t=jnp.zeros((B, cfg.ratio_cap), jnp.int32),
        n_ratio=jnp.zeros((B,), jnp.int32))


def ring_update(ring: TelemetryRing, cfg: RingConfig, *, t, active,
                newly_done, gap, n_active, n_unshrink, plan_event,
                ratio) -> TelemetryRing:
    """One in-loop telemetry step (pure O(B) algebra, no row-width work).

    ``t`` is the scalar loop counter; every other argument is (B,).
    ``active`` marks lanes live *entering* the iteration, ``newly_done``
    lanes that froze on it (forces a final sample so the convergence
    point is always captured), ``plan_event`` accepted planning steps.
    """
    B = ring.n_samples.shape[0]
    lanes = jnp.arange(B, dtype=jnp.int32)
    ti = jnp.asarray(t, jnp.int32)

    write = active & (((ti % cfg.sample_every) == 0) | newly_done)
    slot = jnp.minimum(ring.n_samples, cfg.cap - 1)

    def wr(buf, val):
        cur = buf[lanes, slot]
        val = val.astype(buf.dtype)
        return buf.at[lanes, slot].set(jnp.where(write, val, cur))

    ev = plan_event & active
    rslot = jnp.minimum(ring.n_ratio, cfg.ratio_cap - 1)

    def wr_ev(buf, val):
        cur = buf[lanes, rslot]
        val = val.astype(buf.dtype)
        return buf.at[lanes, rslot].set(jnp.where(ev, val, cur))

    # the scatters are the expensive part and fire on a small fraction
    # of iterations (every sample_every-th, lane freezes, accepted
    # planning steps) — cond them out so the common iteration pays only
    # the O(B) predicates and counter bumps
    t_b, gap_b, na_b, nu_b = jax.lax.cond(
        jnp.any(write),
        lambda bufs: (wr(bufs[0], jnp.broadcast_to(ti, (B,))),
                      wr(bufs[1], gap), wr(bufs[2], n_active),
                      wr(bufs[3], n_unshrink)),
        lambda bufs: bufs,
        (ring.t, ring.gap, ring.n_active, ring.n_unshrink))
    ratio_b, rt_b = jax.lax.cond(
        jnp.any(ev),
        lambda bufs: (wr_ev(bufs[0], ratio),
                      wr_ev(bufs[1], jnp.broadcast_to(ti, (B,)))),
        lambda bufs: bufs,
        (ring.ratio, ring.ratio_t))

    return TelemetryRing(
        t=t_b, gap=gap_b, n_active=na_b, n_unshrink=nu_b,
        n_samples=ring.n_samples + write.astype(jnp.int32),
        ratio=ratio_b, ratio_t=rt_b,
        n_ratio=ring.n_ratio + ev.astype(jnp.int32))


def ring_slice(ring: TelemetryRing, idx) -> TelemetryRing:
    """Lane-subset view (all leaves are lane-leading)."""
    return jax.tree.map(lambda leaf: leaf[idx], ring)
