"""Training step: mixed precision, microbatch gradient accumulation,
clipping, optional gradient compression, optimizer apply.

The step is a single jit-able function of (TrainState, batch); microbatches
run as a ``lax.scan`` so the HLO stays compact, and the accumulation buffer
dtype is configurable (bf16 accumulation halves the grad-buffer HBM for the
314B-parameter cells — see DESIGN.md §6).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import registry
from repro.train import optimizer as opt_mod
from repro.train.compression import ef_compress_grads


class TrainState(NamedTuple):
    params: Any
    opt: Any
    ef: Any          # error-feedback residuals (compression) or None
    step: jax.Array


def _cast(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def init_state(rng, cfg: ModelConfig, tc: TrainConfig) -> TrainState:
    pdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[tc.param_dtype]
    params = registry.init_params(rng, cfg, pdt)
    ef = None
    if tc.compress_grads:
        ef = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return TrainState(params=params, opt=opt_mod.init(params, tc), ef=ef,
                      step=jnp.zeros((), jnp.int32))


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    cdt = {"float32": jnp.float32,
           "bfloat16": jnp.bfloat16}[tc.compute_dtype]
    adt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[tc.accum_dtype]

    def loss_of(params, mb):
        return registry.loss_fn(_cast(params, cdt), cfg, mb, remat=tc.remat)

    def train_step(state: TrainState, batch: Dict[str, Any]):
        params = state.params
        if tc.microbatches > 1:
            M = tc.microbatches

            def split(x):
                return x.reshape((M, x.shape[0] // M) + x.shape[1:])

            mbs = jax.tree.map(split, batch)

            if tc.accum_mode == "inside_grad":
                # microbatch scan INSIDE the differentiated function:
                # autodiff accumulates layer grads in the backward scan
                # carry as LOCAL partial sums, so the cross-data gradient
                # reduction happens once per step instead of once per
                # microbatch (16x less gradient all-reduce volume on the
                # grok cell — EXPERIMENTS.md §Perf).
                def total_loss(p):
                    def body(carry, mb):
                        l, _ = loss_of(p, mb)
                        return carry + l, l

                    s, losses = jax.lax.scan(
                        body, jnp.zeros((), jnp.float32), mbs)
                    return s / M, losses

                (loss, losses), grads = jax.value_and_grad(
                    total_loss, has_aux=True)(params)
            else:
                acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, adt),
                                    params)

                def body(acc, mb):
                    (l, metrics), g = jax.value_and_grad(
                        loss_of, has_aux=True)(params, mb)
                    acc = jax.tree.map(
                        lambda a, gg: a + gg.astype(adt), acc, g)
                    return acc, l

                acc, losses = jax.lax.scan(body, acc0, mbs)
                grads = jax.tree.map(
                    lambda a: a.astype(jnp.float32) / M, acc)
                loss = jnp.mean(losses)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)

        ef = state.ef
        if tc.compress_grads:
            grads, ef = ef_compress_grads(grads, ef)

        grads, gnorm = opt_mod.clip_by_global_norm(grads, tc.grad_clip)
        lr = opt_mod.lr_schedule(tc, state.step)
        new_params, new_opt = opt_mod.update(grads, state.opt, params, tc,
                                             lr)
        new_state = TrainState(params=new_params, opt=new_opt, ef=ef,
                               step=state.step + 1)
        return new_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    return train_step
