"""Serving: batched prefill + single-token decode steps.

``serve_step`` is the one-new-token function the decode_* dry-run cells
lower: (params, cache, tokens, pos) -> (logits, cache), with the cache
donated so the ring update is in-place on device.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ServeConfig
from repro.models import registry


def _cast(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def make_serve_step(cfg: ModelConfig, sc: ServeConfig):
    cdt = {"float32": jnp.float32,
           "bfloat16": jnp.bfloat16}[sc.compute_dtype]

    def serve_step(params, cache, tokens, pos):
        logits, cache = registry.decode_step(_cast(params, cdt), cfg, cache,
                                             tokens, pos)
        return logits, cache

    return serve_step


def make_prefill(cfg: ModelConfig, sc: ServeConfig):
    cdt = {"float32": jnp.float32,
           "bfloat16": jnp.bfloat16}[sc.compute_dtype]
    kdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[sc.kv_dtype]

    def prefill(params, batch: Dict[str, Any]):
        return registry.prefill(_cast(params, cdt), cfg, batch, sc.seq_len,
                                kv_dtype=kdt)

    return prefill


def greedy_generate(cfg: ModelConfig, sc: ServeConfig, params,
                    prompt: Dict[str, Any], steps: int):
    """Simple batched greedy generation driver (example/serving demo)."""
    prefill = jax.jit(make_prefill(cfg, sc))
    step = jax.jit(make_serve_step(cfg, sc), donate_argnums=(1,))
    logits, cache = prefill(params, prompt)
    S = prompt["tokens"].shape[1]
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    for t in range(steps - 1):
        logits, cache = step(params, cache, tok,
                             jnp.asarray(S + t, jnp.int32))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
