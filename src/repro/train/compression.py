"""Gradient compression: int8 quantization with error feedback.

Two layers:

* ``quantize_int8`` / ``dequantize_int8`` — per-tensor symmetric int8 with
  stochastic-free deterministic rounding (reproducible restarts).
* ``ef_compress_grads`` — error-feedback (EF14/EF21-style) wrapper: the
  quantization residual is carried to the next step, so the *sequence* of
  applied updates is unbiased and SGD/Adam converge at the uncompressed
  rate asymptotically.
* ``compressed_psum`` — shard_map building block that quantizes before the
  cross-replica sum and dequantizes after, cutting DP all-reduce bytes 4x
  (bf16) / 8x (f32).  Used by the manual-DP path; the pjit path applies
  quantize+EF to the already-reduced gradient, modeling the same numerics.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8.  Returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_grads(grads: Any, residuals: Any) -> Tuple[Any, Any]:
    """Quantize (grad + residual); carry the quantization error forward."""

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        return deq, g32 - deq

    out = jax.tree.map(one, grads, residuals)
    outer = jax.tree.structure(grads)
    inner = jax.tree.structure((0, 0))
    return jax.tree.transpose(outer, inner, out)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-compressed all-reduce (inside shard_map).

    Quantizes locally, sums int32 across the axis (8x fewer bytes on the
    wire than f32), then rescales by the max scale.  Biased by scale
    harmonization; pair with error feedback at the call site.
    """
    q, s = quantize_int8(x)
    s_max = jax.lax.pmax(s, axis_name)
    # requantize against the shared scale so the integer sum is coherent
    q2 = jnp.clip(jnp.round(x.astype(jnp.float32) / s_max), -127,
                  127).astype(jnp.int32)
    total = jax.lax.psum(q2, axis_name)
    return total.astype(jnp.float32) * s_max
