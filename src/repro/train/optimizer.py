"""In-house optimizers: AdamW, Adafactor(-lite), momentum SGD.

No optax dependency.  State dtypes are configurable (fp32 moments by
default; bf16 supported for memory-squeezed cells) and optimizer state
inherits the parameter sharding (FSDP x TP), so per-chip optimizer memory
scales down with the full mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any          # pytree like params
    v: Any


class AdafactorState(NamedTuple):
    step: jax.Array
    vr: Any         # row second-moment (last-dim reduced)
    vc: Any         # col second-moment (second-to-last reduced)
    v: Any          # full second moment for <2D tensors


class SGDMState(NamedTuple):
    step: jax.Array
    m: Any


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


def _unzip(out, like, n: int):
    """Split a tree of n-tuples into an n-tuple of trees (NamedTuple-safe)."""
    outer = jax.tree.structure(like)
    inner = jax.tree.structure(tuple(0 for _ in range(n)))
    return jax.tree.transpose(outer, inner, out)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(
        g.dtype), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params, tc: TrainConfig) -> AdamWState:
    dt = _dtype(tc.opt_state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def adamw_update(grads, state: AdamWState, params, tc: TrainConfig,
                 lr: Optional[jax.Array] = None):
    lr = tc.learning_rate if lr is None else lr
    b1, b2, eps = tc.beta1, tc.beta2, 1e-8
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + tc.weight_decay * p.astype(
            jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_p, new_m, new_v = _unzip(out, params, 3)
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments — sublinear optimizer memory)
# ---------------------------------------------------------------------------

def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor_init(params, tc: TrainConfig) -> AdafactorState:
    dt = _dtype(tc.opt_state_dtype)

    def vr(p):
        return jnp.zeros(p.shape[:-1], dt) if _factored(p) else jnp.zeros(
            (), dt)

    def vc(p):
        return jnp.zeros(p.shape[:-2] + p.shape[-1:], dt) if _factored(p) \
            else jnp.zeros((), dt)

    def vf(p):
        return jnp.zeros((), dt) if _factored(p) else jnp.zeros(p.shape, dt)

    return AdafactorState(step=jnp.zeros((), jnp.int32),
                          vr=jax.tree.map(vr, params),
                          vc=jax.tree.map(vc, params),
                          v=jax.tree.map(vf, params))


def adafactor_update(grads, state: AdafactorState, params, tc: TrainConfig,
                     lr: Optional[jax.Array] = None):
    lr = tc.learning_rate if lr is None else lr
    step = state.step + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** -0.8
    eps = 1e-30

    def upd(g, vr, vc, v, p):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + eps
        if _factored(p):
            vr32 = beta2 * vr.astype(jnp.float32) + (1 - beta2) * jnp.mean(
                g2, axis=-1)
            vc32 = beta2 * vc.astype(jnp.float32) + (1 - beta2) * jnp.mean(
                g2, axis=-2)
            rfac = vr32 / jnp.maximum(
                jnp.mean(vr32, axis=-1, keepdims=True), eps)
            pre = rfac[..., None] * vc32[..., None, :]
            upd_ = g32 * jax.lax.rsqrt(jnp.maximum(pre, eps))
            v32 = v.astype(jnp.float32)
        else:
            v32 = beta2 * v.astype(jnp.float32) + (1 - beta2) * g2
            upd_ = g32 * jax.lax.rsqrt(jnp.maximum(v32, eps))
            vr32 = vr.astype(jnp.float32)
            vc32 = vc.astype(jnp.float32)
        # update clipping (Shazeer & Stern)
        rms = jnp.sqrt(jnp.mean(upd_ * upd_))
        upd_ = upd_ / jnp.maximum(1.0, rms)
        new_p = (p.astype(jnp.float32) - lr * upd_
                 - lr * tc.weight_decay * p.astype(jnp.float32)).astype(
                     p.dtype)
        return new_p, vr32.astype(vr.dtype), vc32.astype(vc.dtype), \
            v32.astype(v.dtype)

    out = jax.tree.map(upd, grads, state.vr, state.vc, state.v, params)
    new_p, vr, vc, v = _unzip(out, params, 4)
    return new_p, AdafactorState(step=step, vr=vr, vc=vc, v=v)


# ---------------------------------------------------------------------------
# momentum SGD
# ---------------------------------------------------------------------------

def sgdm_init(params, tc: TrainConfig) -> SGDMState:
    dt = _dtype(tc.opt_state_dtype)
    return SGDMState(step=jnp.zeros((), jnp.int32),
                     m=jax.tree.map(lambda p: jnp.zeros(p.shape, dt),
                                    params))


def sgdm_update(grads, state: SGDMState, params, tc: TrainConfig,
                lr: Optional[jax.Array] = None):
    lr = tc.learning_rate if lr is None else lr

    def upd(g, m, p):
        m32 = 0.9 * m.astype(jnp.float32) + g.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * m32
                 - lr * tc.weight_decay * p.astype(jnp.float32)).astype(
                     p.dtype)
        return new_p, m32.astype(m.dtype)

    out = jax.tree.map(upd, grads, state.m, params)
    new_p, m = _unzip(out, params, 2)
    return new_p, SGDMState(step=state.step + 1, m=m)


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------

def init(params, tc: TrainConfig):
    return {"adamw": adamw_init, "adafactor": adafactor_init,
            "sgdm": sgdm_init}[tc.optimizer](params, tc)


def update(grads, state, params, tc: TrainConfig, lr=None):
    fn = {"adamw": adamw_update, "adafactor": adafactor_update,
          "sgdm": sgdm_update}[tc.optimizer]
    return fn(grads, state, params, tc, lr)


def lr_schedule(tc: TrainConfig, step, warmup: int = 100,
                total: int = 10_000):
    """Linear warmup + cosine decay."""
    t = step.astype(jnp.float32)
    warm = t / max(warmup, 1)
    prog = jnp.clip((t - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return tc.learning_rate * jnp.minimum(warm, 1.0) * jnp.maximum(cos, 0.1)
