from repro.train import optimizer, train_step, serve_step
