"""Fig. 4 analog: multiple planning-ahead with the N most recent working
sets.  Paper's finding: N in {2, 3} is comparable to (slightly better
than) standard PA-SMO; large N slows the solver down."""

import time

import jax
import jax.numpy as jnp

from repro.core import qp as qp_mod
from repro.core.solver import SolverConfig, solve
from repro.svm.data import make_dataset

NS = [1, 2, 3, 5, 10]
CASES = [("xor", 600, 100.0, 0.5), ("chessboard", 600, 10_000.0, 0.5)]


def run():
    rows = []
    for name, n, C, gamma in CASES:
        X, y, _, _ = make_dataset(name, n, seed=0)
        kern = qp_mod.make_rbf(jnp.asarray(X), gamma)
        yj = jnp.asarray(y)
        base_time = None
        for N in NS:
            cfg = SolverConfig(algorithm="pasmo", plan_candidates=N,
                               eps=1e-3, max_iter=400_000)
            r = solve(kern, yj, C, cfg)
            jax.block_until_ready(r.alpha)
            t0 = time.perf_counter()
            r = solve(kern, yj, C, cfg)
            jax.block_until_ready(r.alpha)
            dt = time.perf_counter() - t0
            if N == 1:
                base_time = dt
            rows.append((f"fig4/{name}-{n}/N={N}", dt * 1e6,
                         f"iters={int(r.iterations)};"
                         f"rel_time={dt / base_time:.3f};"
                         f"planning={int(r.n_planning)}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
