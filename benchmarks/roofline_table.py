"""Emit the roofline table from the dry-run artifacts (one row per
(arch x shape x mesh) cell).  Run the dry-run first:

    python -m repro.launch.dryrun --mesh single --arch all --shape all
    python -m repro.launch.dryrun --mesh multi  --arch all --shape all
"""

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def run():
    rows = []
    for f in sorted(glob.glob(os.path.join(ART, "*.json"))):
        r = json.load(open(f))
        tag = f"roofline/{r['mesh']}/{r['arch']}/{r['shape']}"
        if r.get("skipped"):
            rows.append((tag, 0.0, f"skipped:{r['skip_reason']}"))
            continue
        if not r.get("ok"):
            rows.append((tag, 0.0, f"FAILED:{r.get('error', '?')[:80]}"))
            continue
        if "roofline" not in r:
            # solver dry-run artifacts carry per-iteration terms instead
            if "per_iteration" in r:
                p = r["per_iteration"]
                rows.append((tag, p["compute_us"],
                             f"memory_us={p['memory_us']:.3f};"
                             f"collective_us={p['collective_us']:.3f}"))
            continue
        ro = r["roofline"]
        rows.append((tag, ro["compute_s"] * 1e6,
                     f"dominant={ro['dominant']};"
                     f"memory_s={ro['memory_s']:.3e};"
                     f"collective_s={ro['collective_s']:.3e};"
                     f"useful_ratio={ro['useful_flops_ratio']:.3f};"
                     f"frac={ro['roofline_fraction']:.3f}"))
    if not rows:
        rows.append(("roofline/none", 0.0, "no artifacts - run the dry-run"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
