"""Solver micro-benchmarks: per-iteration cost of the standard (oracle)
solver vs the fused two-pass solver, and the batched (vmap) throughput
mode.  CPU numbers use the jnp kernel path; the Pallas path targets TPU
(validated in interpret mode by tests)."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qp as qp_mod
from repro.core.solver import SolverConfig, solve, solve_batched
from repro.core.solver_fused import solve_fused
from repro.svm.data import xor_gaussians

SIZES = [1024, 4096, 16384]


def run():
    rows = []
    for n in SIZES:
        X, y = xor_gaussians(n, seed=0)
        gamma, C = 0.5, 100.0
        Xj, yj = jnp.asarray(X), jnp.asarray(y)
        cfg = SolverConfig(algorithm="pasmo", eps=1e-3, max_iter=30_000)

        kern = qp_mod.make_rbf(Xj, gamma)
        r = solve(kern, yj, C, cfg)
        jax.block_until_ready(r.alpha)
        t0 = time.perf_counter()
        r = solve(kern, yj, C, cfg)
        jax.block_until_ready(r.alpha)
        dt_std = time.perf_counter() - t0
        us_std = dt_std / max(int(r.iterations), 1) * 1e6

        rf = solve_fused(Xj, yj, C, gamma, cfg, impl="jnp")
        jax.block_until_ready(rf.alpha)
        t0 = time.perf_counter()
        rf = solve_fused(Xj, yj, C, gamma, cfg, impl="jnp")
        jax.block_until_ready(rf.alpha)
        dt_fused = time.perf_counter() - t0
        us_fused = dt_fused / max(int(rf.iterations), 1) * 1e6

        rows.append((f"solver_micro/standard/l={n}", us_std,
                     f"iters={int(r.iterations)}"))
        rows.append((f"solver_micro/fused/l={n}", us_fused,
                     f"iters={int(rf.iterations)};"
                     f"speedup={us_std / us_fused:.2f}x"))

    # batched throughput: 8 QPs in one vmapped while_loop
    n, B = 512, 8
    Ks, ys = [], []
    for s in range(B):
        X, y = xor_gaussians(n, seed=s)
        sq = np.sum(X * X, 1)
        Ks.append(np.exp(-0.5 * (sq[:, None] + sq[None, :] - 2 * X @ X.T)))
        ys.append(y)
    Ks = jnp.asarray(np.stack(Ks))
    ys = jnp.asarray(np.stack(ys))
    cfg = SolverConfig(algorithm="pasmo", eps=1e-3, max_iter=30_000)
    r = solve_batched(Ks, ys, 100.0, cfg)
    jax.block_until_ready(r.alpha)
    t0 = time.perf_counter()
    r = solve_batched(Ks, ys, 100.0, cfg)
    jax.block_until_ready(r.alpha)
    dt_b = time.perf_counter() - t0
    # sequential baseline
    t0 = time.perf_counter()
    for s in range(B):
        rs = solve(qp_mod.PrecomputedKernel(Ks[s]), ys[s], 100.0, cfg)
        jax.block_until_ready(rs.alpha)
    dt_seq = time.perf_counter() - t0
    rows.append((f"solver_micro/batched/B={B}xl={n}", dt_b * 1e6,
                 f"seq_time_us={dt_seq * 1e6:.0f};"
                 f"batch_speedup={dt_seq / dt_b:.2f}x"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
