"""CI perf gate: compare a fresh quick-profile ``BENCH_grid.json`` against
the checked-in record and FAIL on a real speedup regression.

    python -m benchmarks.bench_gate FRESH_JSON RECORD_JSON

Gated metrics (both are *same-machine ratios* — contenders run interleaved
on the same host in the same process — so they transfer across runner
generations where absolute wall times do not):

* ``fused_batched_vs_sequential`` — the fused batched engine's speedup
  over the status-quo sequential loop;
* ``doubled_row_parity`` — t_base / t_doubled for the pass A/B kernel pair
  at equal base l (interpret backend): guards the in-kernel doubled ε-SVR
  row mode staying within ~1.2x of the plain pass (the halved-matmul win —
  a regression toward the old pre-tiled-X 2x shows up here).
* ``shrinking_speedup`` — t_off / t_on for the chunked fused driver with
  the active-set shrinking + row-compaction knob on a skewed-straggler
  grid (bar: >= 1.3x; guards the shrink/unshrink cycle staying a net win).
* ``sharded_lanes_speedup`` — t_fused_single / t_sharded for the
  lane-sharded engine over every attached device (bar: >= 2x with 8
  forced host devices; measured only when >1 device is attached — a
  single-device fresh run simply lacks the config and the gate skips it).
* ``telemetry_overhead`` — t_off / t_on for the fused engine with the
  flight recorder (ISSUE 8): ~1.0 means the telemetry ring + drain is
  near-free; its per-record tolerance bounds the allowed recorder cost
  at ~10%.  (Telemetry *off* is gated structurally instead: the jaxpr is
  asserted byte-identical to pre-telemetry in ``tests/test_telemetry.py``.)
* ``conjugate_iters_ratio`` — iters_pasmo / iters_conjugate on the
  chess-board problem (ISSUE 9): an ITERATION-COUNT ratio, deterministic
  per (jax version, dtype), so host noise can't move it.  Bar: >= 1.1x —
  the per-record tolerance in ``BENCH_grid_quick.json`` maps the
  measured ~1.75x record down to exactly that floor.

A fresh record whose ``"errors"`` list is non-empty is PARTIAL — some
bench entry raised and ``benchmarks.run`` already exited non-zero; the
gate refuses to pass judgement on it (the surviving ratios may be fine,
but "green gate over a failed bench" is how silent coverage loss starts).

On any failure the gate prints the stored-vs-fresh **environment
fingerprint** diff (machine/backend/device provenance stamped into every
``BENCH_*.json`` record) — the first suspect for cross-machine ratio
drift.

Noise policy:

* the quick profile times contenders in alternating rounds and computes
  every gated ratio from the MEDIAN over rounds (see
  ``benchmarks/grid_bench.py``) — min-of-rounds let one lucky round move
  a checked-in ratio by tens of percent between identical runs;
* the gate tolerates a 25% drop below the record before failing
  (``BENCH_GATE_TOLERANCE`` overrides, e.g. ``0.4`` on flakier hardware);
  a record entry may also carry its own ``"tolerances": {metric: frac}``
  map for metrics known to be noisier than the default — the per-record
  value wins over the global one;
* ``BENCH_GATE_SKIP=1`` turns the gate into a report-only run — the CI
  workflow sets it when a PR carries the ``bench-noisy-runner`` label.

A fresh speedup *above* the record prints a hint to refresh the record
(``benchmarks/BENCH_grid_quick.json``) but never fails.
"""

import json
import os
import sys

METRICS = ("fused_batched_vs_sequential", "doubled_row_parity",
           "shrinking_speedup", "sharded_lanes_speedup",
           "telemetry_overhead", "conjugate_iters_ratio")
DEFAULT_TOLERANCE = 0.25


def _fingerprint_note(fresh: dict, record: dict) -> None:
    """On a gate failure, show WHERE the two records came from.

    Same-machine ratios transfer across hosts, but not perfectly — a
    regression verdict on a very different machine (backend, device
    kind, core count) is the first thing to rule out.  Records predating
    the fingerprint field just say so.  Stdlib-only on purpose (the gate
    must not need jax): both fingerprints come from the JSON files.
    """
    fp_f = fresh.get("fingerprint")
    fp_r = record.get("fingerprint")
    if not fp_f or not fp_r:
        which = "fresh run" if not fp_f else "checked-in record"
        print(f"bench_gate: {which} carries no environment fingerprint "
              "(predates it?) — cannot diff environments")
        return
    keys = sorted(set(fp_f) | set(fp_r))
    diffs = [f"  {k}: record={fp_r.get(k)!r} -> fresh={fp_f.get(k)!r}"
             for k in keys if fp_r.get(k) != fp_f.get(k)]
    if diffs:
        print("bench_gate: environment differs from the record "
              "(ratio drift suspect):")
        print("\n".join(diffs))
    else:
        print("bench_gate: environment fingerprint matches the record")


def _config_key(entry: dict):
    c = entry["config"]
    return (c["l"], c["k"], c["n_gamma"], entry["n_qp"])


def gate(fresh_path: str, record_path: str) -> int:
    tolerance = float(os.environ.get("BENCH_GATE_TOLERANCE",
                                     DEFAULT_TOLERANCE))
    skip = os.environ.get("BENCH_GATE_SKIP", "") not in ("", "0", "false")
    with open(fresh_path) as f:
        fresh = json.load(f)
    with open(record_path) as f:
        record = json.load(f)

    errors = fresh.get("errors") or []
    if errors:
        print(f"bench_gate: fresh record is PARTIAL — {len(errors)} bench "
              "entr" + ("y" if len(errors) == 1 else "ies") + " failed:")
        for e in errors:
            print(f"  {e['entry']}: {e['error']}")
        if skip:
            print("bench_gate: partial record IGNORED (BENCH_GATE_SKIP set)")
            return 0
        return 1

    rec_by_key = {_config_key(e): e for e in record["configs"]}
    checked = 0
    failures = []
    for entry in fresh["configs"]:
        key = _config_key(entry)
        rec = rec_by_key.get(key)
        if rec is None:
            print(f"bench_gate: no record for config {key} — skipping")
            continue
        for metric in METRICS:
            if metric not in rec.get("speedups", {}):
                if metric in entry.get("speedups", {}):
                    # the fresh run measures it but the record predates it:
                    # the metric is effectively ungated — make that visible
                    print(f"bench_gate: record lacks {metric} for config "
                          f"{key} — NOT gated; refresh {record_path}")
                continue
            got = entry.get("speedups", {}).get(metric)
            if got is None:
                # e.g. the quick profile dropped its sequential contender
                print(f"bench_gate: fresh run lacks {metric} for config "
                      f"{key} — skipping")
                continue
            want = rec["speedups"][metric]
            tol = float(rec.get("tolerances", {}).get(metric, tolerance))
            floor = want * (1.0 - tol)
            verdict = "OK" if got >= floor else "REGRESSION"
            print(f"bench_gate: {metric} @ {key}: fresh {got:.2f}x vs "
                  f"record {want:.2f}x (floor {floor:.2f}x) -> {verdict}")
            if got < floor:
                failures.append((key, metric))
            elif got > want * (1.0 + tol):
                print(f"bench_gate: note — fresh is >{tol:.0%} above "
                      f"the record; consider refreshing {record_path}")
            checked += 1

    if checked == 0:
        print("bench_gate: ERROR — no comparable configs between fresh "
              "and record")
        _fingerprint_note(fresh, record)
        return 0 if skip else 1
    if failures:
        msg = (f"bench_gate: {len(failures)} config(s) regressed "
               f">{tolerance:.0%} below the checked-in record")
        _fingerprint_note(fresh, record)
        if skip:
            print(msg + " — IGNORED (BENCH_GATE_SKIP set, e.g. via the "
                        "bench-noisy-runner label)")
            return 0
        print(msg)
        return 1
    print(f"bench_gate: all {checked} config(s) within tolerance")
    return 0


def check_record(path: str) -> list:
    """Schema/fingerprint lint of one ``BENCH_*.json`` record.

    No benchmark runs, no jax import — this is the ``--check-only`` mode
    the CI static-analysis job uses to lint *checked-in* records, so a
    hand-edited or truncated record fails loudly before it silently
    un-gates a metric.  Returns a list of problem strings (empty = ok).
    """
    problems = []
    try:
        with open(path) as f:
            rec = json.load(f)
    except (OSError, ValueError) as e:
        return [f"unreadable record: {e}"]
    configs = rec.get("configs")
    if not isinstance(configs, list) or not configs:
        return ["missing/empty 'configs' list"]
    fp = rec.get("fingerprint")
    if not isinstance(fp, dict) or not fp:
        problems.append("missing environment 'fingerprint' (every record "
                        "stamps provenance; see benchmarks/grid_bench.py)")
    if rec.get("errors"):
        problems.append(f"record is PARTIAL ({len(rec['errors'])} bench "
                        "error(s)) — a partial record must not be "
                        "checked in")
    seen = set()
    for n, entry in enumerate(configs):
        where = f"configs[{n}]"
        cfg = entry.get("config")
        if not isinstance(cfg, dict) \
                or not {"l", "k", "n_gamma"} <= set(cfg):
            problems.append(f"{where}: 'config' must carry l/k/n_gamma")
            continue
        if "n_qp" not in entry:
            problems.append(f"{where}: missing 'n_qp'")
            continue
        key = _config_key(entry)
        if key in seen:
            problems.append(f"{where}: duplicate config key {key} "
                            "(the gate would silently drop one)")
        seen.add(key)
        speedups = entry.get("speedups")
        if not isinstance(speedups, dict) or not speedups:
            problems.append(f"{where}: missing/empty 'speedups'")
            continue
        for metric, v in speedups.items():
            if not isinstance(v, (int, float)) or not v > 0:
                problems.append(f"{where}: speedups[{metric!r}] = {v!r} "
                                "is not a positive number")
        for metric, tol in (entry.get("tolerances") or {}).items():
            if metric not in speedups:
                problems.append(f"{where}: tolerance for {metric!r} "
                                "which the entry does not measure")
            if not isinstance(tol, (int, float)) or not 0 < tol < 1:
                problems.append(f"{where}: tolerances[{metric!r}] = "
                                f"{tol!r} outside (0, 1)")
    return problems


def check_only(paths) -> int:
    status = 0
    for path in paths:
        problems = check_record(path)
        if problems:
            status = 1
            print(f"bench_gate: {path}: {len(problems)} problem(s)")
            for msg in problems:
                print(f"  {msg}")
        else:
            print(f"bench_gate: {path}: schema/fingerprint OK")
    return status


def main() -> None:
    if len(sys.argv) >= 3 and sys.argv[1] == "--check-only":
        sys.exit(check_only(sys.argv[2:]))
    if len(sys.argv) != 3:
        sys.exit(__doc__)
    sys.exit(gate(sys.argv[1], sys.argv[2]))


if __name__ == "__main__":
    main()
