"""§7.2 analog ablation: is the win from planning-ahead or from the
modified working-set selection?

Variants: smo (Alg. 1 + WSS2), pasmo (Alg. 3+4), pasmo_simple (Alg. 2 —
planning after any SMO step with unmodified WSS2 selection), and the
first-order MVP selection baseline.  Paper's finding: the speedup comes
from planning-ahead, not the selection change."""

import jax
import jax.numpy as jnp

from repro.core import qp as qp_mod
from repro.core.solver import SolverConfig, solve
from repro.svm.data import make_dataset

VARIANTS = [
    ("smo", dict(algorithm="smo")),
    ("pasmo", dict(algorithm="pasmo")),
    ("pasmo_simple", dict(algorithm="pasmo_simple")),
    ("smo_mvp", dict(algorithm="smo", wss="mvp")),
]

CASES = [("xor", 600, 100.0, 0.5), ("chessboard", 600, 10_000.0, 0.5)]


def run():
    rows = []
    for name, n, C, gamma in CASES:
        X, y, _, _ = make_dataset(name, n, seed=0)
        kern = qp_mod.make_rbf(jnp.asarray(X), gamma)
        yj = jnp.asarray(y)
        base = None
        for label, kw in VARIANTS:
            cfg = SolverConfig(eps=1e-3, max_iter=600_000, **kw)
            r = solve(kern, yj, C, cfg)
            it = int(r.iterations)
            if label == "smo":
                base = it
            rows.append((f"ablation/{name}-{n}/{label}", 0.0,
                         f"iters={it};vs_smo={it / max(base, 1):.3f};"
                         f"converged={bool(r.converged)}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
