"""Fig. 3 analog: histogram of the planning-step size relative to the
Newton step, mu/mu* - 1.

Paper's finding: the distribution is strongly asymmetric — most planning
steps slightly overshoot the Newton step, a few overshoot by orders of
magnitude, almost none shrink or reverse."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qp as qp_mod
from repro.core.solver import SolverConfig, solve
from repro.svm.data import make_dataset

CASES = [("xor", 600, 100.0, 0.5), ("chessboard", 600, 10_000.0, 0.5),
         ("ring", 600, 10.0, 1.0)]

BUCKETS = [(-np.inf, -1.0, "reversed"), (-1.0, -0.1, "shrunk"),
           (-0.1, 0.1, "near-newton"), (0.1, 1.0, "overshoot<2x"),
           (1.0, 10.0, "overshoot<11x"), (10.0, np.inf, "overshoot>11x")]


def run():
    rows = []
    for name, n, C, gamma in CASES:
        X, y, _, _ = make_dataset(name, n, seed=0)
        kern = qp_mod.make_rbf(jnp.asarray(X), gamma)
        cfg = SolverConfig(algorithm="pasmo", eps=1e-3, max_iter=400_000,
                           record_trace=True, trace_cap=65536)
        r = solve(kern, jnp.asarray(y), C, cfg)
        k = int(min(int(r.n_trace), cfg.trace_cap))
        ratios = np.asarray(r.trace)[:k] - 1.0
        counts = {}
        for lo, hi, label in BUCKETS:
            counts[label] = int(np.sum((ratios > lo) & (ratios <= hi)))
        frac_over = (counts["overshoot<2x"] + counts["overshoot<11x"]
                     + counts["overshoot>11x"]) / max(k, 1)
        frac_shrunk = (counts["reversed"] + counts["shrunk"]) / max(k, 1)
        detail = ";".join(f"{l}={c}" for l, c in counts.items())
        rows.append((f"fig3/{name}-{n}", 0.0,
                     f"planning_steps={k};frac_overshoot={frac_over:.3f};"
                     f"frac_shrunk={frac_shrunk:.3f};{detail}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
