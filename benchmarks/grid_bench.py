"""Grid-solve throughput: batched multi-QP subsystem vs sequential loops.

Three ways to solve a (gamma, class, C) model-selection grid:

* ``grid/compacted``  — :func:`repro.core.grid.solve_grid_compacted`: all
  (gamma, class) lanes vmapped, scaled warm starts along C, and the batch
  re-compacted every ``chunk`` iterations so converged lanes stop costing
  wall time.  The CPU throughput mode.
* ``grid/fused``      — :func:`repro.core.grid.solve_grid`: the whole grid
  as ONE jit-compiled vmapped call (the accelerator mode; on CPU it pays
  the straggler tax of the slowest lane per C-step).
* ``grid/seq_oracle`` — the status-quo loop: one jitted ``solve`` per grid
  point through the on-the-fly RBF row oracle (what ``train_svm`` does
  today).  ``grid/seq_gram`` is the same loop upgraded with a precomputed
  Gram per gamma — a stronger baseline than the repo had.

``grid/speedup`` = seq_oracle / compacted (the acceptance bar is >= 2x on
CPU).  All timings are min-over-repeats measured in alternating pairs, so
slow host windows (thread migration, cgroup throttling) hit every
contender equally.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import grid as grid_mod
from repro.core import multiclass as mc
from repro.core import qp as qp_mod
from repro.core.solver import SolverConfig, solve


def _workload(l, d, k, n_gamma, g_range, Cs):
    from repro.svm.data import multiclass_blobs
    X, y = multiclass_blobs(l, seed=0, k=k, d=d)
    X = jnp.asarray(X)
    _, y_idx = mc.class_index(y)
    Y = mc.ovr_labels(y_idx, k)
    gammas = np.geomspace(*g_range, n_gamma)
    return X, Y, gammas, np.asarray(Cs, np.float64)


def _sequential(X, Y, gammas, Cs, cfg, precompute):
    outs = []
    for g in gammas:
        if precompute:
            kern = qp_mod.PrecomputedKernel(jnp.exp(-g * grid_mod.sqdist(X)))
        else:
            kern = qp_mod.make_rbf(X, g)
        for c in range(Y.shape[0]):
            for C in Cs:
                outs.append(solve(kern, Y[c], float(C), cfg))
    jax.block_until_ready(outs[-1].alpha)
    return outs


def _interleaved_min(fns, repeat):
    """min wall time per contender, measured in alternating rounds."""
    for fn in fns:
        fn()  # warmup / compile
    mins = [float("inf")] * len(fns)
    for _ in range(repeat):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            fn()
            mins[i] = min(mins[i], time.perf_counter() - t0)
    return mins


def run():
    cfg = SolverConfig(eps=1e-3)
    rows = []
    # Small-l, realistic feature dim, dense C-path: the model-selection
    # shape (many small QPs).  The larger config is reported for context.
    for l, d, k, ng, g_range, Cs, rep in [
            (64, 32, 4, 8, (0.05, 1.0), np.geomspace(0.5, 64.0, 10), 6),
            (256, 2, 3, 2, (0.3, 1.0), [1.0, 4.0, 16.0, 32.0], 3)]:
        X, Y, gammas, Cs = _workload(l, d, k, ng, g_range, Cs)
        n_qp = ng * k * len(Cs)

        res = grid_mod.solve_grid_compacted(X, Y, Cs, gammas, cfg)
        assert bool(jnp.all(res.converged))

        def compacted():
            r = grid_mod.solve_grid_compacted(X, Y, Cs, gammas, cfg)
            jax.block_until_ready(r.alpha)

        def fused():
            r = grid_mod.solve_grid(X, Y, Cs, gammas, cfg)
            jax.block_until_ready(r.alpha)

        t_c, t_f, t_o, t_g = _interleaved_min(
            [compacted, fused,
             lambda: _sequential(X, Y, gammas, Cs, cfg, precompute=False),
             lambda: _sequential(X, Y, gammas, Cs, cfg, precompute=True)],
            repeat=rep)
        tag = f"l{l}_k{k}_g{ng}_{n_qp}qp"
        for name, t in [("compacted", t_c), ("fused", t_f),
                        ("seq_oracle", t_o), ("seq_gram", t_g)]:
            rows.append((f"grid/{name}_{tag}", t * 1e6,
                         f"{n_qp / t:.1f}_qp_per_s"))
        rows.append((f"grid/speedup_{tag}", 0.0, f"{t_o / t_c:.2f}x"))
    return rows
