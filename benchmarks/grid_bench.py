"""Grid-solve throughput: batched multi-QP engines vs sequential loops.

Contenders for a (gamma, class, C) model-selection grid:

* ``sequential``    — the status-quo loop: one jitted ``solve`` per grid
  point over a per-gamma precomputed Gram (a stronger baseline than the
  original on-the-fly-row loop; reported as the ``sequential`` mode).
* ``vmapped``       — :func:`repro.core.grid.solve_grid`: the whole grid as
  ONE jit-compiled vmapped call over the standard ~4-pass solver body (the
  PR-1 engine; op-dispatch bound on CPU).
* ``compacted``     — :func:`repro.core.grid.solve_grid_compacted`: the
  vmapped engine in host-driven chunks with converged-lane compaction.
* ``fused_batched`` — :func:`repro.core.grid.solve_grid` with
  ``impl="jnp"``: the fused two-pass batched engine, two kernel launches
  per iteration for all lanes, in-kernel lane freezing.
* ``compacted_fused`` — the chunked driver over the fused engine.

Acceptance bar (ISSUE 2): ``fused_batched`` >= 2x over ``vmapped`` on the
CPU jnp backend for a >= 24-lane heterogeneous grid at l ~ 512.  All
timings run in alternating rounds, so slow host windows (thread
migration, cgroup throttling) hit every contender equally; both the min
and the median over rounds are recorded, and every gated speedup ratio is
computed from the MEDIANS — a single lucky round used to move the
checked-in ratios by tens of percent between otherwise identical runs
(``bench_gate.py`` additionally supports per-record tolerances).

Each profile also carries a **row-pass** micro-entry (ISSUE 5): the
batched pass A + pass B kernel pair timed through the Pallas interpret
backend with the doubled ε-SVR operator (state n = 2l) vs the plain
operator at EQUAL base l.  Since the doubled mode computes the base row
tile once per grid step and reads it per half in-kernel, its per-iteration
cost must sit within ~1.2x of the base pass (``doubled_row_parity`` =
t_base / t_doubled >= ~0.83) — the old pre-tiled-X launch paid ~2x (twice
the blocks, twice the matmul width).  ``bench_gate.py`` gates this ratio.

Each profile further carries a **shrinking** entry (ISSUE 6): the chunked
fused driver (``solve_grid_compacted`` over the fused engine, which owns
the hard row-compaction path) timed with ``shrinking=True`` vs ``False``
on a skewed-straggler grid — a large-l, mostly-separable problem whose
big-C lanes iterate long on a small free set, so the active-set mask plus
physical row compaction shed most of the kernel width.
``shrinking_speedup`` = t_off / t_on is recorded and gated (bar: >= 1.3x).

With more than one attached device each profile adds a **sharded** entry
(ISSUE 7): a 64-lane (gamma, class, C) grid solved by the single-device
fused engine vs the lane-sharded engine
(:mod:`repro.core.sharded_lanes`) over every device —
``sharded_lanes_speedup`` = t_fused_single / t_sharded (bar: >= 2x under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).  The workload
(XOR data, gammas down to the near-linear regime, one big-C column) has
a FEW extreme straggler lanes — the top lane runs ~80x the median
iteration count: in that convergence tail the single-device batch drags
ALL 64 lanes through every iteration (frozen lanes are masked no-ops
whose kernel cost still scales with the lane count), while under the
round-robin deal all but the stragglers' shards terminate their
while_loops outright after a few thousand iterations.  Per-shard
termination plus lane-proportional per-iteration cost is what the gate
measures, so the speedup holds even on a single-CORE host where the
forced host devices buy no hardware parallelism (measured 3.4x at
l = 512 on one core); with real parallel devices it only grows.
Sharded-vs-fused objective parity to 1e-6 on every lane is asserted
before any timing.  On a single device the entry is skipped (the gate
skips missing configs gracefully).

Each profile also carries a **conjugate** entry (ISSUE 9): Conjugate-SMO
(``step="conjugate"`` over the plain-SMO base) vs the planning-ahead
default on the chess-board problem, gated on the deterministic
``conjugate_iters_ratio`` iteration-count ratio (see the ``CONJUGATE``
spec below and ``bench_gate.py``).

``run(profile=..., json_path=...)`` also emits the machine-readable
``BENCH_grid.json`` perf-trajectory record (see ``benchmarks.run --quick``).
Any entry that raises is recorded in the JSON's ``"errors"`` list (the
record is still written for post-mortem, marked partial) and ``run()``
re-raises at the end, so ``benchmarks.run`` exits non-zero instead of
shipping a silently-partial record; ``bench_gate.py`` likewise refuses
fresh records with a non-empty ``"errors"`` list.
"""

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import grid as grid_mod
from repro.core import multiclass as mc
from repro.core import qp as qp_mod
from repro.core.solver import SolverConfig, solve
from repro.kernels import ops as kernel_ops
from repro.telemetry import Diagnostics, RingConfig, env_fingerprint

# Each config: problem shape + which contenders to time.  "quick" is the CI
# trajectory profile (small, <1 min); "full" ends with the acceptance
# config — 8 gammas x 3 classes = 24 heterogeneous lanes at l = 512.
PROFILES = {
    "quick": [
        # repeat=5: the quick profile gates CI (benchmarks/bench_gate.py),
        # so the min-over-rounds needs enough rounds to shed host noise
        dict(l=96, d=16, k=3, n_gamma=4, g_range=(0.1, 1.0),
             Cs=[1.0, 8.0], repeat=5, sequential=True),
    ],
    "full": [
        dict(l=64, d=32, k=4, n_gamma=8, g_range=(0.05, 1.0),
             Cs=list(np.geomspace(0.5, 64.0, 10)), repeat=4,
             sequential=True),
        # acceptance config: 8 gammas x 3 classes x 4 C values = 96
        # heterogeneous QPs (24 (gamma, class) lanes) at l = 512
        dict(l=512, d=32, k=3, n_gamma=8, g_range=(0.05, 2.0),
             Cs=[0.5, 2.0, 8.0, 32.0], repeat=3, sequential=True),
    ],
}

# Row-pass micro-entry per profile: pass A + B through the interpret
# backend, doubled vs plain operator at equal base l (see module docs).
ROW_PASS = {
    "quick": dict(l=256, d=32, B=8, iters=6, repeat=3, block_l=128),
    "full": dict(l=512, d=32, B=8, iters=6, repeat=3, block_l=128),
}

# Sharded entry per profile (>1 device only): 8 gammas x 2 OVR lanes x
# 4 Cs = 64 lanes on XOR data (see module docs).  The near-linear-gamma
# big-C lanes are 10-80x the median iteration count and FEW (top-8 lane
# iters ~[21k, 11k, 8k, 7k, 7k, 5k, 2k, 2k] vs median ~250 at l=512),
# so after the round-robin deal most shards terminate their while_loops
# in a few thousand iterations while the single-device batch drags all
# 64 lanes through the ~21k-iteration tail.  Separable blob grids do NOT
# show this: their big-C column is 16 near-equal stragglers, every slab
# inherits one, and sharding buys ~1.1x.  eps is tight so the pre-timing
# 1e-6 objective-parity assert is robust to slab-shape codegen (see the
# sharded_lanes docstring) and the tail dominates the wall clock.
SHARDED = {
    "quick": dict(l=512, k=2, n_gamma=8, g_range=(0.02, 1.0),
                  Cs=[0.25, 1.0, 4.0, 64.0], repeat=3, eps=1e-5),
    "full": dict(l=512, k=2, n_gamma=8, g_range=(0.02, 1.0),
                 Cs=[0.25, 1.0, 4.0, 64.0], repeat=4, eps=1e-5),
}

# Telemetry entry per profile (ISSUE 8): the fused engine with the flight
# recorder off vs on (default ring geometry).  With telemetry=None the
# traced jaxpr is byte-identical to pre-telemetry (tests/test_telemetry.py
# asserts it), so "off" IS the zero-overhead baseline; the gated
# ``telemetry_overhead`` = t_off / t_on bounds the recorder's cost — ring
# writes every sample_every iterations plus the host-side drain — at ~10%
# (per-record tolerance in BENCH_grid_quick.json).
TELEMETRY = {
    # l is deliberately NOT tiny: the recorder's cost is a fixed host
    # drain plus O(B) in-loop algebra, so at toy sizes it reads as tens
    # of percent of a ~10ms solve while the device tier itself is ~2%
    "quick": dict(l=384, d=8, k=2, n_gamma=4, g_range=(0.2, 1.0),
                  Cs=[1.0, 8.0], repeat=5, sample_every=64),
    "full": dict(l=512, d=16, k=2, n_gamma=4, g_range=(0.2, 1.0),
                 Cs=[1.0, 8.0], repeat=5, sample_every=64),
}

# Shrinking entry per profile: the chunked fused driver on a large-l
# skewed-straggler grid, shrinking knob on vs off (see module docs).  Kept
# out of PROFILES so the quick gate never times the vmapped engine at this
# l — only the two chunked contenders run.
SHRINK = {
    # d=2 blobs at the default separation with a big-C straggler lane:
    # ~9-18k iterations concentrated on ~50 free SVs of l rows, so the
    # active-set mask + physical row compaction shed most kernel width
    "quick": dict(l=512, d=2, k=2, n_gamma=2, g_range=(0.3, 1.0),
                  Cs=[1.0, 256.0], repeat=3, chunk=256, eps=1e-5),
    "full": dict(l=1024, d=2, k=2, n_gamma=2, g_range=(0.3, 1.0),
                 Cs=[1.0, 256.0], repeat=3, chunk=256, eps=1e-5),
}


# Conjugate entry per profile (ISSUE 9): the fused engine on the paper's
# chess-board problem, ``step="conjugate"`` (over the plain-SMO base) vs
# the planning-ahead default.  The gated ``conjugate_iters_ratio`` =
# iters_pasmo / iters_conjugate is an ITERATION-COUNT ratio, not a wall
# time — deterministic per (jax version, dtype), so its gate is immune to
# host noise.  Bar: >= 1.1x (measured ~1.75x on the quick config; the
# per-record tolerance in BENCH_grid_quick.json encodes the 1.1 floor).
CONJUGATE = {
    "quick": dict(n=240, C=1000.0, gamma=0.5, eps=1e-3, repeat=2),
    "full": dict(n=240, C=1000.0, gamma=0.5, eps=1e-3, repeat=3),
}


def _workload(l, d, k, n_gamma, g_range, Cs):
    from repro.svm.data import multiclass_blobs
    X, y = multiclass_blobs(l, seed=0, k=k, d=d)
    X = jnp.asarray(X)
    _, y_idx = mc.class_index(y)
    Y = mc.ovr_labels(y_idx, k)
    gammas = np.geomspace(*g_range, n_gamma)
    return X, Y, gammas, np.asarray(Cs, np.float64)


def _sequential(X, Y, gammas, Cs, cfg):
    outs = []
    for g in gammas:
        kern = qp_mod.PrecomputedKernel(jnp.exp(-g * grid_mod.sqdist(X)))
        for c in range(Y.shape[0]):
            for C in Cs:
                outs.append(solve(kern, Y[c], float(C), cfg))
    jax.block_until_ready(outs[-1].alpha)
    return outs


def _row_pass_state(l, d, B, dup, seed=0):
    """Random-but-feasible lane state for one pass A + B iteration."""
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(l, d)))
    sqn = jnp.sum(X * X, axis=-1)
    C = 5.0
    if dup:
        zl = jnp.zeros((B, l))
        L = jnp.concatenate([zl, zl - C], axis=1)
        U = jnp.concatenate([zl + C, zl], axis=1)
    else:
        ys = jnp.asarray(np.sign(rng.normal(size=(B, l))))
        L, U = jnp.minimum(0.0, ys * C), jnp.maximum(0.0, ys * C)
    n = 2 * l if dup else l
    alpha = jnp.clip(jnp.asarray(rng.uniform(-1, 1, (B, n))), L, U)
    G = jnp.asarray(rng.normal(size=(B, n)))
    gammas = jnp.asarray(rng.uniform(0.3, 1.0, B))
    i_idx = jnp.asarray(rng.integers(0, n, B), jnp.int32)
    j_idx = jnp.asarray(rng.integers(0, n, B), jnp.int32)
    mu = jnp.asarray(rng.uniform(-0.3, 0.3, B))
    return X, sqn, G, alpha, L, U, gammas, i_idx, j_idx, mu


def _row_pass_iteration(state, dup, block_l):
    """One fused-engine iteration worth of kernel work (pass A + pass B)
    on the interpret backend — the structural proxy for the compiled
    Pallas path (block count and matmul width match the TPU launch)."""
    X, sqn, G, alpha, L, U, gammas, i_idx, j_idx, mu = state
    l = X.shape[0]
    bi = i_idx % l if dup else i_idx
    bj = j_idx % l if dup else j_idx
    lane = lambda M, idx: jnp.take_along_axis(M, idx[:, None], 1)[:, 0]
    j, gain = kernel_ops.rbf_row_wss_batched(
        X, sqn, G, alpha, L, U, jnp.take(X, bi, axis=0),
        jnp.take(sqn, bi), lane(alpha, i_idx), lane(L, i_idx),
        lane(U, i_idx), lane(G, i_idx), i_idx,
        jnp.zeros((G.shape[0],), bool), gammas,
        impl="interpret", block_l=block_l, dup=dup)
    out = kernel_ops.rbf_update_wss_batched(
        X, sqn, G, alpha, L, U, jnp.take(X, bi, axis=0),
        jnp.take(sqn, bi), jnp.take(X, bj, axis=0), jnp.take(sqn, bj),
        mu, gammas, impl="interpret", block_l=block_l, dup=dup)
    jax.block_until_ready((j, out[0]))


def _row_pass_bench(spec: dict) -> dict:
    l, d, B = spec["l"], spec["d"], spec["B"]
    iters, block_l = spec["iters"], spec["block_l"]
    states = {"row_pass_base": (_row_pass_state(l, d, B, False), False),
              "row_pass_doubled": (_row_pass_state(l, d, B, True), True)}
    fns = {name: (lambda st=st, dup=dup: [
        _row_pass_iteration(st, dup, block_l) for _ in range(iters)])
        for name, (st, dup) in states.items()}
    secs, meds = _interleaved_time(fns, spec["repeat"])
    return {
        "config": {"l": l, "d": d, "k": 0, "n_gamma": 0, "g_range": (0, 0),
                   "Cs": [], "repeat": spec["repeat"], "row_pass": True,
                   "B": B, "iters": iters, "block_l": block_l},
        "lanes": B,
        "n_qp": B,
        "eps": 0.0,
        "seconds": secs,
        "seconds_median": meds,
        "speedups": {"doubled_row_parity": (meds["row_pass_base"]
                                            / meds["row_pass_doubled"])},
    }


def _shrink_bench(spec: dict) -> dict:
    l, d, k, ng = spec["l"], spec["d"], spec["k"], spec["n_gamma"]
    X, Y, gammas, Cs = _workload(l, d, k, ng, spec["g_range"], spec["Cs"])
    cfg = SolverConfig(eps=spec["eps"])
    lanes = ng * k
    n_qp = lanes * len(Cs)
    kw = dict(chunk=spec["chunk"], impl="jnp")
    on = grid_mod.solve_grid_compacted(X, Y, Cs, gammas, cfg,
                                       shrinking=True, **kw)
    off = grid_mod.solve_grid_compacted(X, Y, Cs, gammas, cfg, **kw)
    assert bool(jnp.all(on.converged)) and bool(jnp.all(off.converged))
    np.testing.assert_allclose(np.asarray(on.objective),
                               np.asarray(off.objective),
                               rtol=1e-4, atol=1e-6)
    fns = {
        "chunked_fused_shrink_off": lambda: jax.block_until_ready(
            grid_mod.solve_grid_compacted(X, Y, Cs, gammas, cfg,
                                          **kw).alpha),
        "chunked_fused_shrink_on": lambda: jax.block_until_ready(
            grid_mod.solve_grid_compacted(X, Y, Cs, gammas, cfg,
                                          shrinking=True, **kw).alpha),
    }
    secs, meds = _interleaved_time(fns, spec["repeat"])
    return {
        "config": {"l": l, "d": d, "k": k, "n_gamma": ng,
                   "g_range": spec["g_range"], "Cs": list(spec["Cs"]),
                   "repeat": spec["repeat"], "shrink": True,
                   "chunk": spec["chunk"]},
        "lanes": lanes,
        "n_qp": n_qp,
        "eps": spec["eps"],
        "seconds": secs,
        "seconds_median": meds,
        "speedups": {"shrinking_speedup": (meds["chunked_fused_shrink_off"]
                                           / meds["chunked_fused_shrink_on"])},
    }


def _telemetry_bench(spec: dict) -> dict:
    l, d, k, ng = spec["l"], spec["d"], spec["k"], spec["n_gamma"]
    X, Y, gammas, Cs = _workload(l, d, k, ng, spec["g_range"], spec["Cs"])
    cfg = SolverConfig(eps=1e-3)
    rc = RingConfig(sample_every=spec["sample_every"])
    kw = dict(impl="jnp")

    # the recorder must not perturb the solve: identical iteration
    # trajectories; objectives to last-ulp slack only (the widened
    # while_loop carry lets XLA fuse reductions differently)
    base = grid_mod.solve_grid(X, Y, Cs, gammas, cfg, **kw)
    probe = Diagnostics(ring=rc)
    on = grid_mod.solve_grid(X, Y, Cs, gammas, cfg, diagnostics=probe, **kw)
    assert np.array_equal(np.asarray(base.iterations),
                          np.asarray(on.iterations))
    np.testing.assert_allclose(np.asarray(base.objective),
                               np.asarray(on.objective),
                               rtol=1e-12, atol=0)

    # "on" includes the full host cost: Diagnostics construction
    # (fingerprint probe), the ring through the while_loop, and the
    # per-lane drain into the in-memory sink
    fns = {
        "fused_telemetry_off": lambda: jax.block_until_ready(
            grid_mod.solve_grid(X, Y, Cs, gammas, cfg, **kw).alpha),
        "fused_telemetry_on": lambda: jax.block_until_ready(
            grid_mod.solve_grid(X, Y, Cs, gammas, cfg,
                                diagnostics=Diagnostics(ring=rc),
                                **kw).alpha),
    }
    secs, meds = _interleaved_time(fns, spec["repeat"])

    # REPRO_TELEMETRY_JSONL=<path>: persist one instrumented run's full
    # flight-recorder stream (fingerprint/phase/lane/summary) — CI uploads
    # it as an artifact and smoke-tests the report CLI on it
    out_path = os.environ.get("REPRO_TELEMETRY_JSONL")
    if out_path:
        diag = Diagnostics(out_path, ring=rc)
        grid_mod.solve_grid(X, Y, Cs, gammas, cfg, diagnostics=diag, **kw)
        diag.finalize()

    return {
        "config": {"l": l, "d": d, "k": k, "n_gamma": ng,
                   "g_range": spec["g_range"], "Cs": list(spec["Cs"]),
                   "repeat": spec["repeat"], "telemetry": True,
                   "sample_every": spec["sample_every"]},
        "lanes": ng * k,
        "n_qp": ng * k * len(Cs),
        "eps": cfg.eps,
        "seconds": secs,
        "seconds_median": meds,
        "speedups": {"telemetry_overhead": (meds["fused_telemetry_off"]
                                            / meds["fused_telemetry_on"])},
    }


def _conjugate_bench(spec: dict) -> dict:
    """Conjugate-SMO vs PA-SMO iteration counts on the chess-board (fused
    jnp engine, one lane); also times both solves for the trajectory."""
    from repro.core.solver_fused import solve_fused_batched
    from repro.svm.data import chessboard
    Xn, yn = chessboard(spec["n"], seed=0)
    X, Y = jnp.asarray(Xn), jnp.asarray(yn)[None, :]
    C, gamma = spec["C"], spec["gamma"]
    base = dict(eps=spec["eps"], max_iter=500_000)
    cfg_pa = SolverConfig(algorithm="pasmo", **base)
    cfg_cj = SolverConfig(algorithm="smo", step="conjugate", **base)
    kw = dict(impl="jnp")
    r_pa = solve_fused_batched(X, Y, C, gamma, cfg_pa, **kw)
    r_cj = solve_fused_batched(X, Y, C, gamma, cfg_cj, **kw)
    assert bool(r_pa.converged[0]) and bool(r_cj.converged[0])
    np.testing.assert_allclose(np.asarray(r_cj.objective),
                               np.asarray(r_pa.objective),
                               rtol=1e-6, atol=1e-9)
    it_pa, it_cj = int(r_pa.iterations[0]), int(r_cj.iterations[0])
    fns = {
        "fused_pasmo_chessboard": lambda: jax.block_until_ready(
            solve_fused_batched(X, Y, C, gamma, cfg_pa, **kw).alpha),
        "fused_conjugate_chessboard": lambda: jax.block_until_ready(
            solve_fused_batched(X, Y, C, gamma, cfg_cj, **kw).alpha),
    }
    secs, meds = _interleaved_time(fns, spec["repeat"])
    return {
        "config": {"l": spec["n"], "d": 2, "k": 1, "n_gamma": 1,
                   "g_range": (gamma, gamma), "Cs": [C],
                   "repeat": spec["repeat"], "conjugate": True},
        "lanes": 1,
        "n_qp": 1,
        "eps": spec["eps"],
        "iterations": {"pasmo": it_pa, "conjugate": it_cj},
        "seconds": secs,
        "seconds_median": meds,
        "speedups": {"conjugate_iters_ratio": it_pa / it_cj},
    }


def _sharded_bench(spec: dict):
    """Lane-sharded vs single-device fused engine; None on one device
    (printed as a skip — the gate tolerates the missing config)."""
    if len(jax.devices()) < 2:
        print("grid_bench: single device — sharded entry skipped "
              "(run under XLA_FLAGS=--xla_force_host_platform_"
              "device_count=8 to measure it)")
        return None
    from repro.core.sharded_lanes import resolve_lane_mesh
    from repro.svm.data import xor_gaussians
    l, k, ng = spec["l"], spec["k"], spec["n_gamma"]
    # XOR data: the small-gamma big-C lanes are rare extreme stragglers
    # (see the SHARDED comment) — binary OVR twins give k = 2 lanes
    Xn, yn = xor_gaussians(l, seed=0)
    X = jnp.asarray(Xn)
    Y = jnp.stack([jnp.asarray(yn), -jnp.asarray(yn)])
    gammas = np.geomspace(*spec["g_range"], ng)
    Cs = np.asarray(spec["Cs"], np.float64)
    cfg = SolverConfig(eps=spec["eps"])
    mesh = resolve_lane_mesh(None, None)   # every attached device, once
    n_qp = ng * k * len(Cs)
    kw = dict(impl="jnp")

    # acceptance: objective parity to 1e-6 on every lane, before timing
    r0 = grid_mod.solve_grid(X, Y, Cs, gammas, cfg, **kw)
    r1 = grid_mod.solve_grid(X, Y, Cs, gammas, cfg, mesh=mesh, **kw)
    assert bool(jnp.all(r0.converged)) and bool(jnp.all(r1.converged))
    np.testing.assert_allclose(np.asarray(r1.objective),
                               np.asarray(r0.objective),
                               rtol=0, atol=1e-6)

    fns = {
        "fused_single": lambda: jax.block_until_ready(
            grid_mod.solve_grid(X, Y, Cs, gammas, cfg, **kw).alpha),
        "sharded_lanes": lambda: jax.block_until_ready(
            grid_mod.solve_grid(X, Y, Cs, gammas, cfg, mesh=mesh,
                                **kw).alpha),
    }
    secs, meds = _interleaved_time(fns, spec["repeat"])
    return {
        "config": {"l": l, "d": 2, "k": k, "n_gamma": ng,
                   "g_range": spec["g_range"], "Cs": list(spec["Cs"]),
                   "repeat": spec["repeat"], "sharded": True,
                   "n_devices": len(jax.devices())},
        "lanes": n_qp,
        "n_qp": n_qp,
        "eps": spec["eps"],
        "seconds": secs,
        "seconds_median": meds,
        "speedups": {"sharded_lanes_speedup": (meds["fused_single"]
                                               / meds["sharded_lanes"])},
    }


def _interleaved_time(fns, repeat):
    """Per-contender (min, median) wall times over alternating rounds.

    Gated ratios are computed from the MEDIANS: the min is kept for the
    perf trajectory (best-case latency) but a single lucky round used to
    swing checked-in ratios by tens of percent between identical runs.
    """
    for fn in fns.values():
        fn()  # warmup / compile
    samples = {name: [] for name in fns}
    for _ in range(repeat):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            fn()
            samples[name].append(time.perf_counter() - t0)
    return ({name: min(s) for name, s in samples.items()},
            {name: float(np.median(s)) for name, s in samples.items()})


def _profile_bench(spec: dict, cfg: SolverConfig) -> dict:
    l, d, k, ng = spec["l"], spec["d"], spec["k"], spec["n_gamma"]
    X, Y, gammas, Cs = _workload(l, d, k, ng, spec["g_range"], spec["Cs"])
    lanes = ng * k
    n_qp = lanes * len(Cs)

    res = grid_mod.solve_grid(X, Y, Cs, gammas, cfg, impl="jnp")
    assert bool(jnp.all(res.converged))

    fns = {
        "vmapped": lambda: jax.block_until_ready(
            grid_mod.solve_grid(X, Y, Cs, gammas, cfg).alpha),
        "compacted": lambda: jax.block_until_ready(
            grid_mod.solve_grid_compacted(X, Y, Cs, gammas, cfg).alpha),
        "fused_batched": lambda: jax.block_until_ready(
            grid_mod.solve_grid(X, Y, Cs, gammas, cfg,
                                impl="jnp").alpha),
        "compacted_fused": lambda: jax.block_until_ready(
            grid_mod.solve_grid_compacted(X, Y, Cs, gammas, cfg,
                                          impl="jnp").alpha),
    }
    if spec["sequential"]:
        fns["sequential"] = lambda: _sequential(X, Y, gammas, Cs, cfg)

    secs, meds = _interleaved_time(fns, spec["repeat"])
    speedups = {
        "fused_batched_vs_vmapped": meds["vmapped"]
                                    / meds["fused_batched"],
        "compacted_fused_vs_vmapped": meds["vmapped"]
                                      / meds["compacted_fused"],
    }
    if "sequential" in secs:
        speedups["fused_batched_vs_sequential"] = (
            meds["sequential"] / meds["fused_batched"])
        speedups["compacted_vs_sequential"] = (
            meds["sequential"] / meds["compacted"])
    return {
        "config": {kk: spec[kk] for kk in
                   ("l", "d", "k", "n_gamma", "g_range", "Cs",
                    "repeat")},
        "lanes": lanes,
        "n_qp": n_qp,
        "eps": cfg.eps,
        "seconds": secs,
        "seconds_median": meds,
        "speedups": speedups,
    }


def run_bench(profile: str = "full") -> dict:
    cfg = SolverConfig(eps=1e-3)
    bench = {
        "benchmark": "grid",
        "profile": profile,
        "backend": jax.default_backend(),
        "device": str(jax.devices()[0]),
        "x64": bool(jax.config.jax_enable_x64),
        # which machine produced this record: bench_gate prints the
        # stored-vs-fresh diff when a gate fails, so cross-machine ratio
        # drift is diagnosable from the two JSON files alone
        "fingerprint": env_fingerprint(),
        "configs": [],
        # entries that raised, as {"entry", "error"} — a non-empty list
        # marks the record PARTIAL: ``run()`` re-raises after writing the
        # JSON so the runner exits non-zero, and bench_gate refuses to
        # gate against a partial fresh record
        "errors": [],
    }

    def add_entry(name, fn):
        try:
            entry = fn()
        except Exception as exc:
            bench["errors"].append(
                {"entry": name, "error": f"{type(exc).__name__}: {exc}"})
            print(f"grid_bench: entry '{name}' FAILED — "
                  f"{type(exc).__name__}: {exc}", flush=True)
            return
        if entry is not None:
            bench["configs"].append(entry)

    for spec in PROFILES[profile]:
        add_entry(f"profile_l{spec['l']}",
                  lambda spec=spec: _profile_bench(spec, cfg))
    add_entry("row_pass", lambda: _row_pass_bench(ROW_PASS[profile]))
    add_entry("telemetry", lambda: _telemetry_bench(TELEMETRY[profile]))
    add_entry("shrink", lambda: _shrink_bench(SHRINK[profile]))
    add_entry("conjugate", lambda: _conjugate_bench(CONJUGATE[profile]))
    add_entry("sharded", lambda: _sharded_bench(SHARDED[profile]))
    return bench


def rows_from_bench(bench: dict):
    rows = []
    for entry in bench["configs"]:
        c = entry["config"]
        tag = f"l{c['l']}_k{c['k']}_g{c['n_gamma']}_{entry['n_qp']}qp"
        for name, t in sorted(entry["seconds"].items()):
            rows.append((f"grid/{name}_{tag}", t * 1e6,
                         f"{entry['n_qp'] / t:.1f}_qp_per_s"))
        for name, s in sorted(entry["speedups"].items()):
            rows.append((f"grid/{name}_{tag}", 0.0, f"{s:.2f}x"))
    return rows


def run(profile: str = "full", json_path: str = None):
    bench = run_bench(profile)
    if json_path:
        parent = os.path.dirname(json_path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(json_path, "w") as f:
            json.dump(bench, f, indent=2, sort_keys=True)
            f.write("\n")
    if bench["errors"]:
        # the partial record is on disk (marked via its "errors" field)
        # for post-mortem, but the run must not pass: re-raise so
        # benchmarks.run counts the failure and exits non-zero
        detail = "; ".join(f"{e['entry']}: {e['error']}"
                           for e in bench["errors"])
        raise RuntimeError(
            f"{len(bench['errors'])} grid bench entr"
            f"{'y' if len(bench['errors']) == 1 else 'ies'} failed "
            f"(partial record{' at ' + json_path if json_path else ''}): "
            f"{detail}")
    return rows_from_bench(bench)
