"""Paper Table 2 analog: SMO vs PA-SMO (vs the §7.3 overshoot heuristic)
on the paper-style dataset suite — iterations, wall time, final dual
objective.

The paper's claims validated here (EXPERIMENTS.md §Paper-validation):
  * PA-SMO's iteration count is never significantly worse than SMO and is
    much lower on oscillation-prone problems (chess-board, xor),
  * at equal eps, PA-SMO's dual objective is >= SMO's,
  * the 1.1x overshoot heuristic captures part (not all) of the win.

Sizes are scaled to the CPU container; the chess-board C is the paper's
hard setting scaled to keep runtimes in seconds.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qp as qp_mod
from repro.core.solver import SolverConfig, solve
from repro.svm.data import make_dataset, permute

SUITE = [
    # (dataset, n, C, gamma, eps, max_iter)
    ("blobs", 800, 1.0, 0.05, 1e-3, 400_000),
    ("ring", 800, 10.0, 1.0, 1e-3, 400_000),
    ("xor", 800, 100.0, 0.5, 1e-3, 400_000),
    ("chessboard", 600, 10_000.0, 0.5, 1e-3, 400_000),
    ("chessboard", 1200, 10_000.0, 0.5, 1e-3, 400_000),
]

ALGS = ["smo", "pasmo", "overshoot"]
N_PERMUTATIONS = 5  # the paper uses 100; scaled for the container


def run():
    rows = []
    summary = []
    for name, n, C, gamma, eps, max_iter in SUITE:
        X0, y0, _, _ = make_dataset(name, n, seed=0)
        stats = {a: {"iters": [], "time": [], "obj": []} for a in ALGS}
        for perm in range(N_PERMUTATIONS):
            X, y = permute(X0, y0, seed=perm)
            kern = qp_mod.make_rbf(jnp.asarray(X), gamma)
            yj = jnp.asarray(y)
            for alg in ALGS:
                cfg = SolverConfig(algorithm=alg, eps=eps,
                                   max_iter=max_iter)
                r = solve(kern, yj, C, cfg)          # warm compile
                jax.block_until_ready(r.alpha)
                t0 = time.perf_counter()
                r = solve(kern, yj, C, cfg)
                jax.block_until_ready(r.alpha)
                dt = time.perf_counter() - t0
                stats[alg]["iters"].append(int(r.iterations))
                stats[alg]["time"].append(dt)
                stats[alg]["obj"].append(float(r.objective))
        tag = f"{name}-{n}"
        for alg in ALGS:
            it = np.mean(stats[alg]["iters"])
            tm = np.mean(stats[alg]["time"])
            ob = np.mean(stats[alg]["obj"])
            rows.append((f"table2/{tag}/{alg}", tm * 1e6,
                         f"iters={it:.0f};objective={ob:.6g}"))
        ratio = (np.mean(stats["pasmo"]["iters"])
                 / max(np.mean(stats["smo"]["iters"]), 1))
        obj_delta = (np.mean(stats["pasmo"]["obj"])
                     - np.mean(stats["smo"]["obj"]))
        summary.append((tag, ratio, obj_delta))
        rows.append((f"table2/{tag}/pasmo_vs_smo", 0.0,
                     f"iter_ratio={ratio:.3f};obj_delta={obj_delta:+.3g}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
