"""LM substrate micro-bench on CPU: smoke-scale train and decode step
latencies for each architecture family (sanity that the full stack runs,
not a TPU perf claim)."""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.configs.base import ServeConfig, TrainConfig
from repro.models import registry
from repro.train.serve_step import make_serve_step
from repro.train.train_step import init_state, make_train_step

ARCHS = ["qwen2-0.5b", "mixtral-8x7b", "mamba2-370m", "recurrentgemma-2b",
         "whisper-tiny", "internvl2-1b"]

TC = TrainConfig(param_dtype="float32", compute_dtype="float32",
                 accum_dtype="float32", remat="none")


def run():
    rows = []
    for arch in ARCHS:
        cfg = get_smoke(arch)
        state = init_state(jax.random.PRNGKey(0), cfg, TC)
        step = jax.jit(make_train_step(cfg, TC))
        batch = registry.demo_batch(cfg, batch=2, seq=32)
        state, m = step(state, batch)
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for _ in range(3):
            state, m = step(state, batch)
            jax.block_until_ready(m["loss"])
        us = (time.perf_counter() - t0) / 3 * 1e6
        rows.append((f"lm/train_step/{arch}", us,
                     f"loss={float(m['loss']):.3f}"))

        sc = ServeConfig(seq_len=64, batch=2, param_dtype="float32",
                         compute_dtype="float32", kv_dtype="float32")
        serve = jax.jit(make_serve_step(cfg, sc))
        cache = registry.init_cache(cfg, 2, 64, jnp.float32)
        tok = jnp.zeros((2, 1), jnp.int32)
        logits, cache = serve(state.params, cache, tok,
                              jnp.asarray(0, jnp.int32))
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        for t in range(5):
            logits, cache = serve(state.params, cache, tok,
                                  jnp.asarray(t + 1, jnp.int32))
            jax.block_until_ready(logits)
        us = (time.perf_counter() - t0) / 5 * 1e6
        rows.append((f"lm/decode_step/{arch}", us, "ok"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
