"""Shared benchmark utilities."""

import time

import jax
import numpy as np


def wall(fn, *args, repeat=1, **kwargs):
    """Wall-time a jitted call (after one warmup), seconds."""
    out = fn(*args, **kwargs)
    jax.block_until_ready(jax.tree.leaves(out)[0])
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(jax.tree.leaves(out)[0])
        ts.append(time.perf_counter() - t0)
    return min(ts), out


def emit(rows):
    """Print the harness CSV: name,us_per_call,derived."""
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
