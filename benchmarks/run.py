"""Benchmark runner: one module per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV (stdout).  Select subsets with
``python -m benchmarks.run --only table2,fig3``.  The ``grid`` benchmark
additionally writes a machine-readable ``BENCH_grid.json`` perf-trajectory
record (``--json-dir`` controls where; ``--quick`` selects the small CI
profile).
"""

import argparse
import sys
import time

REGISTRY = [
    ("table2", "benchmarks.table2_pasmo"),
    ("fig3", "benchmarks.fig3_stepsizes"),
    ("fig4", "benchmarks.fig4_multi"),
    ("ablation", "benchmarks.ablation_wss"),
    ("solver_micro", "benchmarks.solver_micro"),
    ("grid", "benchmarks.grid_bench"),
    ("kernels", "benchmarks.kernels_bench"),
    ("lm_step", "benchmarks.lm_step_bench"),
    ("roofline", "benchmarks.roofline_table"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: "
                         + ",".join(k for k, _ in REGISTRY))
    ap.add_argument("--quick", action="store_true",
                    help="small CI profile for benchmarks that support "
                         "profiles (currently: grid)")
    ap.add_argument("--json-dir", default=".",
                    help="directory for machine-readable BENCH_*.json "
                         "records (currently: BENCH_grid.json)")
    ap.add_argument("--check-only", action="store_true",
                    help="validate the schema/fingerprint of every "
                         "BENCH_*.json under --json-dir and exit — no "
                         "benchmark runs, no jax import")
    args = ap.parse_args()
    if args.check_only:
        import glob
        import os

        from benchmarks.bench_gate import check_only
        paths = sorted(glob.glob(os.path.join(args.json_dir,
                                              "BENCH_*.json")))
        if not paths:
            sys.exit(f"run --check-only: no BENCH_*.json under "
                     f"{args.json_dir}")
        sys.exit(check_only(paths))

    import jax

    jax.config.update("jax_enable_x64", True)  # f64 QP solves (paper)
    from benchmarks.common import emit
    only = set(args.only.split(",")) if args.only else None
    if only:
        unknown = only - {k for k, _ in REGISTRY}
        if unknown:
            sys.exit(f"unknown benchmark(s): {','.join(sorted(unknown))}; "
                     f"choose from: {','.join(k for k, _ in REGISTRY)}")

    import importlib
    import json
    import os

    # provenance header: the same environment fingerprint every
    # BENCH_*.json record carries, for runs that only keep the CSV
    from repro.telemetry import env_fingerprint
    print(f"# fingerprint: {json.dumps(env_fingerprint(), sort_keys=True)}",
          flush=True)

    failures = 0
    for key, module in REGISTRY:
        if only is not None and key not in only:
            continue
        t0 = time.time()
        print(f"# --- {key} ({module}) ---", flush=True)
        try:
            mod = importlib.import_module(module)
            if key == "grid":
                json_path = os.path.join(args.json_dir, "BENCH_grid.json")
                rows = mod.run(profile="quick" if args.quick else "full",
                               json_path=json_path)
                emit(rows)
                print(f"# wrote {json_path}", flush=True)
            else:
                emit(mod.run())
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{key}/ERROR,0,{type(e).__name__}:{e}", flush=True)
        print(f"# {key} done in {time.time() - t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
