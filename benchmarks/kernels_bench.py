"""Kernel-layer benchmarks: Gram build and fused pass A/B throughput on
the jnp path (CPU), plus the modeled TPU roofline time for each op."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

HBM_BW = 819e9
PEAK = 197e12


def run():
    rows = []
    rng = np.random.default_rng(0)
    for l, d in [(4096, 64), (16384, 64), (16384, 256)]:
        X = jnp.asarray(rng.normal(size=(l, d)), jnp.float32)
        sqn = jnp.sum(X * X, axis=-1)
        G = jnp.asarray(rng.normal(size=(l,)), jnp.float32)
        alpha = jnp.zeros((l,), jnp.float32)
        y = jnp.asarray(np.sign(rng.normal(size=l)), jnp.float32)
        L = jnp.minimum(0.0, y * 10.0)
        U = jnp.maximum(0.0, y * 10.0)
        gamma = jnp.float32(0.5)

        fn = jax.jit(lambda: ops.rbf_row_wss(
            X, sqn, G, alpha, L, U, X[3], alpha[3], L[3], U[3], G[3],
            jnp.asarray(3, jnp.int32), jnp.asarray(False), gamma,
            impl="jnp"))
        jax.block_until_ready(fn())
        t0 = time.perf_counter()
        for _ in range(10):
            jax.block_until_ready(fn())
        us = (time.perf_counter() - t0) / 10 * 1e6
        # modeled TPU time: pass A reads X + 5 vectors, writes k_i
        bytes_a = l * d * 4 + 6 * l * 4
        flops_a = 2 * l * d
        t_model = max(bytes_a / HBM_BW, flops_a / PEAK) * 1e6
        rows.append((f"kernels/pass_a/l={l},d={d}", us,
                     f"tpu_model_us={t_model:.2f};"
                     f"bytes={bytes_a};flops={flops_a}"))

    for n, d in [(1024, 64), (2048, 256)]:
        X = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        fn = jax.jit(lambda: ops.gram(X, X, 0.5, impl="jnp"))
        jax.block_until_ready(fn())
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(fn())
        us = (time.perf_counter() - t0) / 5 * 1e6
        flops = 2 * n * n * d
        t_model = max(flops / PEAK, (n * n * 4 + 2 * n * d * 4) / HBM_BW) \
            * 1e6
        rows.append((f"kernels/gram/n={n},d={d}", us,
                     f"tpu_model_us={t_model:.2f};flops={flops}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
